"""Docs hygiene gate (CI `docs` job) — dependency-free (stdlib + repro).

    PYTHONPATH=src python tools/check_docs.py

Two checks, both hard failures:

1. **Dangling relative links.**  Every markdown link / image target in
   the repo's committed ``*.md`` pages that is not an absolute URL or a
   pure in-page anchor must resolve to an existing file relative to the
   page that references it.  A renamed doc or a typo'd cross-link fails
   CI instead of 404ing for the next reader.

2. **Public knob coverage.**  The public configuration surfaces of the
   serving stack are introspected from the source of truth (signatures
   and dataclass fields, never a hand-maintained list) and every knob
   must be mentioned in the page that owns that surface:

   * ``repro.api.plan`` keyword knobs (the AlignSession spec) and
     ``repro.api.GatewayPolicy`` fields -> ``docs/api.md``;
   * ``repro.mapper.MapperConfig`` fields -> ``docs/api.md`` or
     ``docs/mapper.md`` (the mapper page derives each default);
   * ``repro.core.config.AlignerConfig`` fields -> ``docs/api.md`` or
     ``docs/backends.md`` (the backend matrix documents the kernel
     knobs).

   Adding a knob without documenting it fails CI with the knob name and
   the page(s) expected to cover it.
"""
from __future__ import annotations

import dataclasses
import inspect
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: markdown pages checked for dangling links (committed prose only —
#: generated artifacts and third-party files are out of scope)
PAGES = ["README.md", "ROADMAP.md", "PAPER.md", "EXPERIMENTS.md",
         "CHANGES.md"] + sorted(
    os.path.join("docs", f) for f in os.listdir(os.path.join(ROOT, "docs"))
    if f.endswith(".md"))

_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")


def check_links() -> list[str]:
    errors = []
    for page in PAGES:
        path = os.path.join(ROOT, page)
        if not os.path.exists(path):
            continue
        with open(path) as fh:
            text = fh.read()
        # fenced code blocks routinely show link-like syntax in examples
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for m in _LINK.finditer(text):
            target = m.group(1)
            if re.match(r"[a-z][a-z0-9+.-]*:", target):   # http:, mailto:
                continue
            if target.startswith("#"):                    # in-page anchor
                continue
            rel = target.split("#", 1)[0]
            resolved = os.path.normpath(
                os.path.join(ROOT, os.path.dirname(page), rel))
            if not os.path.exists(resolved):
                errors.append(f"{page}: dangling link -> {target}")
    return errors


def _mentions(pages: list[str], knob: str) -> bool:
    pat = re.compile(rf"(?<![A-Za-z0-9_]){re.escape(knob)}(?![A-Za-z0-9_])")
    for page in pages:
        with open(os.path.join(ROOT, page)) as fh:
            if pat.search(fh.read()):
                return True
    return False


def check_knobs() -> list[str]:
    from repro.api import plan
    from repro.api.gateway import GatewayPolicy
    from repro.core.config import AlignerConfig
    from repro.mapper import MapperConfig

    surfaces = [
        ("repro.api.plan", ["docs/api.md"],
         [p for p in inspect.signature(plan).parameters
          if p not in ("cfg", "cfg_overrides")]),
        ("repro.api.GatewayPolicy", ["docs/api.md"],
         [f.name for f in dataclasses.fields(GatewayPolicy)]),
        ("repro.mapper.MapperConfig", ["docs/api.md", "docs/mapper.md"],
         [f.name for f in dataclasses.fields(MapperConfig)]),
        ("repro.core.config.AlignerConfig", ["docs/api.md",
                                             "docs/backends.md"],
         [f.name for f in dataclasses.fields(AlignerConfig)]),
    ]
    errors = []
    for surface, pages, knobs in surfaces:
        missing = [k for k in knobs if not _mentions(pages, k)]
        for k in missing:
            errors.append(f"{surface}: public knob `{k}` undocumented "
                          f"(expected in {' or '.join(pages)})")
    return errors


def main() -> int:
    errors = check_links() + check_knobs()
    for e in errors:
        print(f"DOCS CHECK FAIL: {e}", file=sys.stderr)
    if errors:
        return 1
    n_pages = sum(os.path.exists(os.path.join(ROOT, p)) for p in PAGES)
    print(f"docs check ok: {n_pages} pages, links resolve, "
          f"all public knobs documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
