"""Banded X-drop pre-filter: kill hopeless candidates before alignment.

LOGAN (arXiv:2002.05200) showed X-drop is the GPU-friendly pruning
idiom: a fixed-shape banded score wavefront, no data-dependent control
flow, terminated by masking instead of branching.  This is that filter
as vectorized jnp — one jitted call scores EVERY (read, candidate)
prefix pair of a batch on-device, and the mapper drops candidates whose
best extension score never clears a fraction of the scored prefix.

The DP is the classic antidiagonal wavefront over a diagonal band:
cell (i, j) lives at wave d = i + j, offset c = i - j in [-band, band],
and depends only on waves d-1 (gap moves, offset +-1) and d-2 (the
match/mismatch diagonal, same offset) — so every wave updates all 2b+1
offsets of all N lanes in one vector op and a lane's whole score table
is two live waves, nothing is ever stored.  Per lane we track the best
score seen; a lane whose current wave drops more than ``x_drop`` below
its best is frozen (the X-drop termination), exactly LOGAN's semantics
at fixed shapes.

Scoring is +1 match, -2 mismatch, -2 gap.  The penalties MUST outweigh
the match reward: with unit penalties the optimal banded alignment of
two *random* DNA strings drifts upward (~+0.3/base — the expected LCS
of random 4-letter text covers ~65% of it), so decoys would outrun the
X-drop.  At 1:2 the random-path drift is firmly negative, a decoy lane
freezes within a few dozen waves with a best near 0, while a true
candidate at error rate e still gains ~(1 - 3e) per base — ~0.7/base at
the default 10% profile.  The keep threshold (``min_score_frac`` in the
pipeline) sits in the wide gap between the two populations —
docs/mapper.md tabulates the tuning.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core.windowing import SENTINEL_READ, SENTINEL_REF

#: "minus infinity" for int32 score cells: deep enough that a dead cell
#: can never win, shallow enough that D gap penalties can't underflow.
_NEG = -(1 << 20)


@partial(jax.jit, static_argnames=("band", "x_drop", "match", "mismatch",
                                   "gap"))
def xdrop_extend(reads, refs, *, band: int = 16, x_drop: int = 24,
                 match: int = 1, mismatch: int = 2, gap: int = 2):
    """Best banded X-drop extension score per lane.

    reads: (N, S)        uint8 codes, SENTINEL_READ-padded past each read.
    refs:  (N, S + band) uint8 codes, SENTINEL_REF-padded past each slice
           (the two sentinels never compare equal, so padding is
           automatically mismatch — no length arrays needed).
    Returns (N,) int32 best scores, anchored at cell (0, 0): extension
    starts where the chain said the alignment starts.
    """
    N, S = reads.shape
    Sr = refs.shape[1]
    C = 2 * band + 1
    offs = jnp.arange(-band, band + 1)
    neg = jnp.full((N, C), _NEG, jnp.int32)
    wave0 = jnp.where(offs == 0, 0, _NEG).astype(jnp.int32)
    wave0 = jnp.broadcast_to(wave0, (N, C))

    def step(carry, d):
        prev1, prev2, best, alive = carry
        i = (d + offs) // 2
        j = (d - offs) // 2
        # off-parity offsets are never populated (wave0 seeds only c=0 and
        # every move flips d and c parity together), but the geometric
        # bounds must be explicit so clipped gathers can't alias real chars
        ok_cell = (((d + offs) % 2) == 0) & (i >= 0) & (j >= 0) & \
                  (i <= S) & (j <= Sr)
        ok_char = ok_cell & (i >= 1) & (j >= 1)
        rc = reads[:, jnp.clip(i - 1, 0, S - 1)]
        fc = refs[:, jnp.clip(j - 1, 0, Sr - 1)]
        s = jnp.where((rc == fc) & ok_char[None, :],
                      jnp.int32(match), jnp.int32(-mismatch))
        diag = prev2 + s
        up = jnp.concatenate([neg[:, :1], prev1[:, :-1]], axis=1) - gap
        left = jnp.concatenate([prev1[:, 1:], neg[:, :1]], axis=1) - gap
        cur = jnp.maximum(diag, jnp.maximum(up, left))
        cur = jnp.where(ok_cell[None, :], cur, _NEG)
        wave_best = cur.max(axis=1)
        best = jnp.where(alive, jnp.maximum(best, wave_best), best)
        alive = alive & (wave_best >= best - x_drop)
        cur = jnp.where(alive[:, None], cur, _NEG)   # freeze: X-drop stop
        return (cur, prev1, best, alive), None

    carry = (wave0, neg, jnp.zeros((N,), jnp.int32), jnp.ones((N,), bool))
    carry, _ = jax.lax.scan(step, carry, jnp.arange(1, S + Sr + 1))
    return carry[2]


def pack_pairs(read_prefixes, ref_slices, seg_len: int, band: int,
               lanes: int | None = None):
    """Pad a ragged batch of (read prefix, ref slice) code arrays into the
    sentinel-padded (N, seg_len) / (N, seg_len + band) arrays
    ``xdrop_extend`` consumes.  ``lanes`` pads the lane count too (the
    pipeline buckets N to a power of two so the jitted wavefront compiles
    per bucket, not per batch size); pad lanes are all-sentinel and score
    0 — callers slice them off."""
    n = len(read_prefixes)
    lanes = n if lanes is None else lanes
    reads = np.full((lanes, seg_len), SENTINEL_READ, np.uint8)
    refs = np.full((lanes, seg_len + band), SENTINEL_REF, np.uint8)
    for i, (r, f) in enumerate(zip(read_prefixes, ref_slices)):
        r = np.asarray(r, np.uint8)[:seg_len]
        f = np.asarray(f, np.uint8)[:seg_len + band]
        reads[i, :len(r)] = r
        refs[i, :len(f)] = f
    return reads, refs
