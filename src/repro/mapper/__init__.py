"""repro.mapper — the mapping front half: minimizer index, colinear
chaining, X-drop pre-filter, and the ReadMapper pipeline that feeds
surviving candidates through the AlignSession front door.

    from repro.mapper import ReadMapper, MapperConfig
    with ReadMapper(genome, backend="auto") as m:
        out = m.map_batch(reads)        # strings or encoded codes
        out.mapped[0].cigar, out.stats["kill_rate"]

docs/mapper.md walks the stages and tuning.
"""
from .chain import Candidate, chain_anchors
from .index import MinimizerIndex, minimizers
from .pipeline import (CandidateOutcome, MapBatchResult, MappedRead,
                       MapperConfig, ReadMapper)
from .prefilter import pack_pairs, xdrop_extend

__all__ = [
    "Candidate", "chain_anchors", "MinimizerIndex", "minimizers",
    "CandidateOutcome", "MapBatchResult", "MappedRead", "MapperConfig",
    "ReadMapper", "pack_pairs", "xdrop_extend",
]
