"""Minimizer hash index over a reference genome (host numpy).

The front half the repo was missing: the paper (and its GPU successors)
benchmark GenASM on *candidate* pairs produced by a seeding stage like
minimap2's.  This module is that stage's index: (w, k) minimizers over
the 2-bit genome codes, stored as two hash-sorted parallel arrays
(``hashes``, ``positions``) and queried with ``np.searchsorted`` — no
python dicts, so build and lookup are vectorized numpy end to end and
the index itself is trivially picklable/shippable.

Minimizer selection is the standard scheme: hash every k-mer with an
invertible 64-bit mixer (so low-complexity k-mers don't all collide at
the low end), then keep the argmin of every w-wide window of hashes.
Two identical error-free stretches of >= w + k - 1 bases always select
the same minimizer, which is what makes read-vs-index anchor lookup
work under sequencing error.
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: out-of-alphabet codes (N bases, pad sentinels) poison any k-mer that
#: covers them: their hash is forced to the max value and dropped.
_BAD_HASH = np.uint64(0xFFFFFFFFFFFFFFFF)


def _mix64(h: np.ndarray, mask: np.uint64) -> np.ndarray:
    """Invertible 64-bit integer finalizer (minimap2's hash64), masked to
    the 2k-bit k-mer space.  Spreads adjacent/low-complexity k-mers so the
    window-argmin picks near-uniformly among them."""
    h = h & mask
    h = (~h + (h << np.uint64(21))) & mask
    h = h ^ (h >> np.uint64(24))
    h = (h + (h << np.uint64(3)) + (h << np.uint64(8))) & mask
    h = h ^ (h >> np.uint64(14))
    h = (h + (h << np.uint64(2)) + (h << np.uint64(4))) & mask
    h = h ^ (h >> np.uint64(28))
    h = (h + (h << np.uint64(31))) & mask
    return h


def kmer_hashes(codes: np.ndarray, k: int) -> np.ndarray:
    """Mixed hash of every k-mer of ``codes`` (length n-k+1).  K-mers that
    cover a non-ACGT code (>= 4: read/ref sentinels, N bases) get
    ``_BAD_HASH`` so they can never become minimizers."""
    codes = np.asarray(codes)
    n = len(codes) - k + 1
    if n <= 0:
        return np.zeros(0, np.uint64)
    c64 = codes.astype(np.uint64)
    km = np.zeros(n, np.uint64)
    for j in range(k):
        km = (km << np.uint64(2)) | (c64[j:j + n] & np.uint64(3))
    mask = np.uint64((1 << (2 * k)) - 1) if 2 * k < 64 else _BAD_HASH
    h = _mix64(km, mask)
    bad = (codes >= 4).astype(np.int32)
    cum = np.concatenate([[0], np.cumsum(bad)])
    h[(cum[k:] - cum[:-k]) > 0] = _BAD_HASH
    return h


def minimizers(codes: np.ndarray, k: int, w: int) -> tuple[np.ndarray,
                                                           np.ndarray]:
    """(hashes, positions) of the (w, k)-minimizers of ``codes``: for every
    window of w consecutive k-mers, the position of the minimum hash
    (ties -> leftmost), deduplicated.  Sequences shorter than w + k - 1
    fall back to a single window over whatever k-mers exist."""
    h = kmer_hashes(codes, k)
    if len(h) == 0:
        return np.zeros(0, np.uint64), np.zeros(0, np.int64)
    w = min(w, len(h))
    win = np.lib.stride_tricks.sliding_window_view(h, w)
    pos = np.unique(win.argmin(axis=1) + np.arange(len(win)))
    pos = pos[h[pos] != _BAD_HASH]
    return h[pos], pos.astype(np.int64)


@dataclasses.dataclass
class MinimizerIndex:
    """Hash-sorted minimizer table of one reference genome.

    ``hashes`` is sorted ascending; ``positions[i]`` is the genome offset
    of minimizer ``hashes[i]`` (equal hashes grouped, positions ascending
    within a group).  ``anchors(read)`` is the seed-lookup primitive the
    chaining stage consumes: every (read minimizer, genome occurrence)
    match as parallel (query_pos, ref_pos) arrays.  Minimizers occurring
    more than ``max_occ`` times in the genome (repeats) are skipped at
    lookup time, minimap2's ``-f`` style, so one repeat family can't
    explode the anchor list.
    """
    k: int
    w: int
    max_occ: int
    genome_len: int
    hashes: np.ndarray
    positions: np.ndarray

    @classmethod
    def build(cls, genome: np.ndarray, k: int = 13, w: int = 8,
              max_occ: int = 64) -> "MinimizerIndex":
        assert 0 < k <= 28 and w >= 1 and max_occ >= 1
        h, p = minimizers(np.asarray(genome, np.uint8), k, w)
        order = np.argsort(h, kind="stable")     # stable: positions ascend
        return cls(k, w, max_occ, len(genome), h[order],
                   p[order].astype(np.int64))

    def anchors(self, read: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """All (query_pos, ref_pos) seed matches of ``read`` against the
        index: read minimizer at query_pos equals a genome minimizer at
        ref_pos (both are k-mer start offsets)."""
        rh, rp = minimizers(np.asarray(read, np.uint8), self.k, self.w)
        if len(rh) == 0:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        lo = np.searchsorted(self.hashes, rh, "left")
        hi = np.searchsorted(self.hashes, rh, "right")
        cnt = hi - lo
        sel = np.nonzero((cnt > 0) & (cnt <= self.max_occ))[0]
        if len(sel) == 0:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        qpos = np.repeat(rp[sel], cnt[sel])
        rpos = np.concatenate([self.positions[lo[i]:hi[i]] for i in sel])
        return qpos.astype(np.int64), rpos

    def stats(self) -> dict:
        """Index telemetry (benchmarks / docs): minimizer density and the
        distinct-hash fraction that makes lookups near-unique."""
        n = len(self.hashes)
        return {"n_minimizers": int(n),
                "density": float(n / max(1, self.genome_len)),
                "n_distinct": int(len(np.unique(self.hashes))),
                "k": self.k, "w": self.w, "max_occ": self.max_occ}
