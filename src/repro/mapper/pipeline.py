"""ReadMapper: FASTQ-like read batches -> CIGARs, end to end.

The four stages the paper's evaluation presumes but this repo lacked:

1. **seed**   — minimizer lookup against a :class:`MinimizerIndex`
   (`index.py`, host numpy).
2. **chain**  — colinear chaining of anchors into candidate loci
   (`chain.py`): each candidate is a (ref_start, ref_end) window the
   windowed aligner can consume end to end.
3. **filter** — banded X-drop pre-filter (`prefilter.py`, one jitted
   jnp call for the whole batch): candidates whose extension score
   can't clear ``min_score_frac`` of the scored prefix are killed
   before they cost a full alignment.
4. **align**  — survivors stream through an existing
   :class:`repro.api.AlignSession` via ``submit``/``flush``: its
   length bucketing, AOT compile cache, threaded executor and
   bucket-compacted rescue are reused unchanged.  A candidate pair is
   byte-for-byte the pair a direct ``session.align`` call would see, so
   mapper CIGARs are bit-identical to standalone alignment
   (tests/test_mapper.py proves it differentially).

Per read, the best surviving alignment (min edit distance, chain score
as tie-break) becomes its :class:`MappedRead`; the batch-level
:class:`MapBatchResult` carries the funnel telemetry (candidates,
kill rate, alignments) that ``benchmarks/run.py --json`` exports.

The funnel rides the session's observability domain (repro.obs): each
stage runs under its own span (``mapper.map_batch`` ->
``index.lookup`` / ``chain`` / ``prefilter`` / ``align``) and the
cumulative counters (``mapper_*_total``) live on the session's
registry; ``MapBatchResult.stats`` is the per-batch DELTA of those
counters (start-vs-end snapshot), so every number it reports is
derivable from the registry.  With ``obs='off'`` the funnel, like the
session, trades its telemetry for zero overhead (stats read zeros).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..api import session as api_session
from ..core.aligner import encode, encode_ref
from .chain import Candidate, chain_anchors
from .index import MinimizerIndex
from .prefilter import pack_pairs, xdrop_extend


@dataclasses.dataclass(frozen=True)
class MapperConfig:
    """Knobs for the seed/chain/filter stages (the align stage is the
    AlignSession's own plan).  Defaults sized for ~1kb reads at ~10%
    error — docs/mapper.md derives each number."""
    k: int = 13                  # minimizer k-mer size
    w: int = 8                   # minimizer window (k-mers per window)
    max_occ: int = 64            # skip seeds occurring more often (repeats)
    min_anchors: int = 3         # colinear evidence floor per candidate
    max_candidates: int = 8      # loci tried per read
    prefilter: bool = True       # banded X-drop stage on/off
    seg_len: int = 128           # read prefix length the pre-filter scores
    band: int = 16               # X-drop diagonal band half-width
    x_drop: int = 24             # freeze a lane this far below its best
    min_score_frac: float = 0.25  # keep if best >= frac * scored prefix


@dataclasses.dataclass(frozen=True)
class CandidateOutcome:
    """Funnel record for one candidate of one read."""
    ref_start: int
    ref_end: int
    chain_score: int
    filter_score: int            # X-drop best (0 if pre-filter off)
    killed: bool                 # dropped by the pre-filter
    ok: bool                     # aligned within the session's k ladder
    dist: int                    # edit distance (-1 if killed / failed)


@dataclasses.dataclass(frozen=True)
class MappedRead:
    read_id: int
    ok: bool                     # at least one candidate aligned
    ref_start: int               # -1 when unmapped
    ref_end: int
    dist: int
    cigar: str
    k_used: int
    candidates: tuple            # CandidateOutcome per chained locus


@dataclasses.dataclass
class MapBatchResult:
    mapped: list                 # MappedRead, input order
    stats: dict                  # funnel counters (see _finalize)

    @property
    def n_mapped(self) -> int:
        return self.stats["n_mapped"]


class ReadMapper:
    """Index a genome once, then map read batches through seed -> chain ->
    pre-filter -> AlignSession.

    ``genome`` is an A/C/G/T string or ``encode_ref`` codes.  ``session``
    is an existing planned AlignSession to share; when omitted the mapper
    plans its own (forwarding ``plan_kwargs``, e.g. ``backend=``,
    ``rescue_rounds=``) and closes it with the mapper.
    """

    #: MapBatchResult.stats key -> cumulative registry metric (deltas
    #: per batch; kill_rate is derived) — see docs/observability.md
    FUNNEL_METRICS = {
        "n_reads": "mapper_reads_total",
        "n_mapped": "mapper_mapped_total",
        "n_candidates": "mapper_candidates_total",
        "n_killed": "mapper_killed_total",
        "n_aligned": "mapper_aligned_total",
        "n_no_candidates": "mapper_no_candidates_total",
    }

    def __init__(self, genome, cfg: MapperConfig | None = None, *,
                 session=None, **plan_kwargs):
        self.cfg = cfg or MapperConfig()
        self.genome = (encode_ref(genome) if isinstance(genome, str)
                       else np.asarray(genome, np.uint8))
        self.index = MinimizerIndex.build(
            self.genome, k=self.cfg.k, w=self.cfg.w,
            max_occ=self.cfg.max_occ)
        self._owns_session = session is None
        self.session = session if session is not None else api_session.plan(
            **plan_kwargs)
        # the mapper shares the session's observability domain: one
        # registry/trace carries the whole funnel -> align story
        self.obs = self.session.obs
        self._m = {k: self.obs.counter(name)
                   for k, name in self.FUNNEL_METRICS.items()}
        self._m_batches = self.obs.counter("mapper_batches_total")

    # -- stages ------------------------------------------------------------

    def candidates(self, read_codes: np.ndarray) -> list[Candidate]:
        """Stages 1+2 for one read: anchors -> chained candidate loci."""
        qpos, rpos = self.index.anchors(read_codes)
        return chain_anchors(
            qpos, rpos, len(read_codes),
            min_anchors=self.cfg.min_anchors,
            max_candidates=self.cfg.max_candidates,
            genome_len=self.index.genome_len)

    def _filter_scores(self, pairs, reads) -> np.ndarray:
        """Stage 3: one device call scoring every (read, candidate) pair.
        ``pairs`` is [(read_idx, Candidate)].  Lane count is padded to a
        power of two so the jitted wavefront compiles per bucket."""
        m = self.cfg
        lanes = 16
        while lanes < len(pairs):
            lanes *= 2
        packed_r, packed_f = pack_pairs(
            [reads[i][:m.seg_len] for i, _ in pairs],
            [self.genome[c.ref_start:c.ref_start + m.seg_len + m.band]
             for _, c in pairs],
            m.seg_len, m.band, lanes=lanes)
        scores = xdrop_extend(packed_r, packed_f, band=m.band,
                              x_drop=m.x_drop)
        return np.asarray(scores)[:len(pairs)]

    def _keep_threshold(self, read_len: int, cand: Candidate) -> int:
        scored = min(read_len, self.cfg.seg_len,
                     cand.ref_end - cand.ref_start + self.cfg.band)
        return max(1, int(self.cfg.min_score_frac * scored))

    # -- front end ---------------------------------------------------------

    def map_batch(self, reads) -> MapBatchResult:
        """Map a batch of reads (strings or ``encode`` code arrays).
        Each funnel stage runs under its own span; the batch stats are
        the registry-counter deltas across this call."""
        before = {k: m.value for k, m in self._m.items()}
        codes = [encode(r) if isinstance(r, str) else
                 np.asarray(r, np.uint8) for r in reads]

        with self.obs.span("mapper.map_batch", n_reads=len(codes)):
            with self.obs.span("index.lookup"):
                anchors = [self.index.anchors(rc) for rc in codes]
            with self.obs.span("chain"):
                per_read = [
                    chain_anchors(qpos, rpos, len(rc),
                                  min_anchors=self.cfg.min_anchors,
                                  max_candidates=self.cfg.max_candidates,
                                  genome_len=self.index.genome_len)
                    for (qpos, rpos), rc in zip(anchors, codes)]
            pairs = [(i, c) for i, cs in enumerate(per_read) for c in cs]

            if self.cfg.prefilter and pairs:
                with self.obs.span("prefilter", n_pairs=len(pairs)):
                    scores = self._filter_scores(pairs, codes)
                    keep = [s >= self._keep_threshold(len(codes[i]), c)
                            for s, (i, c) in zip(scores, pairs)]
            else:
                scores = np.zeros(len(pairs), np.int32)
                keep = [True] * len(pairs)

            with self.obs.span("align", n_pairs=sum(keep)):
                futs = {}                  # pair index -> AlignFuture
                for p, ((i, c), k) in enumerate(zip(pairs, keep)):
                    if k:
                        futs[p] = self.session.submit(
                            codes[i], self.genome[c.ref_start:c.ref_end])
                self.session.flush()
                results = {p: f.result() for p, f in futs.items()}
        return self._finalize(codes, per_read, pairs, scores, keep,
                              results, before)

    def _finalize(self, codes, per_read, pairs, scores, keep, results,
                  before):
        outcomes = [[] for _ in codes]    # CandidateOutcome per read
        best = [None] * len(codes)        # (dist, -chain_score, p)
        for p, ((i, c), s, k) in enumerate(zip(pairs, scores, keep)):
            res = results.get(p)
            ok = bool(res and res["ok"])
            dist = int(res["dist"]) if ok else -1
            outcomes[i].append(CandidateOutcome(
                c.ref_start, c.ref_end, c.score, int(s), not k, ok, dist))
            if ok:
                cand_key = (dist, -c.score, p)
                if best[i] is None or cand_key < best[i]:
                    best[i] = cand_key

        mapped = []
        for i, rc in enumerate(codes):
            if best[i] is None:
                mapped.append(MappedRead(i, False, -1, -1, -1, "", -1,
                                         tuple(outcomes[i])))
                continue
            _, _, p = best[i]
            _, c = pairs[p]
            res = results[p]
            mapped.append(MappedRead(
                i, True, c.ref_start, c.ref_start + int(res["ref_consumed"]),
                int(res["dist"]), res["cigar"], int(res["k_used"]),
                tuple(outcomes[i])))

        # record the funnel into the registry, then report this batch as
        # the counter DELTA across the call — MapBatchResult telemetry
        # is a registry view, not a hand-collected dict
        self._m_batches.inc()
        self._m["n_reads"].inc(len(codes))
        self._m["n_mapped"].inc(sum(1 for m in mapped if m.ok))
        self._m["n_candidates"].inc(len(pairs))
        self._m["n_killed"].inc(sum(1 for k in keep if not k))
        self._m["n_aligned"].inc(len(results))
        self._m["n_no_candidates"].inc(
            sum(1 for cs in per_read if not cs))
        stats = {k: self._m[k].value - before[k] for k in self._m}
        stats["kill_rate"] = (stats["n_killed"]
                              / max(1, stats["n_candidates"]))
        return MapBatchResult(mapped, stats)

    def map_read(self, read) -> MappedRead:
        return self.map_batch([read]).mapped[0]

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        if self._owns_session:
            self.session.close()

    def __enter__(self) -> "ReadMapper":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
