"""Colinear chaining: seed anchors -> candidate reference loci.

minimap2-style two-stage chaining, sized for this repo's aligner: anchors
are first grouped by diagonal (ref_pos - query_pos; indel drift keeps a
true locus's anchors within a narrow diagonal band), then each group is
reduced to its best colinear subset (query-sorted anchors with
non-decreasing ref positions — a greedy LIS stand-in that drops the
stray repeat hits a diagonal band can trap).  A surviving chain is
extrapolated to a candidate (ref_start, ref_end) window: the segment the
GenASM windowed aligner consumes END TO END, so both ends matter — every
base the estimate over/undershoots costs one edit in the first/last
window.  First and last colinear anchors carry the local diagonal at
each end, which keeps that error within a few bases at long-read error
rates.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One candidate locus: align read end-to-end against
    genome[ref_start:ref_end].  ``score`` is the colinear anchor count
    (the chain's evidence); ``n_anchors`` the raw diagonal-group size;
    ``diag`` the group's median diagonal (ref_pos - query_pos) — which is
    also the implied mapping position of read offset 0."""
    ref_start: int
    ref_end: int
    score: int
    n_anchors: int
    diag: int


def _colinear_subset(q: np.ndarray, r: np.ndarray) -> np.ndarray:
    """Indices of the greedy colinear subset: walk anchors in query order,
    keep those whose ref position does not step backwards.  Anchor counts
    per group are small (tens), so the python walk is negligible next to
    the vectorized grouping."""
    keep, last = [], -1
    for i in range(len(q)):
        if r[i] >= last:
            keep.append(i)
            last = r[i]
    return np.asarray(keep, np.int64)


def chain_anchors(qpos: np.ndarray, rpos: np.ndarray, read_len: int, *,
                  max_diag_gap: int | None = None, min_anchors: int = 3,
                  max_candidates: int = 8,
                  genome_len: int | None = None) -> list[Candidate]:
    """Chain (query_pos, ref_pos) anchors into candidate loci.

    max_diag_gap  — split diagonal groups where consecutive sorted
                    diagonals jump further than this (default scales with
                    read_len: indel drift grows with read length).
    min_anchors   — minimum colinear evidence for a candidate.
    max_candidates— keep at most this many, best colinear score first;
                    near-duplicate loci (within read_len // 2) dedupe to
                    the better-scoring chain.
    genome_len    — clip candidate windows to [0, genome_len).
    """
    if len(qpos) == 0:
        return []
    if max_diag_gap is None:
        max_diag_gap = max(32, read_len // 16)
    qpos = np.asarray(qpos, np.int64)
    rpos = np.asarray(rpos, np.int64)
    diag = rpos - qpos
    order = np.lexsort((qpos, diag))
    dg, qg, rg = diag[order], qpos[order], rpos[order]
    cut = np.nonzero(np.diff(dg) > max_diag_gap)[0] + 1
    bounds = np.concatenate([[0], cut, [len(dg)]])

    cands: list[Candidate] = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if hi - lo < min_anchors:
            continue
        o = np.argsort(qg[lo:hi], kind="stable")
        q, r = qg[lo:hi][o], rg[lo:hi][o]
        keep = _colinear_subset(q, r)
        if len(keep) < min_anchors:
            continue
        q, r = q[keep], r[keep]
        # extrapolate each end along its LOCAL diagonal: the unanchored
        # head/tail is a few minimizer spacings, so drift stays small
        start = int(r[0] - q[0])
        end = int(r[-1] + (read_len - q[-1]))
        if genome_len is not None:
            start, end = max(0, start), min(int(genome_len), end)
        if end - start < max(1, read_len // 4):
            continue
        cands.append(Candidate(start, end, int(len(keep)), int(hi - lo),
                               int(np.median(diag[order][lo:hi]))))

    cands.sort(key=lambda c: (-c.score, c.ref_start))
    out: list[Candidate] = []
    for c in cands:
        if any(abs(c.ref_start - o.ref_start) < max(1, read_len // 2)
               for o in out):
            continue                    # same locus, weaker chain
        out.append(c)
        if len(out) >= max_candidates:
            break
    return out
