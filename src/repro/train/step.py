"""Train step factory: value_and_grad -> clip -> AdamW, with optional
gradient accumulation (scan over microbatches).  Data parallelism is
GSPMD-implicit: the batch is sharded over ('pod','data'), so gradient
all-reduces are inserted by the partitioner."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..optim.adamw import AdamWConfig, adamw_update, init_opt_state


def make_train_step(model, opt_cfg: AdamWConfig, grad_accum: int = 1):
    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                acc, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                acc = jax.tree_util.tree_map(jnp.add, acc, g)
                return (acc, lsum + l), None
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                    *x.shape[1:]), batch)
            (grads, lsum), _ = jax.lax.scan(micro, (zeros, jnp.float32(0)), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
            loss = lsum / grad_accum
            metrics = {}
        new_params, new_opt, om = adamw_update(params, grads, opt, opt_cfg)
        out_metrics = {"loss": loss, **om}
        return {"params": new_params, "opt": new_opt}, out_metrics

    return train_step


def init_state(model, rng, dtype=jnp.float32):
    params = model.init(rng, dtype)
    return {"params": params, "opt": init_opt_state(params)}


def abstract_state(model, dtype=jnp.float32):
    params = model.abstract_params(dtype)
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {"params": params,
            "opt": {"m": jax.tree_util.tree_map(sds, params),
                    "v": jax.tree_util.tree_map(sds, params),
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}}


def state_partition_specs(model):
    from jax.sharding import PartitionSpec as P
    pspec = model.partition_specs()
    return {"params": pspec,
            "opt": {"m": pspec, "v": pspec, "step": P()}}
