"""Fault tolerance: supervised training loop with checkpoint/restart,
failure injection (for tests), and a step-time straggler watchdog.

On a real pod, worker failure surfaces as a raised exception / lost
heartbeat in the coordinator; the supervisor's contract is the same here:
any exception inside a step triggers restore-from-last-checkpoint and
replay.  Straggler mitigation at this layer is detection + logging (the
data pipeline over-decomposes shards so a re-mesh at the next checkpoint
boundary rebalances; see DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

from ..checkpoint.ckpt import latest_step, restore_checkpoint, save_checkpoint


class FailureInjector:
    """Deterministically fail at given steps (once each) — tests/demo."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"[ft-test] injected worker failure @ step {step}")


@dataclasses.dataclass
class Watchdog:
    """Flags steps slower than `factor` x the running median."""
    factor: float = 3.0
    history: list = dataclasses.field(default_factory=list)
    stragglers: list = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float):
        self.history.append(dt)
        if len(self.history) >= 8:
            med = sorted(self.history[-50:])[len(self.history[-50:]) // 2]
            if dt > self.factor * med:
                self.stragglers.append((step, dt, med))
                return True
        return False


def supervise(train_step: Callable, state, data, *, steps: int,
              ckpt_dir, ckpt_every: int = 50, abstract_state=None,
              shardings=None, injector: FailureInjector | None = None,
              log_every: int = 10, max_restarts: int = 5):
    """Run `steps` optimizer steps with checkpoint/restart supervision.

    `data` must be indexable by step: a callable step->batch or an object
    with .batch_at(step).  (A free-running iterator would desynchronize
    from the step counter after a restore — batches are drawn *before* a
    step can fail — breaking deterministic replay; caught by
    tests/test_train_ft.py::test_restart_resumes_identical_state.)
    Returns (state, log: list of dicts, restarts)."""
    data_fn = data.batch_at if hasattr(data, "batch_at") else data
    wd = Watchdog()
    log = []
    step = latest_step(ckpt_dir) or 0
    if step:
        state, step = restore_checkpoint(ckpt_dir, abstract_state or state,
                                         shardings=shardings)
    restarts = 0
    while step < steps:
        try:
            t0 = time.time()
            batch = data_fn(step)
            if injector:
                injector.maybe_fail(step)
            state, metrics = train_step(state, batch)
            dt = time.time() - t0
            slow = wd.record(step, dt)
            step += 1
            if step % log_every == 0 or slow:
                rec = {"step": step, "dt": round(dt, 4),
                       **{k: float(v) for k, v in metrics.items()}}
                if slow:
                    rec["straggler"] = True
                log.append(rec)
            if step % ckpt_every == 0:
                save_checkpoint(ckpt_dir, state, step, async_save=False)
        except Exception as e:  # worker failure -> restore and continue
            restarts += 1
            if restarts > max_restarts:
                raise
            last = latest_step(ckpt_dir)
            log.append({"step": step, "event": f"restart({e})",
                        "restored_to": last or 0})
            if last:
                state, step = restore_checkpoint(
                    ckpt_dir, abstract_state or state, shardings=shardings)
            else:
                step = 0
    return state, log, restarts
