"""Elastic scaling: rebuild a mesh from currently-available devices and
re-place (reshard) training state onto it.

Checkpoints are logical (checkpoint/ckpt.py), so scale-up/down =
restore under the new mesh's shardings; live resharding (no checkpoint)
is a device_put with the new NamedShardings."""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding


def best_mesh_shape(n_devices: int, model_parallel: int = 0):
    """Factor available devices into (data, model); prefers the largest
    model axis <= 16 that divides, unless pinned."""
    if model_parallel:
        assert n_devices % model_parallel == 0
        return (n_devices // model_parallel, model_parallel)
    for m in (16, 8, 4, 2, 1):
        if n_devices % m == 0:
            return (n_devices // m, m)
    return (n_devices, 1)


def make_elastic_mesh(model_parallel: int = 0):
    n = len(jax.devices())
    shape = best_mesh_shape(n, model_parallel)
    return jax.make_mesh(shape, ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def reshard(tree, pspec_tree, mesh):
    """Place `tree` onto `mesh` under logical PartitionSpecs (axes that
    don't divide are dropped by the caller's fit logic)."""
    from ..launch.dryrun import fit_pspec

    def place(x, sp):
        spec = fit_pspec(x.shape, tuple(sp), mesh)
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(place, tree, pspec_tree)
