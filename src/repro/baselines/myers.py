"""Edlib-style baseline: Myers' (1999) bit-parallel NW edit distance.

Multi-word (block) variant with explicit carry chains, batched over pairs
and jit-compiled — the algorithmic core of Edlib [Šošić & Šikić 2017].
Edlib additionally skips out-of-band blocks (Ukkonen banding); we report
that as a modeled factor (words_in_band / words_total) in the benchmark
rather than implementing the dynamic block window (see DESIGN.md §5).

Convention here is Myers' original: Peq bit i == 1 iff P[i] == c
(1-active, opposite of GenASM's).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

WORD = 32
_U1 = jnp.uint32(1)
_UF = jnp.uint32(0xFFFFFFFF)


def build_peq(pat_codes, nw: int, n_symbols: int = 4):
    """(B, n_symbols+1, NW); bit set where pattern char equals symbol.
    Padding rows (>= m_len) match nothing."""
    m_pad = nw * WORD
    pad = m_pad - pat_codes.shape[-1]
    if pad:
        pat_codes = jnp.pad(pat_codes, ((0, 0), (0, pad)), constant_values=255)
    sym = jnp.arange(n_symbols, dtype=pat_codes.dtype)
    eq = (pat_codes[:, None, :] == sym[None, :, None]).astype(jnp.uint32)
    eq = eq.reshape(eq.shape[0], n_symbols, nw, WORD)
    w = _U1 << jnp.arange(WORD, dtype=jnp.uint32)
    peq = jnp.sum(eq * w, axis=-1, dtype=jnp.uint32)
    zero = jnp.zeros((peq.shape[0], 1, nw), jnp.uint32)
    return jnp.concatenate([peq, zero], axis=1)


def _add_carry(a, b):
    """Multi-word addition a + b over the word axis (axis=-1, LSW first).
    Word count is small; the carry chain is unrolled."""
    nw = a.shape[-1]
    outs = []
    carry = jnp.zeros(a.shape[:-1], jnp.uint32)
    for w in range(nw):
        s1 = a[..., w] + b[..., w]
        c1 = (s1 < a[..., w]).astype(jnp.uint32)
        s2 = s1 + carry
        c2 = (s2 < s1).astype(jnp.uint32)
        outs.append(s2)
        carry = c1 | c2
    return jnp.stack(outs, axis=-1)


def _shift1(v, carry_in):
    hi = v >> jnp.uint32(WORD - 1)
    carry = jnp.concatenate(
        [jnp.broadcast_to(jnp.asarray(carry_in, jnp.uint32), v[..., :1].shape),
         hi[..., :-1]], axis=-1)
    return (v << _U1) | carry


@partial(jax.jit, static_argnames=("nw", "n"))
def myers_distance(pat_codes, text_codes, m_len, n_len, *, nw: int, n: int):
    """Global (NW) edit distance per pair.  pat_codes (B, <=32*nw) with 255
    padding; text_codes (B, n) with out-of-alphabet padding past n_len."""
    B = pat_codes.shape[0]
    peq = build_peq(pat_codes, nw)
    n_sym = peq.shape[1] - 1

    # mask of valid pattern bits; the score is tracked at bit m_len-1
    tgt_word = (m_len - 1) // WORD
    tgt_off = ((m_len - 1) % WORD).astype(jnp.uint32)

    VP = jnp.full((B, nw), 0xFFFFFFFF, jnp.uint32)
    VN = jnp.zeros((B, nw), jnp.uint32)
    score = jnp.asarray(m_len, jnp.int32)

    def step(carry, j):
        VP, VN, score = carry
        c = jnp.clip(text_codes[:, j].astype(jnp.int32), 0, n_sym)
        Eq = jnp.take_along_axis(peq, c[:, None, None], axis=1)[:, 0]
        Xv = Eq | VN
        Xh = (_add_carry(Eq & VP, VP) ^ VP) | Eq
        Ph = VN | ~(Xh | VP)
        Mh = VP & Xh
        # score update at the target bit (per-problem m_len)
        ph_t = (jnp.take_along_axis(Ph, tgt_word[:, None], axis=1)[:, 0]
                >> tgt_off) & _U1
        mh_t = (jnp.take_along_axis(Mh, tgt_word[:, None], axis=1)[:, 0]
                >> tgt_off) & _U1
        live = j < n_len
        score = score + jnp.where(live, ph_t.astype(jnp.int32)
                                  - mh_t.astype(jnp.int32), 0)
        # NW: first column is a gap column -> horizontal delta shift-in is +1
        Ph = _shift1(Ph, 1)
        Mh = _shift1(Mh, 0)
        VP_new = Mh | ~(Xv | Ph)
        VN_new = Ph & Xv
        keep = live[:, None]
        VP = jnp.where(keep, VP_new, VP)
        VN = jnp.where(keep, VN_new, VN)
        return (VP, VN, score), None

    (VP, VN, score), _ = jax.lax.scan(step, (VP, VN, score), jnp.arange(n))
    return score


def banded_traceback(p: np.ndarray, t: np.ndarray, k: int):
    """Host-side banded DP traceback used to recover the CIGAR once the
    bit-parallel distance is known (Edlib recomputes the path similarly).
    Returns (dist, ops front-first) or (None, None) if |ED| > k."""
    from ..core.oracle import OP_DEL, OP_INS, OP_MATCH, OP_SUBST
    m, n = len(p), len(t)
    bw = 2 * k + 1
    INF = 10 ** 9
    D = np.full((m + 1, bw), INF, np.int64)
    off0 = k  # column j maps to band slot j - i + k
    D[0, k:min(bw, k + n + 1)] = np.arange(min(n + 1, bw - k))
    for i in range(1, m + 1):
        lo = max(0, i - k)
        hi = min(n, i + k)
        for j in range(lo, hi + 1):
            s = j - i + k
            best = INF
            if j > 0 and 0 <= s <= bw - 1:
                dd = D[i - 1, s] + (p[i - 1] != t[j - 1])
                best = min(best, dd)
            if s + 1 <= bw - 1:
                best = min(best, D[i - 1, s + 1] + 1)  # I (consume read)
            if j > 0 and s - 1 >= 0:
                best = min(best, D[i, s - 1] + 1)      # D (consume ref)
            D[i, s] = best
    if n - m + k < 0 or n - m + k >= bw or D[m, n - m + k] > k:
        return None, None
    dist = int(D[m, n - m + k])
    ops = []
    i, j = m, n
    while i > 0 or j > 0:
        s = j - i + k
        d = D[i, s]
        if i > 0 and j > 0 and D[i - 1, s] + (p[i - 1] != t[j - 1]) == d:
            ops.append(OP_MATCH if p[i - 1] == t[j - 1] else OP_SUBST)
            i -= 1; j -= 1
        elif j > 0 and s - 1 >= 0 and D[i, s - 1] + 1 == d:
            ops.append(OP_DEL); j -= 1
        else:
            ops.append(OP_INS); i -= 1
    ops.reverse()
    return dist, np.array(ops, np.uint8)
