"""KSW2-style baseline: banded global alignment with affine gaps.

KSW2 [Suzuki & Kasahara 2018; Li 2018] computes banded affine-gap DP with
SIMD difference recurrences.  The JAX analogue vectorizes the band (width
2*bw+1) across lanes and batches pairs; the within-row horizontal gap chain
is resolved with a (min,+) prefix scan instead of KSW2's lazy-F loop.
Unit costs (sub=1, open=0, ext=1) reproduce edit distance for comparison
with the bitvector aligners; affine costs exercise the full recurrence.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

INF = jnp.int32(1 << 28)


@partial(jax.jit, static_argnames=("bw", "m", "sub", "gapo", "gape"))
def banded_affine_dist(pat_codes, text_codes, m_len, n_len, *, bw: int, m: int,
                       sub: int = 1, gapo: int = 0, gape: int = 1):
    """Banded global affine-gap cost per pair (B,).  Band slot s = j - i + bw.

    pat (B, m) padded with 255; text (B, n) padded out-of-alphabet.
    Returns INF-ish where the band was exceeded."""
    B, n = text_codes.shape
    W = 2 * bw + 1
    sl = jnp.arange(W, dtype=jnp.int32)

    # row 0: H[0][j] = gapo + gape*j (global, leading ref gap)
    j0 = sl - bw
    H0 = jnp.where(j0 >= 0, jnp.where(j0 > 0, gapo + gape * j0, 0), INF)
    H0 = jnp.broadcast_to(H0, (B, W)).astype(jnp.int32)
    E0 = jnp.full((B, W), INF, jnp.int32)  # vertical-gap state

    def row(carry, i):
        H_prev, E_prev = carry  # band-indexed at row i-1
        # j at slot s for row i: j = i + s - bw
        j_at = i + sl - bw                                    # (W,)
        pc = pat_codes[:, jnp.clip(i - 1, 0, m - 1)][:, None]  # (B,1)
        tc = jnp.take_along_axis(
            text_codes, jnp.clip(j_at - 1, 0, n - 1)[None, :].astype(jnp.int32)
            .repeat(B, 0), axis=1)
        mis = jnp.where(pc == tc, 0, sub).astype(jnp.int32)

        # diagonal: H[i-1][j-1] is slot s at row i-1 ; vertical: slot s+1
        diag = H_prev
        up_H = jnp.concatenate([H_prev[:, 1:], jnp.full((B, 1), INF)], axis=1)
        up_E = jnp.concatenate([E_prev[:, 1:], jnp.full((B, 1), INF)], axis=1)
        E = jnp.minimum(up_E + gape, up_H + gapo + gape)       # gap in read (I)
        Hd = jnp.where(j_at[None] - 1 >= 0, diag, INF) + mis
        Hd = jnp.where(j_at[None] >= 1, Hd, INF)
        H_noF = jnp.minimum(Hd, E)
        # boundary: j == 0 column (all-read gap) = gapo + gape * i
        H_noF = jnp.where(j_at[None] == 0, gapo + gape * i, H_noF)
        # horizontal chain F via (min,+) prefix scan along slots
        a = H_noF + gapo - sl[None] * gape
        run = jax.lax.associative_scan(jnp.minimum, a, axis=1)
        run = jnp.concatenate([jnp.full((B, 1), INF), run[:, :-1]], axis=1)
        F = run + sl[None] * gape
        H = jnp.minimum(H_noF, F)
        H = jnp.where(j_at[None] < 0, INF, H)
        H = jnp.where(j_at[None] > n_len[:, None], INF, H)
        live = (i <= m_len)[:, None]
        H = jnp.where(live, H, H_prev)
        E = jnp.where(live, E, E_prev)
        H = jnp.minimum(H, INF)
        return (H, E), None

    (H, _), _ = jax.lax.scan(row, (H0, E0), jnp.arange(1, m + 1))
    # answer at slot s = n_len - m_len + bw
    s_fin = jnp.clip(n_len - m_len + bw, 0, W - 1)
    out = jnp.take_along_axis(H, s_fin[:, None], axis=1)[:, 0]
    return jnp.where(jnp.abs(n_len - m_len) > bw, INF, out)


def affine_traceback(p: np.ndarray, t: np.ndarray, bw: int,
                     sub: int = 1, gapo: int = 0, gape: int = 1):
    """Host-side banded affine traceback (KSW2 keeps a direction matrix;
    costs here are tiny after banding).  Returns (cost, ops) or (None, None)."""
    from ..core.oracle import OP_DEL, OP_INS, OP_MATCH, OP_SUBST
    m, n = len(p), len(t)
    if abs(n - m) > bw:
        return None, None
    W = 2 * bw + 1
    INFN = 1 << 28
    H = np.full((m + 1, W), INFN, np.int64)
    for j in range(0, min(bw, n) + 1):
        H[0, j + bw] = (gapo + gape * j) if j else 0
    for i in range(1, m + 1):
        for j in range(max(0, i - bw), min(n, i + bw) + 1):
            s = j - i + bw
            best = INFN
            if j == 0:
                best = gapo + gape * i
            if j > 0:
                best = min(best, H[i - 1, s] + (sub if p[i - 1] != t[j - 1] else 0))
            if s + 1 < W:
                best = min(best, H[i - 1, s + 1] + gapo + gape)  # read gap
            if j > 0 and s - 1 >= 0:
                best = min(best, H[i, s - 1] + gapo + gape)      # ref gap
            H[i, s] = best
    cost = H[m, n - m + bw]
    if cost >= INFN:
        return None, None
    ops = []
    i, j = m, n
    while i > 0 or j > 0:
        s = j - i + bw
        c = H[i, s]
        if i > 0 and j > 0 and H[i - 1, s] + (sub if p[i-1] != t[j-1] else 0) == c:
            ops.append(OP_MATCH if p[i - 1] == t[j - 1] else OP_SUBST)
            i -= 1; j -= 1
        elif i > 0 and s + 1 < W and H[i - 1, s + 1] + gapo + gape == c:
            ops.append(OP_INS); i -= 1
        elif j > 0 and s - 1 >= 0 and H[i, s - 1] + gapo + gape == c:
            ops.append(OP_DEL); j -= 1
        elif j == 0 and gapo + gape * i == c:
            ops.append(OP_INS); i -= 1
        else:  # pragma: no cover
            raise AssertionError("traceback stuck")
    ops.reverse()
    return int(cost), np.array(ops, np.uint8)
