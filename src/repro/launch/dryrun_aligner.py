import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run + roofline for the paper's own workload: the batched GenASM
aligner sharded over the production mesh (data-parallel across pairs).

The aligner is integer (VPU) work, so the compute term uses an analytic
int-op model (cost_analysis only counts floating-point FLOPs):
  ops/window = levels * W * NW * OPS_PER_CELL lanes-ops   (DC fill)
with VPU_INT_THROUGHPUT ~ 1e12 op/s/chip (8x128 lanes @ ~1 GHz), an
estimate recorded as such in EXPERIMENTS.md.  Memory/collective terms come
from the compiled HLO as for the LM cells.

  PYTHONPATH=src python -m repro.launch.dryrun_aligner [--banded-compute]
"""
import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..analysis.hlo import collective_bytes
from ..analysis.roofline import HBM_BW, ICI_BW
from ..core.config import AlignerConfig
from ..core.windowing import n_main_windows
from ..serve.align_step import align_input_specs, align_step, make_align_step
from .mesh import make_production_mesh

VPU_INT_OPS = 1.0e12   # int32 lane-ops/s/chip (estimate, see module doc)
OPS_PER_CELL = 14      # shifts/ands/ors/selects per (level, column, word)


def aligner_cell(batch=131072, read_len=10_000, cfg=AlignerConfig(),
                 banded_compute=False, multi_pod=False):
    """Lower/compile the align step for `batch` 10kb pairs on the mesh."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 256
    specs = align_input_specs(batch, read_len, cfg)
    jfn = make_align_step(cfg, read_len, mesh)   # sharded in+out (see §Perf)
    with jax.set_mesh(mesh):
        t0 = time.time()
        lowered = jfn.lower(*specs)
        compiled = lowered.compile()
        wall = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    colls = collective_bytes(compiled.as_text())

    # analytic integer-compute model (per chip)
    n_win = n_main_windows(read_len, cfg) + 1
    avg_levels = 7.0 if cfg.early_term else cfg.k + 1
    nw_compute = cfg.nwb if banded_compute else cfg.nw
    ops = (batch / chips) * n_win * avg_levels * cfg.W * nw_compute \
        * OPS_PER_CELL
    compute_s = ops / VPU_INT_OPS
    # memory term: DENT band writes + text/PM reads dominate HBM traffic
    bytes_dev = float(ca.get("bytes accessed", 0.0) or 0.0)
    memory_s = bytes_dev / HBM_BW
    coll_s = colls["total_wire_bytes"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dom = max(terms, key=terms.get)
    return {
        "arch": "genasm-aligner", "shape": f"b{batch}_L{read_len}",
        "mesh": list(mesh.shape.values()),
        "banded_compute": banded_compute,
        "memory": {"argument_bytes": int(ma.argument_size_in_bytes),
                   "temp_bytes": int(ma.temp_size_in_bytes)},
        "collectives_schedule": colls,
        "roofline": {**terms, "dominant": dom.replace("_s", ""),
                     "int_ops_per_chip": ops,
                     "hlo_bytes_per_dev": bytes_dev,
                     "windows_per_pair": n_win, "avg_levels": avg_levels},
        "compile_s": round(wall, 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--banded-compute", action="store_true")
    ap.add_argument("--batch", type=int, default=131072)
    ap.add_argument("--read-len", type=int, default=10_000)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    for mp in (False, True):
        rec = aligner_cell(args.batch, args.read_len,
                           banded_compute=args.banded_compute, multi_pod=mp)
        tag = "mp" if mp else "sp"
        bc = "_banded" if args.banded_compute else ""
        (out / f"genasm-aligner__{tag}{bc}.json").write_text(
            json.dumps(rec, indent=1))
        r = rec["roofline"]
        print(f"[ok] aligner {tag}{bc}: compute={r['compute_s']:.3f}s "
              f"memory={r['memory_s']:.3f}s coll={r['collective_s']:.3f}s "
              f"dominant={r['dominant']} "
              f"temp={rec['memory']['temp_bytes']/2**30:.1f}GB")


if __name__ == "__main__":
    main()
