"""Training driver: elastic mesh, sharded state, supervised loop with
checkpoint/restart, synthetic data pipeline with prefetch.

CPU-scale e2e run (the default trains a ~10M-param model a few hundred
steps on this container; --arch picks any registry architecture, reduced
via --layers/--d-model overrides or --tiny):

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --tiny \
      --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from ..data.tokens import TokenStream
from ..models.registry import get_config, get_model, tiny_config
from ..optim.adamw import AdamWConfig
from ..runtime.elastic import make_elastic_mesh
from ..runtime.ft import FailureInjector, supervise
from ..train.step import (abstract_state, init_state, make_train_step,
                          state_partition_specs)


def build(args):
    cfg = get_config(args.arch)
    if args.tiny:
        cfg = tiny_config(cfg, n_layers=args.layers or 2)
    else:
        over = {}
        if args.layers:
            over["n_layers"] = args.layers
        if args.d_model:
            over["d_model"] = args.d_model
        if over:
            cfg = dataclasses.replace(cfg, **over)
    model = get_model(cfg)
    return cfg, model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=0)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg, model = build(args)
    mesh = make_elastic_mesh(args.model_parallel)
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name}  "
          f"params(abstract): "
          f"{sum(p.size for p in jax.tree_util.tree_leaves(model.abstract_params()))/1e6:.1f}M")

    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(10, args.steps // 20))
    step_fn = make_train_step(model, opt_cfg, grad_accum=args.grad_accum)

    from .dryrun import fit_pspec, tree_shardings
    a_state = abstract_state(model)
    st_sh = tree_shardings(a_state, state_partition_specs(model), mesh)
    with jax.set_mesh(mesh):
        jit_step = jax.jit(step_fn, in_shardings=(st_sh, None),
                           out_shardings=(st_sh, None), donate_argnums=0)
        state = init_state(model, jax.random.PRNGKey(args.seed))
        state = jax.device_put(state, st_sh)

        stream = TokenStream(cfg.vocab, args.batch, args.seq, args.seed,
                             family=cfg.family, d_model=cfg.d_model,
                             n_codebooks=cfg.n_codebooks)
        injector = (FailureInjector([args.inject_failure_at])
                    if args.inject_failure_at >= 0 else None)
        t0 = time.time()
        state, log, restarts = supervise(
            jit_step, state, stream, steps=args.steps,
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
            abstract_state=a_state, shardings=st_sh, injector=injector)
    wall = time.time() - t0
    toks = args.steps * args.batch * args.seq
    for rec in log[-5:]:
        print(json.dumps(rec))
    print(f"done: {args.steps} steps, {restarts} restarts, "
          f"{toks/wall:.0f} tok/s, final loss "
          f"{log[-1].get('loss', float('nan')):.4f}")
    return log


if __name__ == "__main__":
    main()
