import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, prove memory fits, and extract roofline
terms.  (The two lines above MUST precede any jax import: jax locks the
device count on first init.)

Protocol per cell (see DESIGN.md 'Dry-run roofline protocol'):
  1. full-depth compile (scan-over-layers) -> memory_analysis + collective
     schedule; run on the single-pod (16,16) AND multi-pod (2,16,16) mesh.
  2. two *unrolled* shallow compiles (L = unit, 2*unit) -> exact per-layer
     FLOPs/bytes/collective-bytes by linear extrapolation (scan bodies are
     cost-counted once regardless of trip count, verified; unrolling makes
     depth visible to cost_analysis).

Results cache to experiments/dryrun/<cell>.json (resumable); run cells in
subprocesses to bound memory:  python -m repro.launch.dryrun --arch all
"""
import argparse
import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..analysis.hlo import collective_bytes
from ..analysis.roofline import (ICI_BW, model_flops, roofline_terms,
                                 useful_fraction)
from ..models.registry import (ARCH_IDS, SHAPES, get_config, get_model,
                               input_specs, shape_applicable)
from ..optim.adamw import AdamWConfig
from ..train.step import abstract_state, make_train_step, state_partition_specs
from .mesh import make_production_mesh

OUT_DIR = pathlib.Path("experiments/dryrun")


def _axis_size(mesh, axes):
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        if a not in mesh.shape:
            return 0          # axis absent from this mesh -> can't shard
        n *= mesh.shape[a]
    return n


def fit_pspec(shape, spec, mesh):
    """Drop partition axes that don't divide the dimension (e.g. batch=1)."""
    out = []
    for i, dim in enumerate(shape):
        ax = spec[i] if i < len(spec) else None
        if ax is None:
            out.append(None)
            continue
        sz = _axis_size(mesh, ax)
        if sz and dim % sz == 0:
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


def _zero_over_pod(sp, mesh):
    """ZeRO the parameter/optimizer shards across pods too: the logical
    'data' axis in param specs widens to ('pod','data') on multi-pod meshes
    (§Perf iteration 7 — otherwise every pod replicates the fp32 state)."""
    if "pod" not in mesh.axis_names:
        return sp
    return tuple(("pod", "data") if a == "data" else a for a in sp)


def tree_shardings(sds_tree, spec_tree, mesh, zero_pod: bool = False):
    def one(s, sp):
        sp = tuple(sp)
        if zero_pod:
            sp = _zero_over_pod(sp, mesh)
        return NamedSharding(mesh, fit_pspec(s.shape, sp, mesh))
    return jax.tree_util.tree_map(one, sds_tree, spec_tree)


def batch_pspec(sds, mesh):
    """Shard the leading batch dim over (pod,)data; positions (3,B,S) on
    dim 1; scalars replicated."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    shape = sds.shape
    if len(shape) == 0:
        return P()
    if len(shape) == 3 and shape[0] == 3:   # M-RoPE positions
        return fit_pspec(shape, (None, dp, None), mesh)
    return fit_pspec(shape, (dp,) + (None,) * (len(shape) - 1), mesh)


def cache_pspecs(cache_sds, mesh):
    """KV caches shard batch over data and *sequence over model* (works for
    any kv-head count incl. GQA with few heads); SSM/conv/xlstm states shard
    batch and the largest inner dim where divisible."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

    def one(path, s):
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        shape = s.shape
        if "kv" in keys:           # (L/A, B, S, KV, Dh)
            return fit_pspec(shape, (None, dp, "model", None, None), mesh)
        if "ssm" in keys:          # (L, B, H, N, P)
            return fit_pspec(shape, (None, dp, "model", None, None), mesh)
        if "conv" in keys:         # (L, B, dconv-1, ch)
            return fit_pspec(shape, (None, dp, None, "model"), mesh)
        if "states" in keys:       # xlstm per-layer states, B leading
            return fit_pspec(shape, (dp,) + (None,) * (len(shape) - 1), mesh)
        return P()

    return jax.tree_util.tree_map_with_path(one, cache_sds)


def depth_unit(cfg):
    return max(cfg.local_global_every, cfg.shared_attn_every,
               cfg.slstm_every, 1)


def lower_cell(arch: str, shape: str, mesh, *, n_layers=None,
               scan_layers=True):
    """Build and lower the cell's step.  Returns (lowered, cfg, meta)."""
    cfg = get_config(arch)
    if n_layers is not None:
        cfg = dataclasses.replace(cfg, n_layers=n_layers)
    cfg = dataclasses.replace(cfg, scan_layers=scan_layers)
    model = get_model(cfg)
    S, GB, kind = SHAPES[shape]
    specs = input_specs(cfg, shape)
    batch_sds = specs["batch"]
    batch_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, batch_pspec(s, mesh)), batch_sds)

    with jax.set_mesh(mesh):
        if kind == "train":
            step = make_train_step(model, AdamWConfig())
            state_sds = abstract_state(model)
            st_sh = tree_shardings(state_sds, state_partition_specs(model),
                                   mesh, zero_pod=True)
            # donate the train state: params/opt buffers update in place
            fn = jax.jit(step, in_shardings=(st_sh, batch_sh),
                         out_shardings=(st_sh, None), donate_argnums=(0,))
            lowered = fn.lower(state_sds, batch_sds)
        elif kind == "prefill":
            # serve with bf16 weights (fp32 masters live in the trainer);
            # halves the per-token parameter-read bytes (§Perf iteration 6)
            p_sds = model.abstract_params(jnp.bfloat16)
            p_sh = tree_shardings(p_sds, model.partition_specs(), mesh,
                                  zero_pod=True)
            fn = jax.jit(lambda p, b: model.prefill(p, b),
                         in_shardings=(p_sh, batch_sh))
            lowered = fn.lower(p_sds, batch_sds)
        else:
            p_sds = model.abstract_params(jnp.bfloat16)
            p_sh = tree_shardings(p_sds, model.partition_specs(), mesh,
                                  zero_pod=True)
            cache_sds = specs["cache"]
            c_sh = jax.tree_util.tree_map(
                lambda s, sp: NamedSharding(mesh, sp), cache_sds,
                cache_pspecs(cache_sds, mesh))
            # donate the KV/SSM cache: decode appends in place (without
            # donation every step round-trips the full multi-GB cache)
            fn = jax.jit(lambda p, b, c: model.decode_step(p, b, c),
                         in_shardings=(p_sh, batch_sh, c_sh),
                         out_shardings=(None, c_sh), donate_argnums=(2,))
            lowered = fn.lower(p_sds, batch_sds, cache_sds)
    return lowered, cfg, {"seq": S, "batch": GB, "kind": kind}


def _cost(compiled):
    ca = compiled.cost_analysis()
    return {"flops": float(ca.get("flops", 0.0) or 0.0),
            "bytes": float(ca.get("bytes accessed", 0.0) or 0.0)}


def _active_params(model, cfg):
    from ..analysis.roofline import count_params
    sds = model.abstract_params()
    total = count_params(sds)
    if cfg.n_experts:
        moe_keys = sds["layers"].get("moe", {})
        expert_params = sum(int(v.size) for k, v in moe_keys.items()
                            if k != "router")
        total = total - int(expert_params * (1 - cfg.top_k / cfg.n_experts))
    return total


def run_cell(arch: str, shape: str, out_dir: pathlib.Path = OUT_DIR,
             skip_multipod: bool = False) -> dict:
    cfg0 = get_config(arch)
    if not shape_applicable(cfg0, shape):
        return {"arch": arch, "shape": shape, "skipped":
                "long_500k requires sub-quadratic mixing (DESIGN.md §4)"}
    rec = {"arch": arch, "shape": shape}
    S, GB, kind = SHAPES[shape]
    chips = 256

    # ---- 1. full-depth compiles: single-pod (+ multi-pod pass) ----
    for mp in ([False] if skip_multipod else [False, True]):
        mesh = make_production_mesh(multi_pod=mp)
        t0 = time.time()
        lowered, cfg, meta = lower_cell(arch, shape, mesh)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        ma = compiled.memory_analysis()
        mem = {"argument_bytes": int(ma.argument_size_in_bytes),
               "output_bytes": int(ma.output_size_in_bytes),
               "temp_bytes": int(ma.temp_size_in_bytes),
               "generated_code_bytes": int(ma.generated_code_size_in_bytes)}
        colls = collective_bytes(compiled.as_text())
        key = "multipod" if mp else "singlepod"
        rec[key] = {"mesh": list(mesh.shape.values()),
                    "lower_s": round(t1 - t0, 2),
                    "compile_s": round(t2 - t1, 2),
                    "memory": mem, "collectives_schedule": colls,
                    "cost_per_device": _cost(compiled)}
        del compiled, lowered

    # ---- 2. two-point unrolled cost compiles (single-pod) ----
    mesh = make_production_mesh(multi_pod=False)
    unit = depth_unit(cfg0)
    costs = {}
    for mult in (1, 2):
        L = unit * mult
        lowered, cfg, _ = lower_cell(arch, shape, mesh, n_layers=L,
                                     scan_layers=False)
        compiled = lowered.compile()
        costs[mult] = {**_cost(compiled),
                       "colls": collective_bytes(compiled.as_text())}
        del compiled, lowered

    Lf = cfg0.n_layers
    def extrap(f1, f2):
        per_unit = (f2 - f1)
        return f1 + per_unit * (Lf - unit) / unit

    flops_dev = extrap(costs[1]["flops"], costs[2]["flops"])
    bytes_dev = extrap(costs[1]["bytes"], costs[2]["bytes"])
    coll_dev = extrap(costs[1]["colls"]["total_wire_bytes"],
                      costs[2]["colls"]["total_wire_bytes"])
    flops_global = flops_dev * chips
    bytes_global = bytes_dev * chips

    model = get_model(cfg0)
    n_active = _active_params(model, cfg0)
    tokens = GB * S if kind == "train" else (GB * S if kind == "prefill" else GB)
    mfl = model_flops(n_active, tokens, kind == "train")
    terms = roofline_terms(flops_global, bytes_global, coll_dev, chips)
    rec["roofline"] = {
        **terms,
        "hlo_flops_global": flops_global,
        "hlo_bytes_global": bytes_global,
        "coll_wire_bytes_per_dev": coll_dev,
        "model_flops": mfl,
        "useful_fraction": useful_fraction(mfl, flops_global),
        "n_active_params": n_active,
        "depth_unit": unit,
        "cost_points": costs,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-multipod", action="store_true")
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    for arch in archs:
        for shape in shapes:
            cell = out / f"{arch}__{shape}.json"
            if cell.exists() and not args.force:
                print(f"[skip] {cell.name} (cached)")
                continue
            t0 = time.time()
            try:
                rec = run_cell(arch, shape, out,
                               skip_multipod=args.skip_multipod)
                rec["wall_s"] = round(time.time() - t0, 1)
            except Exception as e:  # record failures for triage
                import traceback
                rec = {"arch": arch, "shape": shape, "error": str(e),
                       "traceback": traceback.format_exc()}
            cell.write_text(json.dumps(rec, indent=1))
            status = ("SKIP" if "skipped" in rec else
                      "ERR " if "error" in rec else "ok  ")
            dom = rec.get("roofline", {}).get("dominant", "-")
            print(f"[{status}] {arch:22s} {shape:12s} {rec.get('wall_s','')}s"
                  f" dominant={dom}", flush=True)


if __name__ == "__main__":
    main()
