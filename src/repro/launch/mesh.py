"""Production mesh construction (assignment-specified geometry).

A FUNCTION, not a module constant: importing this module never touches jax
device state.  Single pod = (16, 16) chips = ('data','model'); multi-pod
adds a leading 'pod' axis (2 pods = 512 chips)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many (virtual) devices tests configured."""
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def batch_axes(mesh) -> tuple:
    """Axes the global batch shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
