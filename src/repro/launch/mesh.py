"""Production mesh construction (assignment-specified geometry).

A FUNCTION, not a module constant: importing this module never touches jax
device state.  Single pod = (16, 16) chips = ('data','model'); multi-pod
adds a leading 'pod' axis (2 pods = 512 chips)."""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    # jax.sharding.AxisType (explicit-sharding meshes) only exists in newer
    # jax; every mesh here is GSPMD-Auto, which is also the old default.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many (virtual) devices tests configured."""
    return _mesh(shape, axes)


def use_mesh(mesh):
    """Context manager activating `mesh`: jax.set_mesh on new jax; on older
    versions Mesh is itself a context manager with the same effect for
    shard_map/collective lowering (explicit shardings don't need it)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """jax.shard_map compat: the replication-check kwarg was renamed
    (check_rep -> check_vma) when shard_map left jax.experimental."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


def batch_axes(mesh) -> tuple:
    """Axes the global batch shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
