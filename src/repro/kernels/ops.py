"""jit'd wrappers for the Pallas kernels (layout marshalling + dispatch).

On this CPU container the kernels execute in interpret mode; on a real TPU
pass interpret=False (the BlockSpecs/VMEM scratch are TPU-shaped).  The
``backend`` knob in AlignerConfig selects jnp (core) vs pallas paths.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core.config import AlignerConfig
from ..core.genasm import build_pm_ext
from .genasm_dc import genasm_dc_pallas


@partial(jax.jit, static_argnames=("cfg", "tile", "interpret"))
def genasm_dc_op(pat_codes, text_codes, *, cfg: AlignerConfig, tile: int = 128,
                 interpret: bool = True):
    """Standard layout in, standard layout out.

    pat_codes/text_codes: (B, W).  Returns DCResult-like tuple
    (dist (B,), band (k+1, ncb, B, nwb), levels ()) — same as core.dc_dmajor
    store layout, so core.traceback consumes it unchanged.
    """
    B = pat_codes.shape[0]
    pad = (-B) % tile
    if pad:
        pat_codes = jnp.pad(pat_codes, ((0, pad), (0, 0)), constant_values=255)
        text_codes = jnp.pad(text_codes, ((0, pad), (0, 0)), constant_values=9)
    pm = build_pm_ext(pat_codes, cfg.nw)                  # (B', 5, NW)
    pm_k = jnp.transpose(pm, (1, 2, 0))                   # (5, NW, B')
    text_k = jnp.transpose(text_codes.astype(jnp.int32), (1, 0))
    dist, band, lvl = genasm_dc_pallas(pm_k, text_k, cfg=cfg, tile=tile,
                                       interpret=interpret)
    band = jnp.transpose(band, (0, 1, 3, 2))              # (K1, ncb, B', nwb)
    return dist[:B], band[:, :, :B, :], jnp.max(lvl)
