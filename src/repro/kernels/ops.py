"""jit'd wrappers for the Pallas kernels (layout marshalling + dispatch).

On this CPU container the kernels execute in interpret mode; on a real
accelerator pass interpret=False — cfg.backend picks the lowering the
kernel wrappers build ('pallas'/'pallas_fused' → Mosaic TPU with VMEM
scratch, 'pallas_gpu' → Triton with the store as a GMEM output block; see
kernels.genasm_dc and docs/backends.md).  ``default_interpret(cfg.backend)``
is the one place that decides interpret-vs-compiled from the platform.

Multi-device: every op takes an optional ``mesh``.  When given, the
pallas_call is wrapped in ``shard_map`` over the mesh's pair axes
(distributed.sharding.pair_axes), so each device runs the Pallas grid on
its local slice of the problem axis — the batch is padded to
``tile * n_pair_shards`` first so every shard holds whole kernel tiles.
Per-lane kernel results are independent of tile composition (padding
lanes solve at level 0, so only the per-tile ``levels`` statistic — the
analytic whole-tile-ET level count — sees them, and never as the max),
and the cross-lane ``levels`` reduction is taken OUTSIDE the shard_map on
the global array, so sharded dispatch is bit-identical to single-device
dispatch (asserted by tests/test_multidevice.py).

``cfg`` is a static jit argument, so knobs that pick a kernel body —
notably ``cfg.tail_store``, which selects the banded vs full-store tail
kernel — resolve at trace time and key separate executables.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.config import AlignerConfig
from ..core.genasm import build_pm_ext
from ..distributed.sharding import n_pair_shards, pair_axes
from ..launch.mesh import shard_map
from .genasm_dc import (META_DFIN, META_DIST, META_LVL, META_NOPS, META_OK,
                        META_RD, META_RF, genasm_dc_pallas,
                        genasm_tail_fused_pallas, genasm_tb_fused_pallas)


#: jax.default_backend() values that carry a CUDA/ROCm device — the
#: platforms where the Triton lowering compiles for real
GPU_PLATFORMS = ("gpu", "cuda", "rocm")


def default_interpret(backend: str | None = None) -> bool:
    """Interpret-mode Pallas everywhere the cfg.backend's real lowering
    target is absent: 'pallas_gpu' compiles only on a CUDA/ROCm device,
    the TPU backends only on a real TPU — CPU CI interprets both.  Called
    with cfg.backend by every dispatch site (core.windowing, core.genasm);
    the no-argument form keeps the historical TPU-only contract."""
    if backend == "pallas_gpu":
        return jax.default_backend() not in GPU_PLATFORMS
    return jax.default_backend() != "tpu"


def _pad_to_tile(pat_codes, text_codes, tile):
    """Pad the batch to a tile multiple with identical all-zero ('AAA...')
    lanes: they solve at level 0, so they never block the kernel's
    whole-tile early termination or inflate the levels stat (sentinel pads
    would sit at dist > k forever).  Padded lanes are trimmed after the
    kernel."""
    B = pat_codes.shape[0]
    pad = (-B) % tile
    if pad:
        pat_codes = jnp.pad(pat_codes, ((0, pad), (0, 0)))
        text_codes = jnp.pad(text_codes, ((0, pad), (0, 0)))
    return pat_codes, text_codes


def _to_kernel_layout(pat_codes, text_codes, cfg):
    pm = build_pm_ext(pat_codes, cfg.nw)                  # (B', 5, NW)
    pm_k = jnp.transpose(pm, (1, 2, 0))                   # (5, NW, B')
    text_k = jnp.transpose(text_codes.astype(jnp.int32), (1, 0))
    return pm_k, text_k


def _pad_unit(cfg, tile, mesh) -> tuple[int, int]:
    """(resolved lane tile, global batch pad unit): the batch pads to
    tile * n_shards so every mesh shard holds whole kernel tiles."""
    tile = tile or cfg.lane_tile
    return tile, tile * (n_pair_shards(mesh) if mesh is not None else 1)


def _shard_pairs(call, mesh, in_specs, out_specs):
    """Wrap a kernel-layout pallas dispatch in shard_map over the mesh's
    pair axes (problems are the INNERMOST axis of every kernel array, so
    the pair dim is the last entry of each spec).  Identity when there is
    nothing to shard over."""
    if mesh is None or n_pair_shards(mesh) == 1:
        return call
    return shard_map(call, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check=False)


def _pair_specs(mesh, ranks_in, ranks_out):
    """P specs placing the pair axes on the last dim of each operand."""
    ax = pair_axes(mesh) if mesh is not None else ()
    mk = lambda r: P(*([None] * (r - 1) + [ax]))
    return tuple(mk(r) for r in ranks_in), tuple(mk(r) for r in ranks_out)


@partial(jax.jit, static_argnames=("cfg", "tile", "interpret", "mesh"))
def genasm_dc_op(pat_codes, text_codes, *, cfg: AlignerConfig,
                 tile: int | None = None, interpret: bool = True, mesh=None):
    """Standard layout in, standard layout out.

    pat_codes/text_codes: (B, W).  Returns DCResult-like tuple
    (dist (B,), band (k+1, ncb, B, nwb), levels ()) — same as core.dc_dmajor
    store layout, so core.traceback consumes it unchanged.
    """
    B = pat_codes.shape[0]
    tile, unit = _pad_unit(cfg, tile, mesh)
    pat_codes, text_codes = _pad_to_tile(pat_codes, text_codes, unit)
    pm_k, text_k = _to_kernel_layout(pat_codes, text_codes, cfg)
    call = partial(genasm_dc_pallas, cfg=cfg, tile=tile, interpret=interpret)
    in_sp, out_sp = _pair_specs(mesh, (3, 2), (1, 4, 1))
    dist, band, lvl = _shard_pairs(call, mesh, in_sp, out_sp)(pm_k, text_k)
    band = jnp.transpose(band, (0, 1, 3, 2))              # (K1, ncb, B', nwb)
    return dist[:B], band[:, :, :B, :], jnp.max(lvl)


@partial(jax.jit, static_argnames=("cfg", "commit_limit", "max_ops",
                                   "max_steps", "tile", "interpret", "mesh"))
def genasm_tb_fused_op(pat_codes, text_codes, *, cfg: AlignerConfig,
                       commit_limit: int, max_ops: int, max_steps: int,
                       tile: int | None = None, interpret: bool = True,
                       mesh=None):
    """Fused GenASM-DC+TB: standard layout in, traceback dict out.

    pat_codes/text_codes: (B, W) reversed square windows (the windowed
    pipeline's main-window contract).  Returns the same dict as
    core.traceback (ops front-first uint8, n_ops, read_adv, ref_adv, cost,
    ok, d_final) plus dist and levels — the DENT band never leaves the
    kernel's VMEM scratch.
    """
    B = pat_codes.shape[0]
    tile, unit = _pad_unit(cfg, tile, mesh)
    pat_codes, text_codes = _pad_to_tile(pat_codes, text_codes, unit)
    pm_k, text_k = _to_kernel_layout(pat_codes, text_codes, cfg)
    call = partial(genasm_tb_fused_pallas, cfg=cfg, commit_limit=commit_limit,
                   max_ops=max_ops, max_steps=max_steps, tile=tile,
                   interpret=interpret)
    in_sp, out_sp = _pair_specs(mesh, (3, 2), (2, 2))
    ops_k, meta = _shard_pairs(call, mesh, in_sp, out_sp)(pm_k, text_k)
    ops = jnp.transpose(ops_k, (1, 0))[:B].astype(jnp.uint8)   # (B, max_ops)
    meta = meta[:, :B]
    return _unpack_meta(ops, meta, cfg)


def _unpack_meta(ops, meta, cfg):
    dist = meta[META_DIST]
    skip = dist > cfg.k
    return {
        "ops": ops,
        "n_ops": meta[META_NOPS],
        "read_adv": meta[META_RD],
        "ref_adv": meta[META_RF],
        "cost": jnp.where(skip, 0, dist - meta[META_DFIN]),
        "ok": meta[META_OK].astype(bool),
        "d_final": meta[META_DFIN],
        "dist": dist,
        "solved": ~skip,
        "levels": jnp.max(meta[META_LVL]),
    }


@partial(jax.jit, static_argnames=("cfg", "n_text", "commit_limit", "max_ops",
                                   "max_steps", "tile", "interpret", "mesh"))
def genasm_tail_fused_op(pat_codes, text_codes, m_len, n_len, *,
                         cfg: AlignerConfig, n_text: int, commit_limit: int,
                         max_ops: int, max_steps: int, tile: int | None = None,
                         interpret: bool = True, mesh=None):
    """Fused rectangular-tail GenASM-DC+TB: standard layout in, traceback
    dict out (same contract as the jnp dc_jmajor + traceback mode='and'
    tail path of core.windowing, bit for bit).

    pat_codes: (B, <= m_pad) reversed tail patterns (sentinel-padded past
    m_len); text_codes: (B, n_text) reversed tail texts (sentinel-padded
    past n_len).  Batch-padding lanes are trivial 'A' vs 'A' one-char
    problems (m_len = n_len = 1): they solve at level 0, so they never
    stall the kernel's (analytic or looped) whole-tile early termination,
    and are trimmed.

    The SENE store stays in VMEM scratch either way; cfg.tail_banded picks
    the Scrooge-style banded store vs the full-table fallback at trace
    time — bit-identical outputs, ~2x less scratch when banded."""
    B = pat_codes.shape[0]
    tile, unit = _pad_unit(cfg, tile, mesh)
    pat_codes, text_codes = _pad_to_tile(pat_codes, text_codes, unit)
    pad = (-B) % unit
    m_len = jnp.asarray(m_len, jnp.int32)
    n_len = jnp.asarray(n_len, jnp.int32)
    if pad:
        m_len = jnp.pad(m_len, ((0, pad),), constant_values=1)
        n_len = jnp.pad(n_len, ((0, pad),), constant_values=1)
    pm_k, text_k = _to_kernel_layout(pat_codes, text_codes, cfg)
    call = partial(genasm_tail_fused_pallas, cfg=cfg, n_text=n_text,
                   commit_limit=commit_limit, max_ops=max_ops,
                   max_steps=max_steps, tile=tile, interpret=interpret)
    in_sp, out_sp = _pair_specs(mesh, (3, 2, 2, 2), (2, 2))
    ops_k, meta = _shard_pairs(call, mesh, in_sp, out_sp)(
        pm_k, text_k, m_len[None, :], n_len[None, :])
    ops = jnp.transpose(ops_k, (1, 0))[:B].astype(jnp.uint8)   # (B, max_ops)
    return _unpack_meta(ops, meta[:, :B], cfg)
