# Pallas kernels for the paper's hot spot: genasm_dc.py holds the
# improved GenASM-DC kernel and the fused GenASM-DC+TB kernel (band never
# leaves VMEM); ops.py has the jit'd standard-layout wrappers; ref.py the
# pure-jnp oracle.  Backend selection: AlignerConfig.backend, see
# docs/backends.md.
