"""Pallas TPU kernels: improved GenASM-DC (SENE + DENT + ET) and the fused
GenASM-DC+TB pipeline that never ships the DP state off-chip.

TPU mapping (see DESIGN.md §2): one VPU *lane* per alignment problem — the
innermost axis of every array is the problem tile (TB, a multiple of 128).
Bitvector words live in small leading axes and are unrolled; all DP state
is VMEM scratch, which is the paper's point: after the three improvements
the entire traceback table fits on-chip (`vmem_bytes` below).

Grid: one program per problem tile.  Per tile:
  * level-0 row filled with a fori_loop over the W text columns,
  * levels 1..k under a while_loop with whole-tile early termination,
  * per column, the DENT band window (funnel-shift extracted, sub-word) is
    stored for the traceback-reachable columns only.

Two kernels share that DC phase (`_dc_phase`):

  * `genasm_dc_pallas` (split) — writes the DENT band to an HBM output so
    the host-side jnp traceback (core.traceback, mode='band') can walk it.
    Band traffic per tile: (k+1) * ncols_band * nwb * TB * 4 bytes each way.
  * `genasm_tb_fused_pallas` (fused) — keeps the band in VMEM scratch and
    walks GenASM-TB *inside* the kernel: the same funnel-shift band-window
    reads as `store_band`, inverted, now per-lane dynamic (each problem is
    at its own (i, j, d) DP cell, so window/column/PM lookups become
    one-hot gathers over the small static axes, vectorized across lanes).
    Only the per-problem op array (<= max_ops int32) and a meta row leave
    the chip — the band never round-trips through HBM, which is the
    bandwidth win the paper's 24x working-set compression pays for.

The traceback walk is bit-identical to core.traceback mode='band' (same
=,X,D,I preference, same commit-limit semantics); tests assert ops/dist
equality against the jnp path.

The pure-jnp oracle is kernels/ref.py (which defers to core.genasm); the
jit'd wrapper with layout marshalling is kernels/ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.config import AlignerConfig
from ..core.oracle import OP_DEL, OP_INS, OP_MATCH, OP_SUBST
from ..core.traceback import OP_NONE

WORD = 32

# meta_ref row layout of the fused kernel (8 rows for sublane alignment)
META_DIST, META_LVL, META_NOPS, META_RD, META_RF, META_DFIN, META_OK = range(7)
META_ROWS = 8


def _band_base(j, k, m_pad, nwb):
    lo = j - 2 - k
    hi = m_pad - WORD * nwb
    return jnp.clip(lo, 0, hi)


def default_max_ops(cfg: AlignerConfig) -> int:
    """Op budget of one committed window walk (= core.windowing's)."""
    return cfg.tb_max_ops


def default_max_steps(cfg: AlignerConfig) -> int:
    return cfg.tb_max_steps


def vmem_bytes(cfg: AlignerConfig, tile: int, fused: bool = False,
               max_ops: int | None = None) -> int:
    """On-chip working set per problem tile (the paper's 'fits in on-chip
    memory' claim, checked against ~16MB VMEM in tests).

    The split kernel's band is an output block, but it still occupies VMEM
    while the tile is in flight, so it is counted either way.  The fused
    kernel adds the traceback state: the op output block (max_ops words)
    plus ~16 per-lane state vectors; its band is pure scratch and never
    becomes HBM traffic.
    """
    rows = 2 * (cfg.W + 1) * cfg.nw * tile * 4
    band = (cfg.k + 1) * cfg.ncols_band * cfg.nwb * tile * 4
    io = (5 * cfg.nw + cfg.W + 2) * tile * 4
    total = rows + band + io
    if fused:
        mo = default_max_ops(cfg) if max_ops is None else max_ops
        total += (mo + META_ROWS + 16) * tile * 4
    return total


def _pm_lookup(pm_ref, cj, nw, n_sym=4):
    """cj: (TB,) int32 -> list of nw (TB,) mask words (sentinel -> all ones)."""
    out = []
    for w in range(nw):
        acc = jnp.full(cj.shape, 0xFFFFFFFF, jnp.uint32)
        for c in range(n_sym):
            acc = jnp.where(cj == c, pm_ref[c, w, :], acc)
        out.append(acc)
    return out


def _shift1_words(words, carry_in, nw):
    """Left-shift a word-list bitvector (LSW first) by one; carry_in at bit 0.
    words: list of nw (TB,) uint32."""
    out, carry = [], carry_in
    for w in range(nw):
        out.append((words[w] << jnp.uint32(1)) | carry)
        carry = words[w] >> jnp.uint32(WORD - 1)
    return out


def _ones_below_words(d, nw, lane_shape):
    """(nw-word, lanes) GenASM level-d init vector ~0 << d for traced d."""
    out = []
    for w in range(nw):
        lo = jnp.clip(d - w * WORD, 0, WORD)
        val = jnp.where(lo >= WORD, jnp.uint32(0),
                        jnp.uint32(0xFFFFFFFF) << lo.astype(jnp.uint32))
        out.append(jnp.broadcast_to(val, lane_shape))
    return out


def _word_select(words, w0):
    """Per-lane dynamic word pick from a word list; w0: (TB,) int32."""
    word = words[0]
    for w in range(1, len(words)):
        word = jnp.where(w0 == w, words[w], word)
    return word


def _dc_phase(pm_ref, text_ref, rows_ref, band_ref, *, cfg: AlignerConfig):
    """Fill the improved GenASM-DC levels, storing DENT band windows into
    band_ref (output block or VMEM scratch).  Returns (dist, d_end)."""
    W, k, nw, nwb = cfg.W, cfg.k, cfg.nw, cfg.nwb
    m_pad = cfg.m_pad
    ncb = cfg.ncols_band
    col0 = W + 1 - ncb
    tgt_w, tgt_o = (W - 1) // WORD, jnp.uint32((W - 1) % WORD)

    def shift1_words(words, carry_in):
        return _shift1_words(words, carry_in, nw)

    def ones_below(d):
        return _ones_below_words(d, nw, text_ref.shape[1:])

    def store_band(d, j, words):
        """Funnel-shift extract the band window of column j and store it."""
        base = _band_base(j, k, m_pad, nwb)
        w0 = base // WORD
        s = (base % WORD).astype(jnp.uint32)
        for b in range(nwb):
            lo = words[0]
            hi = words[0]
            for w in range(nw):          # dynamic word select, unrolled
                lo = jnp.where(w0 + b == w, words[w], lo)
                hi = jnp.where(w0 + b + 1 == w, words[w],
                               jnp.where(w0 + b + 1 >= nw, jnp.uint32(0xFFFFFFFF),
                                         hi))
            win = jnp.where(s == 0, lo, (lo >> s) | (hi << (jnp.uint32(WORD) - s)))
            @pl.when(j >= col0)
            def _():
                band_ref[d, j - col0, b, :] = win

    def row_get(parity, j):
        return [rows_ref[parity, j, w, :] for w in range(nw)]

    def row_set(parity, j, words):
        for w in range(nw):
            rows_ref[parity, j, w, :] = words[w]

    # ---------------- level 0 ----------------
    r0 = ones_below(jnp.int32(0))
    row_set(0, 0, r0)
    store_band(0, 0, r0)

    def col_body0(j, _):
        prev = row_get(0, j - 1)
        cj = text_ref[j - 1, :].astype(jnp.int32)
        pm_j = _pm_lookup(pm_ref, cj, nw)
        bM = ((j - 1) > 0).astype(jnp.uint32)
        r = [a | b for a, b in zip(shift1_words(prev, bM), pm_j)]
        row_set(0, j, r)
        store_band(0, j, r)
        return 0

    jax.lax.fori_loop(1, W + 1, col_body0, 0)
    last0 = row_get(0, W)
    hit0 = ((last0[tgt_w] >> tgt_o) & jnp.uint32(1)) == 0
    dist0 = jnp.where(hit0, 0, k + 1).astype(jnp.int32)

    # ---------------- levels 1..k with early termination ----------------
    def fill_level(d):
        parity, prev_par = d % 2, (d - 1) % 2
        rinit = ones_below(d)
        row_set(parity, 0, rinit)
        store_band(d, 0, rinit)

        def col_body(j, _):
            r_prev = row_get(parity, j - 1)        # R_{j-1}[d]
            p_jm1 = row_get(prev_par, j - 1)       # R_{j-1}[d-1]
            p_j = row_get(prev_par, j)             # R_j[d-1]
            cj = text_ref[j - 1, :].astype(jnp.int32)
            pm_j = _pm_lookup(pm_ref, cj, nw)
            t = j - 1
            bM = (t > d).astype(jnp.uint32)
            bS = (t >= d).astype(jnp.uint32)
            bI = (t >= d - 1).astype(jnp.uint32)
            M = [a | b for a, b in zip(shift1_words(r_prev, bM), pm_j)]
            S = shift1_words(p_jm1, bS)
            I = shift1_words(p_j, bI)
            r = [M[w] & S[w] & p_jm1[w] & I[w] for w in range(nw)]
            row_set(parity, j, r)
            store_band(d, j, r)
            return 0

        jax.lax.fori_loop(1, W + 1, col_body, 0)
        last = row_get(parity, W)
        return ((last[tgt_w] >> tgt_o) & jnp.uint32(1)) == 0

    # NOTE: `dist` rides in the while carry (a cond reading a mutated VMEM
    # ref would observe it one iteration late).
    def lvl_cond(state):
        d, dist = state
        go = d <= k
        if cfg.early_term:
            go &= jnp.any(dist > k)
        return go

    def lvl_body(state):
        d, dist = state
        hit = fill_level(d)
        dist = jnp.where((dist > k) & hit, d, dist).astype(jnp.int32)
        return d + 1, dist

    d_end, dist = jax.lax.while_loop(lvl_cond, lvl_body, (jnp.int32(1), dist0))
    return dist, d_end


def _kernel(pm_ref, text_ref, band_ref, dist_ref, lvl_ref, rows_ref, *,
            cfg: AlignerConfig):
    dist, d_end = _dc_phase(pm_ref, text_ref, rows_ref, band_ref, cfg=cfg)
    dist_ref[0, :] = dist
    lvl_ref[0, :] = jnp.broadcast_to(d_end, lvl_ref.shape[1:]).astype(jnp.int32)


def _tb_walk(*, TB, dist, k, init_i, init_j, commit_limit, max_ops, max_steps,
             avail_words, zbit, peq_at, text_at):
    """Shared in-kernel GenASM-TB walk, bit-identical to core.traceback:
    per-lane (i, j, d) cursors advanced with the =,X,D,I preference order, a
    tail drain (pattern exhausted -> remaining text as deletions), and the
    commit-limit stop.  ``avail_words(dd, jj)`` gathers the stored bitvector
    words of (level dd, column jj); ``zbit(words, dd, jj, ii)`` tests bit ii.

    Returns the final (i, j, d, nops, ops, rd, rf, done, ok) state."""
    slot_ids = jax.lax.broadcasted_iota(jnp.int32, (max_ops, TB), 0)

    def body(state):
        i, j, d, nops, ops, rd, rf, done, ok = state
        tail = i < 0
        stopped = rd >= commit_limit
        active = ~done & ~stopped

        w_d_jm1 = avail_words(d, j - 1)
        w_dm1_jm1 = avail_words(d - 1, j - 1)
        w_dm1_j = avail_words(d - 1, j)
        peq = peq_at(text_at(j), i)
        mA = (j > 0) & peq & zbit(w_d_jm1, d, j - 1, i - 1)
        sA = (j > 0) & (d > 0) & zbit(w_dm1_jm1, d - 1, j - 1, i - 1)
        dA = (j > 0) & (d > 0) & zbit(w_dm1_jm1, d - 1, j - 1, i)
        iA = (d > 0) & zbit(w_dm1_j, d - 1, j, i - 1)

        # tail: pattern exhausted, drain remaining text as deletions
        tail_emit = tail & (j > 0)
        mA &= ~tail; sA &= ~tail; dA &= ~tail; iA &= ~tail

        any_edge = mA | sA | dA | iA | tail_emit
        # exclusive choice with GenASM's =,X,D,I preference
        cM = mA
        cS = ~mA & sA
        cD = ~mA & ~sA & dA
        cI = ~mA & ~sA & ~dA & iA
        op = jnp.where(cM, OP_MATCH,
             jnp.where(cS, OP_SUBST,
             jnp.where(cD, OP_DEL,
             jnp.where(cI, OP_INS, OP_DEL)))).astype(jnp.int32)

        takes_read = active & (cM | cS | cI)
        takes_ref = active & (cM | cS | cD | tail_emit)
        costs = active & (cS | cD | cI | tail_emit)

        new_i = jnp.where(takes_read, i - 1, i)
        new_j = jnp.where(takes_ref, j - 1, j)
        new_d = jnp.where(costs, d - 1, d)
        new_rd = rd + takes_read
        new_rf = rf + takes_ref

        emit = active & any_edge
        slot = jnp.where(emit, nops, max_ops)   # max_ops -> no iota row: drop
        ops = jnp.where(slot_ids == slot[None, :], op[None, :], ops)
        nops = nops + emit

        finished = (new_i < 0) & (new_j <= 0)
        new_done = done | (active & finished)
        # invariant: an active, unfinished cell always has an available edge
        ok &= jnp.where(active & ~finished, any_edge | ((i < 0) & (j <= 0)), True)
        return (new_i, new_j, new_d, nops, ops, new_rd, new_rf,
                new_done | stopped, ok)

    def walk_body(step, state):
        del step
        return jax.lax.cond(jnp.any(~state[7]), body, lambda s: s, state)

    zeros = jnp.zeros((TB,), jnp.int32)
    skip = dist > k
    init = (
        init_i,                                     # i (m_len - 1)
        init_j,                                     # j (n_len)
        dist,                                       # d
        zeros,                                      # nops
        jnp.full((max_ops, TB), OP_NONE, jnp.int32),
        zeros,                                      # read_adv
        zeros,                                      # ref_adv
        skip,                                       # done
        jnp.ones((TB,), bool),                      # ok
    )
    return jax.lax.fori_loop(0, max_steps, walk_body, init)


def _kernel_fused(pm_ref, text_ref, ops_ref, meta_ref, rows_ref, band_ref, *,
                  cfg: AlignerConfig, commit_limit: int, max_ops: int,
                  max_steps: int):
    """DC phase into VMEM scratch, then GenASM-TB walked in-kernel.

    The walk mirrors core.traceback (mode='band') bit for bit: SENE edge
    availability is recomputed from neighbouring stored band windows + the
    PM masks, with the =,X,D,I preference order, a per-lane tail drain, and
    the commit-limit stop.  Per-lane dynamic (d, j) band reads use one-hot
    sums over the small static (k+1, ncols_band) axes — the inverted form
    of store_band's funnel-shift stores.
    """
    W, k, nw, nwb = cfg.W, cfg.k, cfg.nw, cfg.nwb
    m_pad = cfg.m_pad
    ncb = cfg.ncols_band
    col0 = W + 1 - ncb
    TB = text_ref.shape[1]
    u1 = jnp.uint32(1)

    # uncomputed (early-terminated) levels must read as zero, like the jnp
    # path's zeros-initialized band buffer
    band_ref[:, :, :, :] = jnp.zeros((k + 1, ncb, nwb, TB), jnp.uint32)

    dist, d_end = _dc_phase(pm_ref, text_ref, rows_ref, band_ref, cfg=cfg)

    # ---------------- traceback phase ----------------
    d_ids = jax.lax.broadcasted_iota(jnp.int32, (k + 1, ncb, TB), 0)
    s_ids = jax.lax.broadcasted_iota(jnp.int32, (k + 1, ncb, TB), 1)
    t_ids = jax.lax.broadcasted_iota(jnp.int32, (W, TB), 0)

    def band_words(dd, jj):
        """Per-lane gather of the stored band window of (level dd, col jj),
        clipped like core.traceback._zbit_band."""
        onehot = ((d_ids == jnp.clip(dd, 0, k)[None, None, :]) &
                  (s_ids == jnp.clip(jj - col0, 0, ncb - 1)[None, None, :]))
        return [jnp.sum(jnp.where(onehot, band_ref[:, :, b, :], jnp.uint32(0)),
                        axis=(0, 1), dtype=jnp.uint32) for b in range(nwb)]

    def zbit(words, dd, jj, ii):
        """bit ii of the band window == 0; ii == -1 encodes the DP's first
        column: ED(0, jj) <= dd  ⟺  jj <= dd."""
        base = _band_base(jj, k, m_pad, nwb)
        off = ii - base
        inband = (off >= 0) & (off < nwb * WORD)
        offc = jnp.clip(off, 0, nwb * WORD - 1)
        o = (offc % WORD).astype(jnp.uint32)
        bit = (_word_select(words, offc // WORD) >> o) & u1
        return jnp.where(ii < 0, jj <= dd, (bit == 0) & inband)

    def text_at(jj):
        """text char of column jj (= text index jj-1, clipped)."""
        onehot = t_ids == jnp.clip(jj - 1, 0, W - 1)[None, :]
        return jnp.sum(jnp.where(onehot, text_ref[:, :], 0),
                       axis=0).astype(jnp.int32)

    def peq_at(cj, ii):
        """P[ii] == text char cj, via the PM masks (sentinels never match)."""
        words = _pm_lookup(pm_ref, cj, nw)
        iic = jnp.clip(ii, 0, m_pad - 1)
        o = (iic % WORD).astype(jnp.uint32)
        return ((_word_select(words, iic // WORD) >> o) & u1) == 0

    i, j, d, nops, ops, rd, rf, done, ok = _tb_walk(
        TB=TB, dist=dist, k=k,
        init_i=jnp.full((TB,), W - 1, jnp.int32),
        init_j=jnp.full((TB,), W, jnp.int32),
        commit_limit=commit_limit, max_ops=max_ops, max_steps=max_steps,
        avail_words=band_words, zbit=zbit, peq_at=peq_at, text_at=text_at)

    ops_ref[:, :] = ops
    meta_ref[META_DIST, :] = dist
    meta_ref[META_LVL, :] = jnp.broadcast_to(d_end, (TB,)).astype(jnp.int32)
    meta_ref[META_NOPS, :] = nops
    meta_ref[META_RD, :] = rd
    meta_ref[META_RF, :] = rf
    meta_ref[META_DFIN, :] = d
    meta_ref[META_OK, :] = ok.astype(jnp.int32)
    meta_ref[META_ROWS - 1, :] = jnp.zeros((TB,), jnp.int32)


def genasm_dc_pallas(pm, text, *, cfg: AlignerConfig, tile: int = 128,
                     interpret: bool = True):
    """pm: (5, NW, B) uint32; text: (W, B) int32 (kernel layout, problems
    innermost).  Returns (dist (B,), band (k+1, ncb, nwb, B), levels (B,))."""
    _, nw, B = pm.shape
    W = text.shape[0]
    assert W == cfg.W and nw == cfg.nw and B % tile == 0
    ncb, nwb, k = cfg.ncols_band, cfg.nwb, cfg.k
    grid = (B // tile,)
    kern = functools.partial(_kernel, cfg=cfg)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((5, nw, tile), lambda i: (0, 0, i)),
            pl.BlockSpec((W, tile), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((k + 1, ncb, nwb, tile), lambda i: (0, 0, 0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k + 1, ncb, nwb, B), jnp.uint32),
            jax.ShapeDtypeStruct((1, B), jnp.int32),
            jax.ShapeDtypeStruct((1, B), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, W + 1, nw, tile), jnp.uint32),
        ],
        interpret=interpret,
    )(pm, text)
    band, dist, lvl = out
    return dist[0], band, lvl[0]


def genasm_tb_fused_pallas(pm, text, *, cfg: AlignerConfig, commit_limit: int,
                           max_ops: int | None = None,
                           max_steps: int | None = None, tile: int = 128,
                           interpret: bool = True):
    """Fused DC+TB.  pm: (5, NW, B) uint32; text: (W, B) int32 (kernel
    layout).  Returns (ops (max_ops, B) int32 front-first with OP_NONE
    padding, meta (META_ROWS, B) int32 — see META_* row constants).  The
    DENT band lives and dies in VMEM scratch."""
    _, nw, B = pm.shape
    W = text.shape[0]
    assert W == cfg.W and nw == cfg.nw and B % tile == 0
    if max_ops is None:
        max_ops = default_max_ops(cfg)
    if max_steps is None:
        max_steps = default_max_steps(cfg)
    ncb, nwb, k = cfg.ncols_band, cfg.nwb, cfg.k
    grid = (B // tile,)
    kern = functools.partial(_kernel_fused, cfg=cfg, commit_limit=commit_limit,
                             max_ops=max_ops, max_steps=max_steps)
    ops, meta = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((5, nw, tile), lambda i: (0, 0, i)),
            pl.BlockSpec((W, tile), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((max_ops, tile), lambda i: (0, i)),
            pl.BlockSpec((META_ROWS, tile), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((max_ops, B), jnp.int32),
            jax.ShapeDtypeStruct((META_ROWS, B), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, W + 1, nw, tile), jnp.uint32),
            pltpu.VMEM((k + 1, ncb, nwb, tile), jnp.uint32),
        ],
        interpret=interpret,
    )(pm, text)
    return ops, meta


def vmem_bytes_tail(cfg: AlignerConfig, tile: int,
                    max_ops: int | None = None) -> int:
    """On-chip working set of the rectangular-tail fused kernel per problem
    tile: the full (k+1, wt+1, NW) SENE store (no provable DENT band exists
    for per-lane rectangular geometry) plus IO blocks and traceback state."""
    wt = cfg.W + 4 * cfg.k
    store = (cfg.k + 1) * (wt + 1) * cfg.nw * tile * 4
    io = (5 * cfg.nw + wt + 4) * tile * 4
    mo = (cfg.W + wt) if max_ops is None else max_ops
    return store + io + (mo + META_ROWS + 16) * tile * 4


def _kernel_tail_fused(pm_ref, text_ref, mlen_ref, nlen_ref, ops_ref, meta_ref,
                       rfull_ref, *, cfg: AlignerConfig, n_text: int,
                       commit_limit: int, max_ops: int, max_steps: int):
    """Rectangular-tail fused DC+TB (the whole-read tail window on-chip).

    Unlike the square main-window kernel the tail is rectangular and ragged:
    per-lane m_len <= W pattern chars against n_len <= n_text text chars.
    No provable DENT band exists for that geometry, so the DP stores the
    full SENE ('and') vectors for every (level, column) in VMEM scratch and
    the traceback walks them in-kernel — the exact analogue of
    core.windowing's jnp 'and'-store tail path, bit for bit, with neither
    the store nor the walk ever leaving the chip.

    Mirrors dc_jmajor semantics: columns beyond a lane's n_len are frozen
    copies of their left neighbour (hence of column n_len), dist reads the
    per-lane bit (m_len - 1) of the final column, and the level loop runs
    whole-tile early termination — the traceback never visits a level above
    its lane's dist, so ET cannot change results vs the ET-free jnp fill.
    """
    W, k, nw = cfg.W, cfg.k, cfg.nw
    m_pad = cfg.m_pad
    TB = text_ref.shape[1]
    u1 = jnp.uint32(1)
    m_len = mlen_ref[0, :]
    n_len = nlen_ref[0, :]

    # deterministic reads for ET-skipped levels (never walked, see above)
    rfull_ref[:, :, :, :] = jnp.zeros((k + 1, n_text + 1, nw, TB), jnp.uint32)

    def col_get(d, j):
        return [rfull_ref[d, j, w, :] for w in range(nw)]

    def col_set(d, j, words):
        for w in range(nw):
            rfull_ref[d, j, w, :] = words[w]

    def level_hit(d):
        """Per-lane bit (m_len - 1) of the final column == 0.  Empty lanes
        (m_len == 0) never hit, matching the jnp path's sentinel-region
        read of bit -1 for every k < WORD - 1 geometry."""
        last = col_get(d, n_text)
        t = jnp.clip(m_len - 1, 0, m_pad - 1)
        o = (t % WORD).astype(jnp.uint32)
        bit = (_word_select(last, t // WORD) >> o) & u1
        return (bit == 0) & (m_len >= 1)

    # ---------------- level 0 ----------------
    col_set(0, 0, _ones_below_words(jnp.int32(0), nw, (TB,)))

    def col_body0(j, _):
        prev = col_get(0, j - 1)
        pm_j = _pm_lookup(pm_ref, text_ref[j - 1, :].astype(jnp.int32), nw)
        bM = ((j - 1) > 0).astype(jnp.uint32)
        r = [a | b for a, b in zip(_shift1_words(prev, bM, nw), pm_j)]
        live = j <= n_len
        col_set(0, j, [jnp.where(live, rw, pw) for rw, pw in zip(r, prev)])
        return 0

    jax.lax.fori_loop(1, n_text + 1, col_body0, 0)
    dist0 = jnp.where(level_hit(0), 0, k + 1).astype(jnp.int32)

    # ---------------- levels 1..k with early termination ----------------
    def fill_level(d):
        col_set(d, 0, _ones_below_words(d, nw, (TB,)))

        def col_body(j, _):
            r_prev = col_get(d, j - 1)        # R_{j-1}[d]
            p_jm1 = col_get(d - 1, j - 1)     # R_{j-1}[d-1]
            p_j = col_get(d - 1, j)           # R_j[d-1]
            pm_j = _pm_lookup(pm_ref, text_ref[j - 1, :].astype(jnp.int32), nw)
            t = j - 1
            bM = (t > d).astype(jnp.uint32)
            bS = (t >= d).astype(jnp.uint32)
            bI = (t >= d - 1).astype(jnp.uint32)
            M = [a | b for a, b in zip(_shift1_words(r_prev, bM, nw), pm_j)]
            S = _shift1_words(p_jm1, bS, nw)
            I = _shift1_words(p_j, bI, nw)
            r = [M[w] & S[w] & p_jm1[w] & I[w] for w in range(nw)]
            live = j <= n_len
            col_set(d, j, [jnp.where(live, rw, pw)
                           for rw, pw in zip(r, r_prev)])
            return 0

        jax.lax.fori_loop(1, n_text + 1, col_body, 0)
        return level_hit(d)

    def lvl_cond(state):
        d, dist = state
        go = d <= k
        if cfg.early_term:
            go &= jnp.any(dist > k)
        return go

    def lvl_body(state):
        d, dist = state
        hit = fill_level(d)
        return d + 1, jnp.where((dist > k) & hit, d, dist).astype(jnp.int32)

    d_end, dist = jax.lax.while_loop(lvl_cond, lvl_body, (jnp.int32(1), dist0))

    # ------- traceback phase: full-vector zbit, like core.traceback 'and' ---
    d_ids = jax.lax.broadcasted_iota(jnp.int32, (k + 1, n_text + 1, TB), 0)
    c_ids = jax.lax.broadcasted_iota(jnp.int32, (k + 1, n_text + 1, TB), 1)
    t_ids = jax.lax.broadcasted_iota(jnp.int32, (n_text, TB), 0)

    def r_words(dd, jj):
        """Per-lane gather of stored R_jj[dd], clipped like _zbit_full."""
        onehot = ((d_ids == jnp.clip(dd, 0, k)[None, None, :]) &
                  (c_ids == jnp.clip(jj, 0, n_text)[None, None, :]))
        return [jnp.sum(jnp.where(onehot, rfull_ref[:, :, w, :], jnp.uint32(0)),
                        axis=(0, 1), dtype=jnp.uint32) for w in range(nw)]

    def zbit(words, dd, jj, ii):
        iic = jnp.clip(ii, 0, m_pad - 1)
        o = (iic % WORD).astype(jnp.uint32)
        bit = (_word_select(words, iic // WORD) >> o) & u1
        return jnp.where(ii < 0, jj <= dd, bit == 0)

    def text_at(jj):
        onehot = t_ids == jnp.clip(jj - 1, 0, n_text - 1)[None, :]
        return jnp.sum(jnp.where(onehot, text_ref[:, :], 0),
                       axis=0).astype(jnp.int32)

    def peq_at(cj, ii):
        words = _pm_lookup(pm_ref, cj, nw)
        iic = jnp.clip(ii, 0, m_pad - 1)
        o = (iic % WORD).astype(jnp.uint32)
        return ((_word_select(words, iic // WORD) >> o) & u1) == 0

    i, j, d, nops, ops, rd, rf, done, ok = _tb_walk(
        TB=TB, dist=dist, k=k, init_i=m_len - 1, init_j=n_len,
        commit_limit=commit_limit, max_ops=max_ops, max_steps=max_steps,
        avail_words=r_words, zbit=zbit, peq_at=peq_at, text_at=text_at)

    ops_ref[:, :] = ops
    meta_ref[META_DIST, :] = dist
    meta_ref[META_LVL, :] = jnp.broadcast_to(d_end, (TB,)).astype(jnp.int32)
    meta_ref[META_NOPS, :] = nops
    meta_ref[META_RD, :] = rd
    meta_ref[META_RF, :] = rf
    meta_ref[META_DFIN, :] = d
    meta_ref[META_OK, :] = ok.astype(jnp.int32)
    meta_ref[META_ROWS - 1, :] = jnp.zeros((TB,), jnp.int32)


def genasm_tail_fused_pallas(pm, text, m_len, n_len, *, cfg: AlignerConfig,
                             n_text: int, commit_limit: int, max_ops: int,
                             max_steps: int, tile: int = 128,
                             interpret: bool = True):
    """Fused rectangular-tail DC+TB.  pm: (5, NW, B) uint32; text:
    (n_text, B) int32; m_len/n_len: (1, B) int32 (kernel layout, problems
    innermost).  Returns (ops (max_ops, B) int32, meta (META_ROWS, B) int32)
    like genasm_tb_fused_pallas; the full SENE store lives and dies in VMEM
    scratch — the tail window never touches HBM either."""
    _, nw, B = pm.shape
    assert text.shape[0] == n_text and nw == cfg.nw and B % tile == 0
    k = cfg.k
    grid = (B // tile,)
    kern = functools.partial(_kernel_tail_fused, cfg=cfg, n_text=n_text,
                             commit_limit=commit_limit, max_ops=max_ops,
                             max_steps=max_steps)
    ops, meta = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((5, nw, tile), lambda i: (0, 0, i)),
            pl.BlockSpec((n_text, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((max_ops, tile), lambda i: (0, i)),
            pl.BlockSpec((META_ROWS, tile), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((max_ops, B), jnp.int32),
            jax.ShapeDtypeStruct((META_ROWS, B), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((k + 1, n_text + 1, nw, tile), jnp.uint32),
        ],
        interpret=interpret,
    )(pm, text, m_len, n_len)
    return ops, meta
