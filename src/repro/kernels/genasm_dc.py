"""Pallas TPU kernels: improved GenASM-DC (SENE + DENT + ET) and the fused
GenASM-DC+TB pipeline that never ships the DP state off-chip.

TPU mapping (see DESIGN.md §2): one VPU *lane* per alignment problem — the
innermost axis of every array is the problem tile (TB, a multiple of 128).
Bitvector words live in small leading axes and are unrolled; all DP state
is VMEM scratch, which is the paper's point: after the three improvements
the entire traceback table fits on-chip (`vmem_bytes` below).

Grid: one program per problem tile.  Per tile, the DC fill runs
*column-major*: a fori_loop over the W text columns carries the two live
DP columns — all k+1 levels of R_{j-1} ride in the loop state
("registers"), never in scratch — and per column the DENT band window
(funnel-shift extracted, sub-word) is stored for the traceback-reachable
columns only.  That is Scrooge's store-elimination idiom (arxiv
2208.09985): anything the shared traceback walk can re-derive from its two
live columns is never materialised, so the declared VMEM scratch *is* the
counting model's footprint (core.counting.kernel_scratch_words).

Three kernels share helpers:

  * `genasm_dc_pallas` (split) — writes the DENT band to an HBM output so
    the host-side jnp traceback (core.traceback, mode='band') can walk it.
    Band traffic per tile: (k+1) * ncols_band * nwb * TB * 4 bytes each way.
  * `genasm_tb_fused_pallas` (fused) — keeps the band in VMEM scratch and
    walks GenASM-TB *inside* the kernel: the same funnel-shift band-window
    reads as `store_band`, inverted, now per-lane dynamic (each problem is
    at its own (i, j, d) DP cell, so window/column/PM lookups become
    one-hot gathers over the small static axes, vectorized across lanes).
    Only the per-problem op array (<= max_ops int32) and a meta row leave
    the chip — the band never round-trips through HBM, which is the
    bandwidth win the paper's 24x working-set compression pays for.
  * `genasm_tail_fused_pallas` — the ragged rectangular tail.  Stores a
    per-lane *dynamic* DENT band (`_kernel_tail_banded`, the tentpole of
    the Scrooge port: ~2x less tail scratch at W=64 k=12) whenever
    `cfg.tail_banded`, falling back to the full SENE store
    (`_kernel_tail_fused`) when the band is not a strict win.

The traceback walk is bit-identical to core.traceback mode='band' (same
=,X,D,I preference, same commit-limit semantics); tests assert ops/dist
equality against the jnp path.

GPU lowering (``cfg.backend == 'pallas_gpu'``): the same three kernel
bodies compile through Pallas's *Triton* backend for CUDA GPUs.  One
Triton program per problem tile (lane-per-thread: the innermost problem
axis vectorises across the program's threads, ``gpu_num_warps`` warps of
32), with two mapping differences from the TPU path, both decided here at
trace time:

  * **No scratch memory.**  jax 0.4.37's Triton lowering rejects
    ``scratch_shapes`` outright, so the DENT band / SENE store that the
    TPU path keeps in VMEM scratch rides a GMEM-backed *output block*
    instead.  Kernel bodies are reused unchanged — Pallas passes output
    refs before scratch refs, so ``band_ref`` sits in the same positional
    slot either way; the wrapper simply discards the extra output.  The
    live DP columns stay loop-carried (registers), which is why the
    per-backend planner budget is a register model
    (``core.counting.gpu_lane_state_words``), not a 16 MiB VMEM budget.
  * **GPU-shaped tiles.**  The lane tile quantum is a warp (32) and the
    ceiling a CTA (1024 threads), planned by
    ``core.windowing.plan_lane_tile`` from the register model.

Outputs are bit-identical to the TPU/interpret path — asserted per grid
point by tests/test_kernel_fused.py and on the full differential corpus
by tests/test_differential.py.

The pure-jnp oracle is kernels/ref.py (which defers to core.genasm); the
jit'd wrapper with layout marshalling is kernels/ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.config import AlignerConfig
from ..core.counting import kernel_scratch_words, tail_scratch_words
from ..core.oracle import OP_DEL, OP_INS, OP_MATCH, OP_SUBST
from ..core.traceback import OP_NONE

WORD = 32

# meta_ref row layout of the fused kernel (8 rows for sublane alignment)
META_DIST, META_LVL, META_NOPS, META_RD, META_RF, META_DFIN, META_OK = range(7)
META_ROWS = 8


def _band_base(j, k, m_pad, nwb):
    lo = j - 2 - k
    hi = m_pad - WORD * nwb
    return jnp.clip(lo, 0, hi)


def default_max_ops(cfg: AlignerConfig) -> int:
    """Op budget of one committed window walk (= core.windowing's)."""
    return cfg.tb_max_ops


def default_max_steps(cfg: AlignerConfig) -> int:
    return cfg.tb_max_steps


def gpu_num_warps(tile: int) -> int:
    """Warps per Triton program for a `tile`-lane block: one thread per
    lane up to the CTA ceiling (warp = 32 threads, <= 8 warps so two CTAs
    can co-reside per SM at the default tile)."""
    return max(1, min(8, tile // 32))


def _gpu_compiler_params(tile: int):
    """TritonCompilerParams for a compiled GPU launch (unused in interpret
    mode).  num_stages stays 1: the DC fill is a serial column recurrence —
    software-pipelining its loads buys nothing and costs registers, the
    binding resource of the lane-per-thread mapping."""
    from jax.experimental.pallas import triton as plgpu
    return plgpu.TritonCompilerParams(num_warps=gpu_num_warps(tile),
                                      num_stages=1)


def fused_scratch_shapes(cfg: AlignerConfig, tile: int):
    """The declared VMEM scratch of the square fused kernel: the DENT band,
    nothing else — the DC fill's live columns are loop-carried values.
    Single source for `genasm_tb_fused_pallas` and the accounting tests."""
    return [pltpu.VMEM((cfg.k + 1, cfg.ncols_band, cfg.nwb, tile),
                       jnp.uint32)]


def gpu_fused_store_shapes(cfg: AlignerConfig, tile: int):
    """Declared per-program DP store of the square fused kernel on the
    Triton path: the identical DENT band, as a GMEM-backed output block
    (Triton has no scratch memory), one `jax.ShapeDtypeStruct` per store.
    Same words as `fused_scratch_shapes` — only the memory space differs —
    which tests/test_scratch_accounting.py asserts against the
    `core.counting.gpu_store_words` model."""
    return [jax.ShapeDtypeStruct((cfg.k + 1, cfg.ncols_band, cfg.nwb, tile),
                                 jnp.uint32)]


def gpu_tail_store_shapes(cfg: AlignerConfig, tile: int, n_text: int,
                          banded: bool | None = None):
    """Declared per-program DP store of the rectangular-tail kernel on the
    Triton path (GMEM output block, same words as `tail_scratch_shapes`)."""
    banded = cfg.tail_banded if banded is None else banded
    if banded:
        return [jax.ShapeDtypeStruct((cfg.k + 1, n_text, cfg.nwb, tile),
                                     jnp.uint32)]
    return [jax.ShapeDtypeStruct((cfg.k + 1, n_text + 1, cfg.nw, tile),
                                 jnp.uint32)]


def tail_scratch_shapes(cfg: AlignerConfig, tile: int, n_text: int,
                        banded: bool | None = None):
    """Declared VMEM scratch of the rectangular-tail kernel: the per-lane
    dynamic band (columns 1..n_text x nwb words; column 0 is analytic), or
    the full SENE table on the no-band-win fallback."""
    banded = cfg.tail_banded if banded is None else banded
    if banded:
        return [pltpu.VMEM((cfg.k + 1, n_text, cfg.nwb, tile), jnp.uint32)]
    return [pltpu.VMEM((cfg.k + 1, n_text + 1, cfg.nw, tile), jnp.uint32)]


def vmem_bytes(cfg: AlignerConfig, tile: int) -> int:
    """On-chip DP-store bytes per problem tile (the paper's 'fits in
    on-chip memory' claim, checked against ~16MB VMEM in tests).

    Exactly the declared scratch of the fused kernel — which, post
    store-elimination, is the band and only the band, so this equals
    `core.counting.kernel_scratch_words * 4` (one source of truth; the
    equality is asserted per grid point in tests/test_scratch_accounting).
    For the split kernel the identical band is an output block instead of
    scratch: same bytes resident while the tile is in flight."""
    return kernel_scratch_words(cfg, tile) * 4


def vmem_bytes_tail(cfg: AlignerConfig, tile: int, n_text: int | None = None,
                    banded: bool | None = None) -> int:
    """On-chip DP-store bytes of the rectangular-tail fused kernel per
    problem tile: the declared scratch of `tail_scratch_shapes`, via the
    counting model (banded defaults to cfg.tail_banded)."""
    return tail_scratch_words(cfg, tile, n_text, banded) * 4


def _pm_lookup(pm_ref, cj, nw, n_sym=4):
    """cj: (TB,) int32 -> list of nw (TB,) mask words (sentinel -> all ones)."""
    out = []
    for w in range(nw):
        acc = jnp.full(cj.shape, 0xFFFFFFFF, jnp.uint32)
        for c in range(n_sym):
            acc = jnp.where(cj == c, pm_ref[c, w, :], acc)
        out.append(acc)
    return out


def _shift1_words(words, carry_in, nw):
    """Left-shift a word-list bitvector (LSW first) by one; carry_in at bit 0.
    words: list of nw (TB,) uint32."""
    out, carry = [], carry_in
    for w in range(nw):
        out.append((words[w] << jnp.uint32(1)) | carry)
        carry = words[w] >> jnp.uint32(WORD - 1)
    return out


def _ones_below_words(d, nw, lane_shape):
    """(nw-word, lanes) GenASM level-d init vector ~0 << d for traced d."""
    out = []
    for w in range(nw):
        lo = jnp.clip(d - w * WORD, 0, WORD)
        val = jnp.where(lo >= WORD, jnp.uint32(0),
                        jnp.uint32(0xFFFFFFFF) << lo.astype(jnp.uint32))
        out.append(jnp.broadcast_to(val, lane_shape))
    return out


def _word_select(words, w0):
    """Per-lane dynamic word pick from a word list; w0: (TB,) int32."""
    word = words[0]
    for w in range(1, len(words)):
        word = jnp.where(w0 == w, words[w], word)
    return word


def _next_column(prev, cur_below, pm_j, t, d, nw):
    """One SENE cell: R_j[d] from the three stored neighbours + PM mask.
    prev = [R_{j-1}[d], R_{j-1}[d-1]] (or [R_{j-1}[0]] at level 0),
    cur_below = R_j[d-1] (already frozen/final for this column)."""
    if d == 0:
        bM = (t > 0).astype(jnp.uint32)
        return [a | b for a, b in zip(_shift1_words(prev[0], bM, nw), pm_j)]
    r_prev, p_jm1 = prev
    bM = (t > d).astype(jnp.uint32)
    bS = (t >= d).astype(jnp.uint32)
    bI = (t >= d - 1).astype(jnp.uint32)
    M = [a | b for a, b in zip(_shift1_words(r_prev, bM, nw), pm_j)]
    S = _shift1_words(p_jm1, bS, nw)
    I = _shift1_words(cur_below, bI, nw)
    return [M[w] & S[w] & p_jm1[w] & I[w] for w in range(nw)]


def _ids_dist_dend(last_cols, bit_w, bit_o, guard, cfg):
    """dist = min level whose final column clears the target bit (monotone
    in d, so the fold below and the level-major first-hit agree), and the
    analytic d_end that reproduces the retired whole-tile-ET while loop's
    exit level exactly: with ET the loop ran levels 1..max(dist) (capped at
    k) and exited at the next level; without ET it always reached k+1."""
    k = cfg.k
    u1 = jnp.uint32(1)
    dist = None
    for d in range(k, -1, -1):
        bit = (_word_select(list(last_cols[d]), bit_w) >> bit_o) & u1
        hit = (bit == 0) & guard
        full = jnp.full(hit.shape, k + 1, jnp.int32)
        dist = jnp.where(hit, d, full if dist is None else dist)
    if cfg.early_term:
        d_end = jnp.minimum(jnp.max(dist), k) + 1
    else:
        d_end = jnp.int32(k + 1)
    return dist, d_end


def _dc_phase(pm_ref, text_ref, band_ref, *, cfg: AlignerConfig):
    """Column-major improved GenASM-DC fill: all k+1 levels of the two live
    DP columns ride in the fori_loop carry; only the DENT band windows are
    materialised (into band_ref — output block or VMEM scratch).  Returns
    (dist, d_end).

    Level values stored at levels above a lane's dist can differ from the
    retired level-major ET fill (which left them zero) — but no consumer
    reads them: the traceback starts at d = dist and only descends, and the
    band parity tests compare levels [:d_end] only."""
    W, k, nw, nwb = cfg.W, cfg.k, cfg.nw, cfg.nwb
    m_pad = cfg.m_pad
    ncb = cfg.ncols_band
    col0 = W + 1 - ncb
    tgt_w, tgt_o = (W - 1) // WORD, jnp.uint32((W - 1) % WORD)

    def store_band(d, j, words):
        """Funnel-shift extract the band window of column j and store it."""
        base = _band_base(j, k, m_pad, nwb)
        w0 = base // WORD
        s = (base % WORD).astype(jnp.uint32)
        for b in range(nwb):
            lo = words[0]
            hi = words[0]
            for w in range(nw):          # dynamic word select, unrolled
                lo = jnp.where(w0 + b == w, words[w], lo)
                hi = jnp.where(w0 + b + 1 == w, words[w],
                               jnp.where(w0 + b + 1 >= nw, jnp.uint32(0xFFFFFFFF),
                                         hi))
            win = jnp.where(s == 0, lo, (lo >> s) | (hi << (jnp.uint32(WORD) - s)))
            @pl.when(j >= col0)
            def _():
                band_ref[d, j - col0, b, :] = win

    lane_shape = text_ref.shape[1:]
    cols0 = [_ones_below_words(jnp.int32(d), nw, lane_shape)
             for d in range(k + 1)]
    if col0 == 0:                         # column 0 only stored if in band
        for d in range(k + 1):
            store_band(d, jnp.int32(0), cols0[d])

    def col_body(j, carry):
        prev = [list(c) for c in carry]
        cj = text_ref[j - 1, :].astype(jnp.int32)
        pm_j = _pm_lookup(pm_ref, cj, nw)
        t = j - 1
        cur = [_next_column([prev[0]], None, pm_j, t, 0, nw)]
        for d in range(1, k + 1):
            cur.append(_next_column([prev[d], prev[d - 1]], cur[d - 1],
                                    pm_j, t, d, nw))
        for d in range(k + 1):
            store_band(d, j, cur[d])
        return tuple(tuple(c) for c in cur)

    last = jax.lax.fori_loop(1, W + 1, col_body,
                             tuple(tuple(c) for c in cols0))
    guard = jnp.ones(lane_shape, bool)
    return _ids_dist_dend(last, tgt_w, tgt_o, guard, cfg)


def _kernel(pm_ref, text_ref, band_ref, dist_ref, lvl_ref, *,
            cfg: AlignerConfig):
    dist, d_end = _dc_phase(pm_ref, text_ref, band_ref, cfg=cfg)
    dist_ref[0, :] = dist
    lvl_ref[0, :] = jnp.broadcast_to(d_end, lvl_ref.shape[1:]).astype(jnp.int32)


def _tb_walk(*, TB, dist, k, init_i, init_j, commit_limit, max_ops, max_steps,
             avail_words, zbit, peq_at, text_at):
    """Shared in-kernel GenASM-TB walk, bit-identical to core.traceback:
    per-lane (i, j, d) cursors advanced with the =,X,D,I preference order, a
    tail drain (pattern exhausted -> remaining text as deletions), and the
    commit-limit stop.  ``avail_words(dd, jj)`` gathers the stored bitvector
    words of (level dd, column jj); ``zbit(words, dd, jj, ii)`` tests bit ii.

    Returns the final (i, j, d, nops, ops, rd, rf, done, ok) state."""
    slot_ids = jax.lax.broadcasted_iota(jnp.int32, (max_ops, TB), 0)

    def body(state):
        i, j, d, nops, ops, rd, rf, done, ok = state
        tail = i < 0
        stopped = rd >= commit_limit
        active = ~done & ~stopped

        w_d_jm1 = avail_words(d, j - 1)
        w_dm1_jm1 = avail_words(d - 1, j - 1)
        w_dm1_j = avail_words(d - 1, j)
        peq = peq_at(text_at(j), i)
        mA = (j > 0) & peq & zbit(w_d_jm1, d, j - 1, i - 1)
        sA = (j > 0) & (d > 0) & zbit(w_dm1_jm1, d - 1, j - 1, i - 1)
        dA = (j > 0) & (d > 0) & zbit(w_dm1_jm1, d - 1, j - 1, i)
        iA = (d > 0) & zbit(w_dm1_j, d - 1, j, i - 1)

        # tail: pattern exhausted, drain remaining text as deletions
        tail_emit = tail & (j > 0)
        mA &= ~tail; sA &= ~tail; dA &= ~tail; iA &= ~tail

        any_edge = mA | sA | dA | iA | tail_emit
        # exclusive choice with GenASM's =,X,D,I preference
        cM = mA
        cS = ~mA & sA
        cD = ~mA & ~sA & dA
        cI = ~mA & ~sA & ~dA & iA
        op = jnp.where(cM, OP_MATCH,
             jnp.where(cS, OP_SUBST,
             jnp.where(cD, OP_DEL,
             jnp.where(cI, OP_INS, OP_DEL)))).astype(jnp.int32)

        takes_read = active & (cM | cS | cI)
        takes_ref = active & (cM | cS | cD | tail_emit)
        costs = active & (cS | cD | cI | tail_emit)

        new_i = jnp.where(takes_read, i - 1, i)
        new_j = jnp.where(takes_ref, j - 1, j)
        new_d = jnp.where(costs, d - 1, d)
        new_rd = rd + takes_read
        new_rf = rf + takes_ref

        emit = active & any_edge
        slot = jnp.where(emit, nops, max_ops)   # max_ops -> no iota row: drop
        ops = jnp.where(slot_ids == slot[None, :], op[None, :], ops)
        nops = nops + emit

        finished = (new_i < 0) & (new_j <= 0)
        new_done = done | (active & finished)
        # invariant: an active, unfinished cell always has an available edge
        ok &= jnp.where(active & ~finished, any_edge | ((i < 0) & (j <= 0)), True)
        return (new_i, new_j, new_d, nops, ops, new_rd, new_rf,
                new_done | stopped, ok)

    def walk_body(step, state):
        del step
        return jax.lax.cond(jnp.any(~state[7]), body, lambda s: s, state)

    zeros = jnp.zeros((TB,), jnp.int32)
    skip = dist > k
    init = (
        init_i,                                     # i (m_len - 1)
        init_j,                                     # j (n_len)
        dist,                                       # d
        zeros,                                      # nops
        jnp.full((max_ops, TB), OP_NONE, jnp.int32),
        zeros,                                      # read_adv
        zeros,                                      # ref_adv
        skip,                                       # done
        jnp.ones((TB,), bool),                      # ok
    )
    return jax.lax.fori_loop(0, max_steps, walk_body, init)


def _kernel_fused(pm_ref, text_ref, ops_ref, meta_ref, band_ref, *,
                  cfg: AlignerConfig, commit_limit: int, max_ops: int,
                  max_steps: int):
    """DC phase into VMEM scratch, then GenASM-TB walked in-kernel.

    The walk mirrors core.traceback (mode='band') bit for bit: SENE edge
    availability is recomputed from neighbouring stored band windows + the
    PM masks, with the =,X,D,I preference order, a per-lane tail drain, and
    the commit-limit stop.  Per-lane dynamic (d, j) band reads use one-hot
    sums over the small static (k+1, ncols_band) axes — the inverted form
    of store_band's funnel-shift stores.  The column-major fill writes
    every band entry, so no zero-init pass is needed (and the walk never
    visits levels above its lane's dist anyway).
    """
    W, k, nw, nwb = cfg.W, cfg.k, cfg.nw, cfg.nwb
    m_pad = cfg.m_pad
    ncb = cfg.ncols_band
    col0 = W + 1 - ncb
    TB = text_ref.shape[1]
    u1 = jnp.uint32(1)

    dist, d_end = _dc_phase(pm_ref, text_ref, band_ref, cfg=cfg)

    # ---------------- traceback phase ----------------
    d_ids = jax.lax.broadcasted_iota(jnp.int32, (k + 1, ncb, TB), 0)
    s_ids = jax.lax.broadcasted_iota(jnp.int32, (k + 1, ncb, TB), 1)
    t_ids = jax.lax.broadcasted_iota(jnp.int32, (W, TB), 0)

    def band_words(dd, jj):
        """Per-lane gather of the stored band window of (level dd, col jj),
        clipped like core.traceback._zbit_band."""
        onehot = ((d_ids == jnp.clip(dd, 0, k)[None, None, :]) &
                  (s_ids == jnp.clip(jj - col0, 0, ncb - 1)[None, None, :]))
        return [jnp.sum(jnp.where(onehot, band_ref[:, :, b, :], jnp.uint32(0)),
                        axis=(0, 1), dtype=jnp.uint32) for b in range(nwb)]

    def zbit(words, dd, jj, ii):
        """bit ii of the band window == 0; ii == -1 encodes the DP's first
        column: ED(0, jj) <= dd  ⟺  jj <= dd."""
        base = _band_base(jj, k, m_pad, nwb)
        off = ii - base
        inband = (off >= 0) & (off < nwb * WORD)
        offc = jnp.clip(off, 0, nwb * WORD - 1)
        o = (offc % WORD).astype(jnp.uint32)
        bit = (_word_select(words, offc // WORD) >> o) & u1
        return jnp.where(ii < 0, jj <= dd, (bit == 0) & inband)

    def text_at(jj):
        """text char of column jj (= text index jj-1, clipped)."""
        onehot = t_ids == jnp.clip(jj - 1, 0, W - 1)[None, :]
        return jnp.sum(jnp.where(onehot, text_ref[:, :], 0),
                       axis=0).astype(jnp.int32)

    def peq_at(cj, ii):
        """P[ii] == text char cj, via the PM masks (sentinels never match)."""
        words = _pm_lookup(pm_ref, cj, nw)
        iic = jnp.clip(ii, 0, m_pad - 1)
        o = (iic % WORD).astype(jnp.uint32)
        return ((_word_select(words, iic // WORD) >> o) & u1) == 0

    i, j, d, nops, ops, rd, rf, done, ok = _tb_walk(
        TB=TB, dist=dist, k=k,
        init_i=jnp.full((TB,), W - 1, jnp.int32),
        init_j=jnp.full((TB,), W, jnp.int32),
        commit_limit=commit_limit, max_ops=max_ops, max_steps=max_steps,
        avail_words=band_words, zbit=zbit, peq_at=peq_at, text_at=text_at)

    ops_ref[:, :] = ops
    meta_ref[META_DIST, :] = dist
    meta_ref[META_LVL, :] = jnp.broadcast_to(d_end, (TB,)).astype(jnp.int32)
    meta_ref[META_NOPS, :] = nops
    meta_ref[META_RD, :] = rd
    meta_ref[META_RF, :] = rf
    meta_ref[META_DFIN, :] = d
    meta_ref[META_OK, :] = ok.astype(jnp.int32)
    meta_ref[META_ROWS - 1, :] = jnp.zeros((TB,), jnp.int32)


def genasm_dc_pallas(pm, text, *, cfg: AlignerConfig, tile: int = 128,
                     interpret: bool = True):
    """pm: (5, NW, B) uint32; text: (W, B) int32 (kernel layout, problems
    innermost).  Returns (dist (B,), band (k+1, ncb, nwb, B), levels (B,)).
    No VMEM scratch at all: the DC state is loop-carried, the band is the
    output block — which is why this kernel lowers through the Triton
    backend (cfg.backend == 'pallas_gpu') completely unchanged."""
    _, nw, B = pm.shape
    W = text.shape[0]
    assert W == cfg.W and nw == cfg.nw and B % tile == 0
    ncb, nwb, k = cfg.ncols_band, cfg.nwb, cfg.k
    grid = (B // tile,)
    gpu = cfg.backend == "pallas_gpu"
    kern = functools.partial(_kernel, cfg=cfg)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((5, nw, tile), lambda i: (0, 0, i)),
            pl.BlockSpec((W, tile), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((k + 1, ncb, nwb, tile), lambda i: (0, 0, 0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k + 1, ncb, nwb, B), jnp.uint32),
            jax.ShapeDtypeStruct((1, B), jnp.int32),
            jax.ShapeDtypeStruct((1, B), jnp.int32),
        ],
        compiler_params=_gpu_compiler_params(tile)
        if gpu and not interpret else None,
        interpret=interpret,
    )(pm, text)
    band, dist, lvl = out
    return dist[0], band, lvl[0]


def genasm_tb_fused_pallas(pm, text, *, cfg: AlignerConfig, commit_limit: int,
                           max_ops: int | None = None,
                           max_steps: int | None = None, tile: int = 128,
                           interpret: bool = True):
    """Fused DC+TB.  pm: (5, NW, B) uint32; text: (W, B) int32 (kernel
    layout).  Returns (ops (max_ops, B) int32 front-first with OP_NONE
    padding, meta (META_ROWS, B) int32 — see META_* row constants).  The
    DENT band lives and dies on-chip: VMEM scratch on the TPU path
    (`fused_scratch_shapes`), a discarded GMEM output block on the Triton
    path (`gpu_fused_store_shapes` — cfg.backend == 'pallas_gpu', whose
    lowering has no scratch memory).  The kernel body is identical either
    way: output refs precede scratch refs, so band_ref occupies the same
    positional slot as 3rd output or 1st scratch."""
    _, nw, B = pm.shape
    W = text.shape[0]
    assert W == cfg.W and nw == cfg.nw and B % tile == 0
    if max_ops is None:
        max_ops = default_max_ops(cfg)
    if max_steps is None:
        max_steps = default_max_steps(cfg)
    grid = (B // tile,)
    gpu = cfg.backend == "pallas_gpu"
    kern = functools.partial(_kernel_fused, cfg=cfg, commit_limit=commit_limit,
                             max_ops=max_ops, max_steps=max_steps)
    ncb, nwb, k = cfg.ncols_band, cfg.nwb, cfg.k
    out_specs = [
        pl.BlockSpec((max_ops, tile), lambda i: (0, i)),
        pl.BlockSpec((META_ROWS, tile), lambda i: (0, i)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((max_ops, B), jnp.int32),
        jax.ShapeDtypeStruct((META_ROWS, B), jnp.int32),
    ]
    if gpu:
        out_specs.append(pl.BlockSpec((k + 1, ncb, nwb, tile),
                                      lambda i: (0, 0, 0, i)))
        (blk,) = gpu_fused_store_shapes(cfg, tile)
        out_shape.append(jax.ShapeDtypeStruct(blk.shape[:-1] + (B,),
                                              blk.dtype))
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((5, nw, tile), lambda i: (0, 0, i)),
            pl.BlockSpec((W, tile), lambda i: (0, i)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=() if gpu else fused_scratch_shapes(cfg, tile),
        compiler_params=_gpu_compiler_params(tile)
        if gpu and not interpret else None,
        interpret=interpret,
    )(pm, text)
    ops, meta = out[0], out[1]       # gpu: out[2] is the discarded band
    return ops, meta


def _kernel_tail_fused(pm_ref, text_ref, mlen_ref, nlen_ref, ops_ref, meta_ref,
                       rfull_ref, *, cfg: AlignerConfig, n_text: int,
                       commit_limit: int, max_ops: int, max_steps: int):
    """Rectangular-tail fused DC+TB, full-store fallback.

    Unlike the square main-window kernel the tail is rectangular and ragged:
    per-lane m_len <= W pattern chars against n_len <= n_text text chars.
    This variant stores the full SENE ('and') vectors for every (level,
    column) in VMEM scratch and the traceback walks them in-kernel — the
    exact analogue of core.windowing's jnp 'and'-store tail path, bit for
    bit, with neither the store nor the walk ever leaving the chip.  It is
    dispatched only when the banded store (`_kernel_tail_banded`) is not a
    strict win (cfg.tail_banded False, i.e. nwb == nw or forced 'full').

    Mirrors dc_jmajor semantics: columns beyond a lane's n_len are frozen
    copies of their left neighbour (hence of column n_len), dist reads the
    per-lane bit (m_len - 1) of the final column, and the level loop runs
    whole-tile early termination — the traceback never visits a level above
    its lane's dist, so ET cannot change results vs the ET-free jnp fill.
    """
    W, k, nw = cfg.W, cfg.k, cfg.nw
    m_pad = cfg.m_pad
    TB = text_ref.shape[1]
    u1 = jnp.uint32(1)
    m_len = mlen_ref[0, :]
    n_len = nlen_ref[0, :]

    # deterministic reads for ET-skipped levels (never walked, see above)
    rfull_ref[:, :, :, :] = jnp.zeros((k + 1, n_text + 1, nw, TB), jnp.uint32)

    def col_get(d, j):
        return [rfull_ref[d, j, w, :] for w in range(nw)]

    def col_set(d, j, words):
        for w in range(nw):
            rfull_ref[d, j, w, :] = words[w]

    def level_hit(d):
        """Per-lane bit (m_len - 1) of the final column == 0.  Empty lanes
        (m_len == 0) never hit, matching the jnp path's sentinel-region
        read of bit -1 for every k < WORD - 1 geometry."""
        last = col_get(d, n_text)
        t = jnp.clip(m_len - 1, 0, m_pad - 1)
        o = (t % WORD).astype(jnp.uint32)
        bit = (_word_select(last, t // WORD) >> o) & u1
        return (bit == 0) & (m_len >= 1)

    # ---------------- level 0 ----------------
    col_set(0, 0, _ones_below_words(jnp.int32(0), nw, (TB,)))

    def col_body0(j, _):
        prev = col_get(0, j - 1)
        pm_j = _pm_lookup(pm_ref, text_ref[j - 1, :].astype(jnp.int32), nw)
        bM = ((j - 1) > 0).astype(jnp.uint32)
        r = [a | b for a, b in zip(_shift1_words(prev, bM, nw), pm_j)]
        live = j <= n_len
        col_set(0, j, [jnp.where(live, rw, pw) for rw, pw in zip(r, prev)])
        return 0

    jax.lax.fori_loop(1, n_text + 1, col_body0, 0)
    dist0 = jnp.where(level_hit(0), 0, k + 1).astype(jnp.int32)

    # ---------------- levels 1..k with early termination ----------------
    def fill_level(d):
        col_set(d, 0, _ones_below_words(d, nw, (TB,)))

        def col_body(j, _):
            r_prev = col_get(d, j - 1)        # R_{j-1}[d]
            p_jm1 = col_get(d - 1, j - 1)     # R_{j-1}[d-1]
            p_j = col_get(d - 1, j)           # R_j[d-1]
            pm_j = _pm_lookup(pm_ref, text_ref[j - 1, :].astype(jnp.int32), nw)
            t = j - 1
            bM = (t > d).astype(jnp.uint32)
            bS = (t >= d).astype(jnp.uint32)
            bI = (t >= d - 1).astype(jnp.uint32)
            M = [a | b for a, b in zip(_shift1_words(r_prev, bM, nw), pm_j)]
            S = _shift1_words(p_jm1, bS, nw)
            I = _shift1_words(p_j, bI, nw)
            r = [M[w] & S[w] & p_jm1[w] & I[w] for w in range(nw)]
            live = j <= n_len
            col_set(d, j, [jnp.where(live, rw, pw)
                           for rw, pw in zip(r, r_prev)])
            return 0

        jax.lax.fori_loop(1, n_text + 1, col_body, 0)
        return level_hit(d)

    def lvl_cond(state):
        d, dist = state
        go = d <= k
        if cfg.early_term:
            go &= jnp.any(dist > k)
        return go

    def lvl_body(state):
        d, dist = state
        hit = fill_level(d)
        return d + 1, jnp.where((dist > k) & hit, d, dist).astype(jnp.int32)

    d_end, dist = jax.lax.while_loop(lvl_cond, lvl_body, (jnp.int32(1), dist0))

    # ------- traceback phase: full-vector zbit, like core.traceback 'and' ---
    d_ids = jax.lax.broadcasted_iota(jnp.int32, (k + 1, n_text + 1, TB), 0)
    c_ids = jax.lax.broadcasted_iota(jnp.int32, (k + 1, n_text + 1, TB), 1)
    t_ids = jax.lax.broadcasted_iota(jnp.int32, (n_text, TB), 0)

    def r_words(dd, jj):
        """Per-lane gather of stored R_jj[dd], clipped like _zbit_full."""
        onehot = ((d_ids == jnp.clip(dd, 0, k)[None, None, :]) &
                  (c_ids == jnp.clip(jj, 0, n_text)[None, None, :]))
        return [jnp.sum(jnp.where(onehot, rfull_ref[:, :, w, :], jnp.uint32(0)),
                        axis=(0, 1), dtype=jnp.uint32) for w in range(nw)]

    def zbit(words, dd, jj, ii):
        iic = jnp.clip(ii, 0, m_pad - 1)
        o = (iic % WORD).astype(jnp.uint32)
        bit = (_word_select(words, iic // WORD) >> o) & u1
        return jnp.where(ii < 0, jj <= dd, bit == 0)

    def text_at(jj):
        onehot = t_ids == jnp.clip(jj - 1, 0, n_text - 1)[None, :]
        return jnp.sum(jnp.where(onehot, text_ref[:, :], 0),
                       axis=0).astype(jnp.int32)

    def peq_at(cj, ii):
        words = _pm_lookup(pm_ref, cj, nw)
        iic = jnp.clip(ii, 0, m_pad - 1)
        o = (iic % WORD).astype(jnp.uint32)
        return ((_word_select(words, iic // WORD) >> o) & u1) == 0

    i, j, d, nops, ops, rd, rf, done, ok = _tb_walk(
        TB=TB, dist=dist, k=k, init_i=m_len - 1, init_j=n_len,
        commit_limit=commit_limit, max_ops=max_ops, max_steps=max_steps,
        avail_words=r_words, zbit=zbit, peq_at=peq_at, text_at=text_at)

    ops_ref[:, :] = ops
    meta_ref[META_DIST, :] = dist
    meta_ref[META_LVL, :] = jnp.broadcast_to(d_end, (TB,)).astype(jnp.int32)
    meta_ref[META_NOPS, :] = nops
    meta_ref[META_RD, :] = rd
    meta_ref[META_RF, :] = rf
    meta_ref[META_DFIN, :] = d
    meta_ref[META_OK, :] = ok.astype(jnp.int32)
    meta_ref[META_ROWS - 1, :] = jnp.zeros((TB,), jnp.int32)


def _kernel_tail_banded(pm_ref, text_ref, mlen_ref, nlen_ref, ops_ref,
                        meta_ref, band_ref, *, cfg: AlignerConfig, n_text: int,
                        commit_limit: int, max_ops: int, max_steps: int):
    """Rectangular-tail fused DC+TB with the Scrooge-style banded store.

    The band proof (the tentpole): the traceback walk starts at the
    per-lane cell (i = m_len-1, j = n_len) and every step moves i and/or j
    down by one, spending at most dist <= k unit costs on indels — so at
    any visited cell, i - j differs from the starting diagonal
    (m_len - 1 - n_len) by at most k, and the walk's bit reads (at offsets
    -1..+1 around the cursor) stay within [c(j)-k-1, c(j)+k+1] of the
    per-lane column center c(j) = j + m_len - 1 - n_len.  That window is
    2k+3 bits = nwb words: the kernel stores only those words per (level,
    column), funnel-shifted from the live column exactly like the square
    kernel's store_band — but with a per-lane *dynamic* base, since every
    lane sits on its own diagonal.  Column 0 (R_0[d] = ones_below(d)) and
    the i < 0 drain are analytic in zbit, so they need no store at all.

    The fill is column-major (two live columns in the loop carry, all k+1
    levels unrolled — no full-table scratch), with dc_jmajor's ragged
    semantics preserved: columns beyond a lane's n_len freeze their left
    neighbour, and dist reads the per-lane bit (m_len - 1) of the final
    carried column.  d_end reproduces the whole-tile-ET level count
    analytically (see _ids_dist_dend); the walk never visits a level above
    its lane's dist, so the extra computed levels cannot change results.
    """
    W, k, nw, nwb = cfg.W, cfg.k, cfg.nw, cfg.nwb
    m_pad = cfg.m_pad
    TB = text_ref.shape[1]
    u1 = jnp.uint32(1)
    m_len = mlen_ref[0, :]
    n_len = nlen_ref[0, :]
    diag = m_len - 1 - n_len              # per-lane starting diagonal

    def tail_base(jj):
        """Lowest stored bit of column jj's window: k+1 below the per-lane
        center, clipped into the padded pattern like _band_base."""
        return jnp.clip(jj + diag - (k + 1), 0, m_pad - WORD * nwb)

    def store_band(d, j, words):
        base = tail_base(j)
        w0 = base // WORD
        s = (base % WORD).astype(jnp.uint32)
        for b in range(nwb):
            lo = words[0]
            hi = words[0]
            for w in range(nw):          # per-lane dynamic select, unrolled
                lo = jnp.where(w0 + b == w, words[w], lo)
                hi = jnp.where(w0 + b + 1 == w, words[w],
                               jnp.where(w0 + b + 1 >= nw, jnp.uint32(0xFFFFFFFF),
                                         hi))
            win = jnp.where(s == 0, lo, (lo >> s) | (hi << (jnp.uint32(WORD) - s)))
            band_ref[d, j - 1, b, :] = win

    # ------- column-major fill: live columns in the carry, band stored -----
    cols0 = [_ones_below_words(jnp.int32(d), nw, (TB,)) for d in range(k + 1)]

    def col_body(j, carry):
        prev = [list(c) for c in carry]
        pm_j = _pm_lookup(pm_ref, text_ref[j - 1, :].astype(jnp.int32), nw)
        live = j <= n_len
        t = j - 1
        cur = []
        for d in range(k + 1):
            below = cur[d - 1] if d else None
            r = _next_column([prev[d]] if d == 0 else [prev[d], prev[d - 1]],
                             below, pm_j, t, d, nw)
            cur.append([jnp.where(live, rw, pw)
                        for rw, pw in zip(r, prev[d])])
        for d in range(k + 1):
            store_band(d, j, cur[d])
        return tuple(tuple(c) for c in cur)

    last = jax.lax.fori_loop(1, n_text + 1, col_body,
                             tuple(tuple(c) for c in cols0))

    # dist from the final carried column (== frozen column n_len), exactly
    # level_hit of the full-store variant; empty lanes (m_len == 0) never hit
    tm = jnp.clip(m_len - 1, 0, m_pad - 1)
    dist, d_end = _ids_dist_dend(last, tm // WORD,
                                 (tm % WORD).astype(jnp.uint32),
                                 m_len >= 1, cfg)

    # ---------------- traceback phase: banded zbit ----------------
    d_ids = jax.lax.broadcasted_iota(jnp.int32, (k + 1, n_text, TB), 0)
    c_ids = jax.lax.broadcasted_iota(jnp.int32, (k + 1, n_text, TB), 1)
    t_ids = jax.lax.broadcasted_iota(jnp.int32, (n_text, TB), 0)

    def band_words(dd, jj):
        """Per-lane gather of the window of (level dd, col jj); column 0 has
        no store (analytic in zbit), so jj clips into 1..n_text."""
        onehot = ((d_ids == jnp.clip(dd, 0, k)[None, None, :]) &
                  (c_ids == (jnp.clip(jj, 1, n_text) - 1)[None, None, :]))
        return [jnp.sum(jnp.where(onehot, band_ref[:, :, b, :], jnp.uint32(0)),
                        axis=(0, 1), dtype=jnp.uint32) for b in range(nwb)]

    def zbit(words, dd, jj, ii):
        """bit ii of R_jj[dd] == 0 from the banded store; analytic for the
        unstored boundaries: ii < 0 is the DP's first row (ED(0, jj) = jj),
        jj <= 0 the first column (R_0[d] = ones_below(d): ED(ii+1, 0))."""
        base = tail_base(jj)
        off = ii - base
        inband = (off >= 0) & (off < nwb * WORD)
        offc = jnp.clip(off, 0, nwb * WORD - 1)
        o = (offc % WORD).astype(jnp.uint32)
        bit = (_word_select(words, offc // WORD) >> o) & u1
        z = jnp.where(jj <= 0, ii < dd, (bit == 0) & inband)
        return jnp.where(ii < 0, jj <= dd, z)

    def text_at(jj):
        onehot = t_ids == jnp.clip(jj - 1, 0, n_text - 1)[None, :]
        return jnp.sum(jnp.where(onehot, text_ref[:, :], 0),
                       axis=0).astype(jnp.int32)

    def peq_at(cj, ii):
        words = _pm_lookup(pm_ref, cj, nw)
        iic = jnp.clip(ii, 0, m_pad - 1)
        o = (iic % WORD).astype(jnp.uint32)
        return ((_word_select(words, iic // WORD) >> o) & u1) == 0

    i, j, d, nops, ops, rd, rf, done, ok = _tb_walk(
        TB=TB, dist=dist, k=k, init_i=m_len - 1, init_j=n_len,
        commit_limit=commit_limit, max_ops=max_ops, max_steps=max_steps,
        avail_words=band_words, zbit=zbit, peq_at=peq_at, text_at=text_at)

    ops_ref[:, :] = ops
    meta_ref[META_DIST, :] = dist
    meta_ref[META_LVL, :] = jnp.broadcast_to(d_end, (TB,)).astype(jnp.int32)
    meta_ref[META_NOPS, :] = nops
    meta_ref[META_RD, :] = rd
    meta_ref[META_RF, :] = rf
    meta_ref[META_DFIN, :] = d
    meta_ref[META_OK, :] = ok.astype(jnp.int32)
    meta_ref[META_ROWS - 1, :] = jnp.zeros((TB,), jnp.int32)


def genasm_tail_fused_pallas(pm, text, m_len, n_len, *, cfg: AlignerConfig,
                             n_text: int, commit_limit: int, max_ops: int,
                             max_steps: int, tile: int = 128,
                             interpret: bool = True):
    """Fused rectangular-tail DC+TB.  pm: (5, NW, B) uint32; text:
    (n_text, B) int32; m_len/n_len: (1, B) int32 (kernel layout, problems
    innermost).  Returns (ops (max_ops, B) int32, meta (META_ROWS, B) int32)
    like genasm_tb_fused_pallas; the SENE store lives and dies in VMEM
    scratch — banded (`cfg.tail_banded`, ~2x less scratch at the default
    geometry) or full on the fallback — and the tail window never touches
    HBM either.  On the Triton path (cfg.backend == 'pallas_gpu', no
    scratch memory in that lowering) the same store is a discarded GMEM
    output block (`gpu_tail_store_shapes`); kernel bodies unchanged.  All
    variants are bit-identical on every output
    (tests/test_kernel_fused.py, tests/test_differential.py)."""
    _, nw, B = pm.shape
    assert text.shape[0] == n_text and nw == cfg.nw and B % tile == 0
    grid = (B // tile,)
    gpu = cfg.backend == "pallas_gpu"
    body = _kernel_tail_banded if cfg.tail_banded else _kernel_tail_fused
    kern = functools.partial(body, cfg=cfg, n_text=n_text,
                             commit_limit=commit_limit, max_ops=max_ops,
                             max_steps=max_steps)
    out_specs = [
        pl.BlockSpec((max_ops, tile), lambda i: (0, i)),
        pl.BlockSpec((META_ROWS, tile), lambda i: (0, i)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((max_ops, B), jnp.int32),
        jax.ShapeDtypeStruct((META_ROWS, B), jnp.int32),
    ]
    if gpu:
        (blk,) = gpu_tail_store_shapes(cfg, tile, n_text)
        nd = len(blk.shape)
        out_specs.append(pl.BlockSpec(
            blk.shape, lambda i, nd=nd: (0,) * (nd - 1) + (i,)))
        out_shape.append(jax.ShapeDtypeStruct(blk.shape[:-1] + (B,),
                                              blk.dtype))
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((5, nw, tile), lambda i: (0, 0, i)),
            pl.BlockSpec((n_text, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=() if gpu else tail_scratch_shapes(cfg, tile, n_text),
        compiler_params=_gpu_compiler_params(tile)
        if gpu and not interpret else None,
        interpret=interpret,
    )(pm, text, m_len, n_len)
    ops, meta = out[0], out[1]       # gpu: out[2] is the discarded store
    return ops, meta
