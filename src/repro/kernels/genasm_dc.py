"""Pallas TPU kernel: improved GenASM-DC (SENE + DENT + ET).

TPU mapping (see DESIGN.md §2): one VPU *lane* per alignment problem — the
innermost axis of every array is the problem tile (TB, a multiple of 128).
Bitvector words live in small leading axes and are unrolled; all DP state
is VMEM scratch, which is the paper's point: after the three improvements
the entire traceback table fits on-chip (`vmem_bytes` below).

Grid: one program per problem tile.  Per tile:
  * level-0 row filled with a fori_loop over the W text columns,
  * levels 1..k under a while_loop with whole-tile early termination,
  * per column, the DENT band window (funnel-shift extracted, sub-word) is
    stored for the traceback-reachable columns only.

The pure-jnp oracle is kernels/ref.py (which defers to core.genasm); the
jit'd wrapper with layout marshalling is kernels/ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.config import AlignerConfig

WORD = 32


def _band_base(j, k, m_pad, nwb):
    lo = j - 2 - k
    hi = m_pad - WORD * nwb
    return jnp.clip(lo, 0, hi)


def vmem_bytes(cfg: AlignerConfig, tile: int) -> int:
    """On-chip working set per problem tile (the paper's 'fits in on-chip
    memory' claim, checked against ~16MB VMEM in tests)."""
    rows = 2 * (cfg.W + 1) * cfg.nw * tile * 4
    band = (cfg.k + 1) * cfg.ncols_band * cfg.nwb * tile * 4
    io = (5 * cfg.nw + cfg.W + 2) * tile * 4
    return rows + band + io


def _kernel(pm_ref, text_ref, band_ref, dist_ref, lvl_ref, rows_ref, *,
            cfg: AlignerConfig):
    W, k, nw, nwb = cfg.W, cfg.k, cfg.nw, cfg.nwb
    m_pad = cfg.m_pad
    ncb = cfg.ncols_band
    col0 = W + 1 - ncb
    tgt_w, tgt_o = (W - 1) // WORD, jnp.uint32((W - 1) % WORD)
    n_sym = 4

    def pm_lookup(cj):
        """cj: (TB,) int32 -> (nw, TB) mask words (sentinel -> all ones)."""
        out = []
        for w in range(nw):
            acc = jnp.full(cj.shape, 0xFFFFFFFF, jnp.uint32)
            for c in range(n_sym):
                acc = jnp.where(cj == c, pm_ref[c, w, :], acc)
            out.append(acc)
        return out

    def shift1_words(words, carry_in):
        """words: list of (TB,) uint32, LSW first."""
        out = []
        carry = carry_in
        for w in range(nw):
            out.append((words[w] << jnp.uint32(1)) | carry)
            carry = words[w] >> jnp.uint32(WORD - 1)
        return out

    def ones_below(d):
        """(nw, TB) init vector ~0 << d for traced scalar d."""
        out = []
        for w in range(nw):
            lo = jnp.clip(d - w * WORD, 0, WORD)
            val = jnp.where(lo >= WORD, jnp.uint32(0),
                            jnp.uint32(0xFFFFFFFF) << lo.astype(jnp.uint32))
            out.append(jnp.broadcast_to(val, text_ref.shape[1:]))
        return out

    def store_band(d, j, words):
        """Funnel-shift extract the band window of column j and store it."""
        base = _band_base(j, k, m_pad, nwb)
        w0 = base // WORD
        s = (base % WORD).astype(jnp.uint32)
        for b in range(nwb):
            lo = words[0]
            hi = words[0]
            for w in range(nw):          # dynamic word select, unrolled
                lo = jnp.where(w0 + b == w, words[w], lo)
                hi = jnp.where(w0 + b + 1 == w, words[w],
                               jnp.where(w0 + b + 1 >= nw, jnp.uint32(0xFFFFFFFF),
                                         hi))
            win = jnp.where(s == 0, lo, (lo >> s) | (hi << (jnp.uint32(WORD) - s)))
            @pl.when(j >= col0)
            def _():
                band_ref[d, j - col0, b, :] = win

    def row_get(parity, j):
        return [rows_ref[parity, j, w, :] for w in range(nw)]

    def row_set(parity, j, words):
        for w in range(nw):
            rows_ref[parity, j, w, :] = words[w]

    # ---------------- level 0 ----------------
    r0 = ones_below(jnp.int32(0))
    row_set(0, 0, r0)
    store_band(0, 0, r0)

    def col_body0(j, _):
        prev = row_get(0, j - 1)
        cj = text_ref[j - 1, :].astype(jnp.int32)
        pm_j = pm_lookup(cj)
        bM = ((j - 1) > 0).astype(jnp.uint32)
        r = [a | b for a, b in zip(shift1_words(prev, bM), pm_j)]
        row_set(0, j, r)
        store_band(0, j, r)
        return 0

    jax.lax.fori_loop(1, W + 1, col_body0, 0)
    last0 = row_get(0, W)
    hit0 = ((last0[tgt_w] >> tgt_o) & jnp.uint32(1)) == 0
    dist0 = jnp.where(hit0, 0, k + 1).astype(jnp.int32)

    # ---------------- levels 1..k with early termination ----------------
    def fill_level(d):
        parity, prev_par = d % 2, (d - 1) % 2
        rinit = ones_below(d)
        row_set(parity, 0, rinit)
        store_band(d, 0, rinit)

        def col_body(j, _):
            r_prev = row_get(parity, j - 1)        # R_{j-1}[d]
            p_jm1 = row_get(prev_par, j - 1)       # R_{j-1}[d-1]
            p_j = row_get(prev_par, j)             # R_j[d-1]
            cj = text_ref[j - 1, :].astype(jnp.int32)
            pm_j = pm_lookup(cj)
            t = j - 1
            bM = (t > d).astype(jnp.uint32)
            bS = (t >= d).astype(jnp.uint32)
            bI = (t >= d - 1).astype(jnp.uint32)
            M = [a | b for a, b in zip(shift1_words(r_prev, bM), pm_j)]
            S = shift1_words(p_jm1, bS)
            I = shift1_words(p_j, bI)
            r = [M[w] & S[w] & p_jm1[w] & I[w] for w in range(nw)]
            row_set(parity, j, r)
            store_band(d, j, r)
            return 0

        jax.lax.fori_loop(1, W + 1, col_body, 0)
        last = row_get(parity, W)
        return ((last[tgt_w] >> tgt_o) & jnp.uint32(1)) == 0

    # NOTE: `dist` rides in the while carry (a cond reading a mutated VMEM
    # ref would observe it one iteration late).
    def lvl_cond(state):
        d, dist = state
        go = d <= k
        if cfg.early_term:
            go &= jnp.any(dist > k)
        return go

    def lvl_body(state):
        d, dist = state
        hit = fill_level(d)
        dist = jnp.where((dist > k) & hit, d, dist).astype(jnp.int32)
        return d + 1, dist

    d_end, dist = jax.lax.while_loop(lvl_cond, lvl_body, (jnp.int32(1), dist0))
    dist_ref[0, :] = dist
    lvl_ref[0, :] = jnp.broadcast_to(d_end, lvl_ref.shape[1:]).astype(jnp.int32)


def genasm_dc_pallas(pm, text, *, cfg: AlignerConfig, tile: int = 128,
                     interpret: bool = True):
    """pm: (5, NW, B) uint32; text: (W, B) int32 (kernel layout, problems
    innermost).  Returns (dist (B,), band (k+1, ncb, nwb, B), levels (B,))."""
    _, nw, B = pm.shape
    W = text.shape[0]
    assert W == cfg.W and nw == cfg.nw and B % tile == 0
    ncb, nwb, k = cfg.ncols_band, cfg.nwb, cfg.k
    grid = (B // tile,)
    kern = functools.partial(_kernel, cfg=cfg)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((5, nw, tile), lambda i: (0, 0, i)),
            pl.BlockSpec((W, tile), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((k + 1, ncb, nwb, tile), lambda i: (0, 0, 0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k + 1, ncb, nwb, B), jnp.uint32),
            jax.ShapeDtypeStruct((1, B), jnp.int32),
            jax.ShapeDtypeStruct((1, B), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, W + 1, nw, tile), jnp.uint32),
        ],
        interpret=interpret,
    )(pm, text)
    band, dist, lvl = out
    return dist[0], band, lvl[0]
