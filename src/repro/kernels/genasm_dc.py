"""Pallas TPU kernels: improved GenASM-DC (SENE + DENT + ET) and the fused
GenASM-DC+TB pipeline that never ships the DP state off-chip.

TPU mapping (see DESIGN.md §2): one VPU *lane* per alignment problem — the
innermost axis of every array is the problem tile (TB, a multiple of 128).
Bitvector words live in small leading axes and are unrolled; all DP state
is VMEM scratch, which is the paper's point: after the three improvements
the entire traceback table fits on-chip (`vmem_bytes` below).

Grid: one program per problem tile.  Per tile:
  * level-0 row filled with a fori_loop over the W text columns,
  * levels 1..k under a while_loop with whole-tile early termination,
  * per column, the DENT band window (funnel-shift extracted, sub-word) is
    stored for the traceback-reachable columns only.

Two kernels share that DC phase (`_dc_phase`):

  * `genasm_dc_pallas` (split) — writes the DENT band to an HBM output so
    the host-side jnp traceback (core.traceback, mode='band') can walk it.
    Band traffic per tile: (k+1) * ncols_band * nwb * TB * 4 bytes each way.
  * `genasm_tb_fused_pallas` (fused) — keeps the band in VMEM scratch and
    walks GenASM-TB *inside* the kernel: the same funnel-shift band-window
    reads as `store_band`, inverted, now per-lane dynamic (each problem is
    at its own (i, j, d) DP cell, so window/column/PM lookups become
    one-hot gathers over the small static axes, vectorized across lanes).
    Only the per-problem op array (<= max_ops int32) and a meta row leave
    the chip — the band never round-trips through HBM, which is the
    bandwidth win the paper's 24x working-set compression pays for.

The traceback walk is bit-identical to core.traceback mode='band' (same
=,X,D,I preference, same commit-limit semantics); tests assert ops/dist
equality against the jnp path.

The pure-jnp oracle is kernels/ref.py (which defers to core.genasm); the
jit'd wrapper with layout marshalling is kernels/ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.config import AlignerConfig
from ..core.oracle import OP_DEL, OP_INS, OP_MATCH, OP_SUBST
from ..core.traceback import OP_NONE

WORD = 32

# meta_ref row layout of the fused kernel (8 rows for sublane alignment)
META_DIST, META_LVL, META_NOPS, META_RD, META_RF, META_DFIN, META_OK = range(7)
META_ROWS = 8


def _band_base(j, k, m_pad, nwb):
    lo = j - 2 - k
    hi = m_pad - WORD * nwb
    return jnp.clip(lo, 0, hi)


def default_max_ops(cfg: AlignerConfig) -> int:
    """Op budget of one committed window walk (= core.windowing's)."""
    return cfg.tb_max_ops


def default_max_steps(cfg: AlignerConfig) -> int:
    return cfg.tb_max_steps


def vmem_bytes(cfg: AlignerConfig, tile: int, fused: bool = False,
               max_ops: int | None = None) -> int:
    """On-chip working set per problem tile (the paper's 'fits in on-chip
    memory' claim, checked against ~16MB VMEM in tests).

    The split kernel's band is an output block, but it still occupies VMEM
    while the tile is in flight, so it is counted either way.  The fused
    kernel adds the traceback state: the op output block (max_ops words)
    plus ~16 per-lane state vectors; its band is pure scratch and never
    becomes HBM traffic.
    """
    rows = 2 * (cfg.W + 1) * cfg.nw * tile * 4
    band = (cfg.k + 1) * cfg.ncols_band * cfg.nwb * tile * 4
    io = (5 * cfg.nw + cfg.W + 2) * tile * 4
    total = rows + band + io
    if fused:
        mo = default_max_ops(cfg) if max_ops is None else max_ops
        total += (mo + META_ROWS + 16) * tile * 4
    return total


def _pm_lookup(pm_ref, cj, nw, n_sym=4):
    """cj: (TB,) int32 -> list of nw (TB,) mask words (sentinel -> all ones)."""
    out = []
    for w in range(nw):
        acc = jnp.full(cj.shape, 0xFFFFFFFF, jnp.uint32)
        for c in range(n_sym):
            acc = jnp.where(cj == c, pm_ref[c, w, :], acc)
        out.append(acc)
    return out


def _dc_phase(pm_ref, text_ref, rows_ref, band_ref, *, cfg: AlignerConfig):
    """Fill the improved GenASM-DC levels, storing DENT band windows into
    band_ref (output block or VMEM scratch).  Returns (dist, d_end)."""
    W, k, nw, nwb = cfg.W, cfg.k, cfg.nw, cfg.nwb
    m_pad = cfg.m_pad
    ncb = cfg.ncols_band
    col0 = W + 1 - ncb
    tgt_w, tgt_o = (W - 1) // WORD, jnp.uint32((W - 1) % WORD)

    def shift1_words(words, carry_in):
        """words: list of (TB,) uint32, LSW first."""
        out = []
        carry = carry_in
        for w in range(nw):
            out.append((words[w] << jnp.uint32(1)) | carry)
            carry = words[w] >> jnp.uint32(WORD - 1)
        return out

    def ones_below(d):
        """(nw, TB) init vector ~0 << d for traced scalar d."""
        out = []
        for w in range(nw):
            lo = jnp.clip(d - w * WORD, 0, WORD)
            val = jnp.where(lo >= WORD, jnp.uint32(0),
                            jnp.uint32(0xFFFFFFFF) << lo.astype(jnp.uint32))
            out.append(jnp.broadcast_to(val, text_ref.shape[1:]))
        return out

    def store_band(d, j, words):
        """Funnel-shift extract the band window of column j and store it."""
        base = _band_base(j, k, m_pad, nwb)
        w0 = base // WORD
        s = (base % WORD).astype(jnp.uint32)
        for b in range(nwb):
            lo = words[0]
            hi = words[0]
            for w in range(nw):          # dynamic word select, unrolled
                lo = jnp.where(w0 + b == w, words[w], lo)
                hi = jnp.where(w0 + b + 1 == w, words[w],
                               jnp.where(w0 + b + 1 >= nw, jnp.uint32(0xFFFFFFFF),
                                         hi))
            win = jnp.where(s == 0, lo, (lo >> s) | (hi << (jnp.uint32(WORD) - s)))
            @pl.when(j >= col0)
            def _():
                band_ref[d, j - col0, b, :] = win

    def row_get(parity, j):
        return [rows_ref[parity, j, w, :] for w in range(nw)]

    def row_set(parity, j, words):
        for w in range(nw):
            rows_ref[parity, j, w, :] = words[w]

    # ---------------- level 0 ----------------
    r0 = ones_below(jnp.int32(0))
    row_set(0, 0, r0)
    store_band(0, 0, r0)

    def col_body0(j, _):
        prev = row_get(0, j - 1)
        cj = text_ref[j - 1, :].astype(jnp.int32)
        pm_j = _pm_lookup(pm_ref, cj, nw)
        bM = ((j - 1) > 0).astype(jnp.uint32)
        r = [a | b for a, b in zip(shift1_words(prev, bM), pm_j)]
        row_set(0, j, r)
        store_band(0, j, r)
        return 0

    jax.lax.fori_loop(1, W + 1, col_body0, 0)
    last0 = row_get(0, W)
    hit0 = ((last0[tgt_w] >> tgt_o) & jnp.uint32(1)) == 0
    dist0 = jnp.where(hit0, 0, k + 1).astype(jnp.int32)

    # ---------------- levels 1..k with early termination ----------------
    def fill_level(d):
        parity, prev_par = d % 2, (d - 1) % 2
        rinit = ones_below(d)
        row_set(parity, 0, rinit)
        store_band(d, 0, rinit)

        def col_body(j, _):
            r_prev = row_get(parity, j - 1)        # R_{j-1}[d]
            p_jm1 = row_get(prev_par, j - 1)       # R_{j-1}[d-1]
            p_j = row_get(prev_par, j)             # R_j[d-1]
            cj = text_ref[j - 1, :].astype(jnp.int32)
            pm_j = _pm_lookup(pm_ref, cj, nw)
            t = j - 1
            bM = (t > d).astype(jnp.uint32)
            bS = (t >= d).astype(jnp.uint32)
            bI = (t >= d - 1).astype(jnp.uint32)
            M = [a | b for a, b in zip(shift1_words(r_prev, bM), pm_j)]
            S = shift1_words(p_jm1, bS)
            I = shift1_words(p_j, bI)
            r = [M[w] & S[w] & p_jm1[w] & I[w] for w in range(nw)]
            row_set(parity, j, r)
            store_band(d, j, r)
            return 0

        jax.lax.fori_loop(1, W + 1, col_body, 0)
        last = row_get(parity, W)
        return ((last[tgt_w] >> tgt_o) & jnp.uint32(1)) == 0

    # NOTE: `dist` rides in the while carry (a cond reading a mutated VMEM
    # ref would observe it one iteration late).
    def lvl_cond(state):
        d, dist = state
        go = d <= k
        if cfg.early_term:
            go &= jnp.any(dist > k)
        return go

    def lvl_body(state):
        d, dist = state
        hit = fill_level(d)
        dist = jnp.where((dist > k) & hit, d, dist).astype(jnp.int32)
        return d + 1, dist

    d_end, dist = jax.lax.while_loop(lvl_cond, lvl_body, (jnp.int32(1), dist0))
    return dist, d_end


def _kernel(pm_ref, text_ref, band_ref, dist_ref, lvl_ref, rows_ref, *,
            cfg: AlignerConfig):
    dist, d_end = _dc_phase(pm_ref, text_ref, rows_ref, band_ref, cfg=cfg)
    dist_ref[0, :] = dist
    lvl_ref[0, :] = jnp.broadcast_to(d_end, lvl_ref.shape[1:]).astype(jnp.int32)


def _kernel_fused(pm_ref, text_ref, ops_ref, meta_ref, rows_ref, band_ref, *,
                  cfg: AlignerConfig, commit_limit: int, max_ops: int,
                  max_steps: int):
    """DC phase into VMEM scratch, then GenASM-TB walked in-kernel.

    The walk mirrors core.traceback (mode='band') bit for bit: SENE edge
    availability is recomputed from neighbouring stored band windows + the
    PM masks, with the =,X,D,I preference order, a per-lane tail drain, and
    the commit-limit stop.  Per-lane dynamic (d, j) band reads use one-hot
    sums over the small static (k+1, ncols_band) axes — the inverted form
    of store_band's funnel-shift stores.
    """
    W, k, nw, nwb = cfg.W, cfg.k, cfg.nw, cfg.nwb
    m_pad = cfg.m_pad
    ncb = cfg.ncols_band
    col0 = W + 1 - ncb
    TB = text_ref.shape[1]
    u1 = jnp.uint32(1)

    # uncomputed (early-terminated) levels must read as zero, like the jnp
    # path's zeros-initialized band buffer
    band_ref[:, :, :, :] = jnp.zeros((k + 1, ncb, nwb, TB), jnp.uint32)

    dist, d_end = _dc_phase(pm_ref, text_ref, rows_ref, band_ref, cfg=cfg)

    # ---------------- traceback phase ----------------
    d_ids = jax.lax.broadcasted_iota(jnp.int32, (k + 1, ncb, TB), 0)
    s_ids = jax.lax.broadcasted_iota(jnp.int32, (k + 1, ncb, TB), 1)
    t_ids = jax.lax.broadcasted_iota(jnp.int32, (W, TB), 0)
    slot_ids = jax.lax.broadcasted_iota(jnp.int32, (max_ops, TB), 0)

    def band_words(dd, jj):
        """Per-lane gather of the stored band window of (level dd, col jj),
        clipped like core.traceback._zbit_band."""
        onehot = ((d_ids == jnp.clip(dd, 0, k)[None, None, :]) &
                  (s_ids == jnp.clip(jj - col0, 0, ncb - 1)[None, None, :]))
        return [jnp.sum(jnp.where(onehot, band_ref[:, :, b, :], jnp.uint32(0)),
                        axis=(0, 1), dtype=jnp.uint32) for b in range(nwb)]

    def zbit(words, dd, jj, ii):
        """bit ii of the band window == 0; ii == -1 encodes the DP's first
        column: ED(0, jj) <= dd  ⟺  jj <= dd."""
        base = _band_base(jj, k, m_pad, nwb)
        off = ii - base
        inband = (off >= 0) & (off < nwb * WORD)
        offc = jnp.clip(off, 0, nwb * WORD - 1)
        w0 = offc // WORD
        o = (offc % WORD).astype(jnp.uint32)
        word = words[0]
        for b in range(1, nwb):
            word = jnp.where(w0 == b, words[b], word)
        bit = (word >> o) & u1
        return jnp.where(ii < 0, jj <= dd, (bit == 0) & inband)

    def text_at(jj):
        """text char of column jj (= text index jj-1, clipped)."""
        onehot = t_ids == jnp.clip(jj - 1, 0, W - 1)[None, :]
        return jnp.sum(jnp.where(onehot, text_ref[:, :], 0),
                       axis=0).astype(jnp.int32)

    def peq_at(cj, ii):
        """P[ii] == text char cj, via the PM masks (sentinels never match)."""
        words = _pm_lookup(pm_ref, cj, nw)
        iic = jnp.clip(ii, 0, m_pad - 1)
        w0 = iic // WORD
        o = (iic % WORD).astype(jnp.uint32)
        word = words[0]
        for w in range(1, nw):
            word = jnp.where(w0 == w, words[w], word)
        return ((word >> o) & u1) == 0

    def body(state):
        i, j, d, nops, ops, rd, rf, done, ok = state
        tail = i < 0
        stopped = rd >= commit_limit
        active = ~done & ~stopped

        w_d_jm1 = band_words(d, j - 1)
        w_dm1_jm1 = band_words(d - 1, j - 1)
        w_dm1_j = band_words(d - 1, j)
        peq = peq_at(text_at(j), i)
        mA = (j > 0) & peq & zbit(w_d_jm1, d, j - 1, i - 1)
        sA = (j > 0) & (d > 0) & zbit(w_dm1_jm1, d - 1, j - 1, i - 1)
        dA = (j > 0) & (d > 0) & zbit(w_dm1_jm1, d - 1, j - 1, i)
        iA = (d > 0) & zbit(w_dm1_j, d - 1, j, i - 1)

        # tail: pattern exhausted, drain remaining text as deletions
        tail_emit = tail & (j > 0)
        mA &= ~tail; sA &= ~tail; dA &= ~tail; iA &= ~tail

        any_edge = mA | sA | dA | iA | tail_emit
        # exclusive choice with GenASM's =,X,D,I preference
        cM = mA
        cS = ~mA & sA
        cD = ~mA & ~sA & dA
        cI = ~mA & ~sA & ~dA & iA
        op = jnp.where(cM, OP_MATCH,
             jnp.where(cS, OP_SUBST,
             jnp.where(cD, OP_DEL,
             jnp.where(cI, OP_INS, OP_DEL)))).astype(jnp.int32)

        takes_read = active & (cM | cS | cI)
        takes_ref = active & (cM | cS | cD | tail_emit)
        costs = active & (cS | cD | cI | tail_emit)

        new_i = jnp.where(takes_read, i - 1, i)
        new_j = jnp.where(takes_ref, j - 1, j)
        new_d = jnp.where(costs, d - 1, d)
        new_rd = rd + takes_read
        new_rf = rf + takes_ref

        emit = active & any_edge
        slot = jnp.where(emit, nops, max_ops)   # max_ops -> no iota row: drop
        ops = jnp.where(slot_ids == slot[None, :], op[None, :], ops)
        nops = nops + emit

        finished = (new_i < 0) & (new_j <= 0)
        new_done = done | (active & finished)
        # invariant: an active, unfinished cell always has an available edge
        ok &= jnp.where(active & ~finished, any_edge | ((i < 0) & (j <= 0)), True)
        return (new_i, new_j, new_d, nops, ops, new_rd, new_rf,
                new_done | stopped, ok)

    def walk_body(step, state):
        del step
        return jax.lax.cond(jnp.any(~state[7]), body, lambda s: s, state)

    zeros = jnp.zeros((TB,), jnp.int32)
    skip = dist > k
    init = (
        jnp.full((TB,), W - 1, jnp.int32),          # i (m_len - 1)
        jnp.full((TB,), W, jnp.int32),              # j (n_len)
        dist,                                       # d
        zeros,                                      # nops
        jnp.full((max_ops, TB), OP_NONE, jnp.int32),
        zeros,                                      # read_adv
        zeros,                                      # ref_adv
        skip,                                       # done
        jnp.ones((TB,), bool),                      # ok
    )
    i, j, d, nops, ops, rd, rf, done, ok = jax.lax.fori_loop(
        0, max_steps, walk_body, init)

    ops_ref[:, :] = ops
    meta_ref[META_DIST, :] = dist
    meta_ref[META_LVL, :] = jnp.broadcast_to(d_end, (TB,)).astype(jnp.int32)
    meta_ref[META_NOPS, :] = nops
    meta_ref[META_RD, :] = rd
    meta_ref[META_RF, :] = rf
    meta_ref[META_DFIN, :] = d
    meta_ref[META_OK, :] = ok.astype(jnp.int32)
    meta_ref[META_ROWS - 1, :] = zeros


def genasm_dc_pallas(pm, text, *, cfg: AlignerConfig, tile: int = 128,
                     interpret: bool = True):
    """pm: (5, NW, B) uint32; text: (W, B) int32 (kernel layout, problems
    innermost).  Returns (dist (B,), band (k+1, ncb, nwb, B), levels (B,))."""
    _, nw, B = pm.shape
    W = text.shape[0]
    assert W == cfg.W and nw == cfg.nw and B % tile == 0
    ncb, nwb, k = cfg.ncols_band, cfg.nwb, cfg.k
    grid = (B // tile,)
    kern = functools.partial(_kernel, cfg=cfg)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((5, nw, tile), lambda i: (0, 0, i)),
            pl.BlockSpec((W, tile), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((k + 1, ncb, nwb, tile), lambda i: (0, 0, 0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k + 1, ncb, nwb, B), jnp.uint32),
            jax.ShapeDtypeStruct((1, B), jnp.int32),
            jax.ShapeDtypeStruct((1, B), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, W + 1, nw, tile), jnp.uint32),
        ],
        interpret=interpret,
    )(pm, text)
    band, dist, lvl = out
    return dist[0], band, lvl[0]


def genasm_tb_fused_pallas(pm, text, *, cfg: AlignerConfig, commit_limit: int,
                           max_ops: int | None = None,
                           max_steps: int | None = None, tile: int = 128,
                           interpret: bool = True):
    """Fused DC+TB.  pm: (5, NW, B) uint32; text: (W, B) int32 (kernel
    layout).  Returns (ops (max_ops, B) int32 front-first with OP_NONE
    padding, meta (META_ROWS, B) int32 — see META_* row constants).  The
    DENT band lives and dies in VMEM scratch."""
    _, nw, B = pm.shape
    W = text.shape[0]
    assert W == cfg.W and nw == cfg.nw and B % tile == 0
    if max_ops is None:
        max_ops = default_max_ops(cfg)
    if max_steps is None:
        max_steps = default_max_steps(cfg)
    ncb, nwb, k = cfg.ncols_band, cfg.nwb, cfg.k
    grid = (B // tile,)
    kern = functools.partial(_kernel_fused, cfg=cfg, commit_limit=commit_limit,
                             max_ops=max_ops, max_steps=max_steps)
    ops, meta = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((5, nw, tile), lambda i: (0, 0, i)),
            pl.BlockSpec((W, tile), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((max_ops, tile), lambda i: (0, i)),
            pl.BlockSpec((META_ROWS, tile), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((max_ops, B), jnp.int32),
            jax.ShapeDtypeStruct((META_ROWS, B), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, W + 1, nw, tile), jnp.uint32),
            pltpu.VMEM((k + 1, ncb, nwb, tile), jnp.uint32),
        ],
        interpret=interpret,
    )(pm, text)
    return ops, meta
