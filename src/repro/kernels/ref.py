"""Pure-jnp oracle for the Pallas GenASM-DC kernel.

Defers to core.genasm.dc_dmajor (itself validated against the classic
Levenshtein DP in tests) and reshapes to the kernel's output layout.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core.config import AlignerConfig
from ..core.genasm import dc_dmajor


def genasm_dc_ref(pat_codes, text_codes, *, cfg: AlignerConfig):
    """pat/text: (B, W) standard layout.  Returns (dist (B,),
    band (k+1, ncb, nwb, B), levels ()) matching kernels.genasm_dc."""
    res = dc_dmajor(pat_codes, text_codes, cfg=cfg)
    band = jnp.transpose(res.store["Rb"], (0, 1, 3, 2))  # (K1, ncb, nwb, B)
    return res.dist, band, res.levels_run
