"""Gemma-2 2B [arXiv:2408.00118]. Alternating local(4096)/global attention,
attn/final logit softcaps, sandwich norms, GeGLU, scaled+tied embeddings."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_head=256,
    d_ff=9216, vocab=256000,
    sliding_window=4096, local_global_every=2,
    attn_softcap=50.0, final_softcap=30.0,
    post_block_norm=True, scale_embed=True, tie_embeddings=True,
    act="gelu",
)
