"""xLSTM-125M [arXiv:2405.04517]. mLSTM blocks with an sLSTM block every
8th layer (xLSTM[7:1]); d_ff=0 — projections live inside the blocks."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    slstm_every=8,
)
