"""Zamba2-2.7B [arXiv:2411.15242]. Mamba2 backbone (state 64) + shared
attention block every 6 layers (kv=32 MHA over d=2560)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_conv=4, ssm_expand=2,
    shared_attn_every=6,
)
