"""MusicGen-medium [arXiv:2306.05284]. Decoder-only over EnCodec tokens;
4 codebooks (delay pattern is a data-pipeline concern; the EnCodec frontend
is a stub — input_specs supplies summed frame embeddings)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab=2048,
    n_codebooks=4, act="gelu",
)
