"""The paper's own configuration: GenASM window geometry + improvements."""
from ..core.config import AlignerConfig

CONFIG = AlignerConfig(W=64, O=24, k=12, store="band", early_term=True)

# unimproved baseline (GenASM as in MICRO'20: 4 edge bitvectors, no ET)
BASELINE = AlignerConfig(W=64, O=24, k=12, store="edges4", early_term=False)
