"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B family; assignment row].
128 experts top-8, GQA kv=4, per-expert FFN 1536."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=1536, vocab=151936,
    n_experts=128, top_k=8, norm_topk_prob=True, router_aux_coef=0.001,
    rope_theta=1_000_000.0,
)
