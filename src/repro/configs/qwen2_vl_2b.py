"""Qwen2-VL-2B [arXiv:2409.12191]. M-RoPE (t/h/w sections); the vision
frontend is a stub per the assignment — input_specs supplies pre-merged
embeddings and 3D rotary position ids."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_head=128,
    d_ff=8960, vocab=151936,
    qkv_bias=True, mrope_sections=(16, 24, 24),
)
