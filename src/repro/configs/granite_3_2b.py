"""Granite-3.0-2B-base [hf:ibm-granite/granite-3.0-2b-base].
GQA kv=8 with depth-scaled (muP-like) multipliers."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab=49155,
    embedding_multiplier=12.0, residual_multiplier=0.22,
    attention_multiplier=0.015625, logits_scaling=8.0,
    tie_embeddings=True,
)
