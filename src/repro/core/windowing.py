"""Windowed long-read alignment (GenASM's W/O windowing, batched + jittable).

A (read, candidate-ref-segment) pair is aligned as a sequence of W x W
windows: DC+TB inside the window (on *reversed* window contents, so the
traceback emits front-first ops), commit the first W-O read characters'
worth of operations, advance read by exactly W-O and ref by the committed
ref consumption, repeat.  The final <= W read chars are aligned in a single
"tail" window against the remaining reference (end-to-end).

All problems advance in lockstep (read stride is uniform); problems whose
window edit distance exceeds k are flagged `failed` (callers may rescue by
re-running those pairs with a larger k, see core.aligner).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .bitops import SENTINEL_PAT, SENTINEL_TEXT
from .config import AlignerConfig
from .genasm import dc, dc_jmajor
from .traceback import OP_NONE, traceback

SENTINEL_READ = SENTINEL_PAT    # never matches (out of PM alphabet)
SENTINEL_REF = SENTINEL_TEXT    # maps to the all-ones PM row


def n_main_windows(max_read_len: int, cfg: AlignerConfig) -> int:
    """Windows before every problem's remaining read length is <= W."""
    return max(0, -(-(max_read_len - cfg.W) // cfg.stride))


def total_op_budget(max_read_len: int, cfg: AlignerConfig) -> int:
    nm = n_main_windows(max_read_len, cfg)
    return nm * (cfg.stride + cfg.k) + cfg.W + self_tail_width(cfg)


def self_tail_width(cfg: AlignerConfig) -> int:
    return cfg.W + 4 * cfg.k


# ---- bucket-shaped geometry (the session front door's shape classes) ----
#
# `repro.api.AlignSession` never derives pad widths from a batch's ragged
# max_read_len: it quantises lengths to power-of-two BUCKETS and compiles
# one executable per bucket.  These helpers are the single source of truth
# for that geometry — the legacy aligner's exact-shape path uses the same
# pad_geometry so both doors stay bit-identical.

def pow2_bucket(n: int, floor: int = 1) -> int:
    """Smallest power of two >= max(n, floor): the static length class a
    ragged length is padded into."""
    assert n >= 0 and floor >= 1
    b = 1 << max(n - 1, floor - 1, 0).bit_length()
    return max(b, floor)


def pad_geometry(cfg: AlignerConfig, max_read_len: int, max_ref_len: int,
                 rescue_rounds: int = 0) -> tuple[int, int]:
    """(Lr, Lf) padded array widths for a (read, ref) length class: reads
    carry >= W sentinels past read_len, refs enough for the FINAL rescue
    round's tail width (the contract of align_pairs / align_pairs_rescued)."""
    wt = self_tail_width(rescue_schedule(cfg, rescue_rounds)[-1])
    return max_read_len + cfg.W + 1, max_ref_len + cfg.W + wt + 1


def bucket_avals(cfg: AlignerConfig, lanes: int, read_bucket: int,
                 ref_bucket: int, rescue_rounds: int = 0):
    """ShapeDtypeStructs of one bucket's batch — what the session AOT-lowers
    an executable against (see repro.api.CompileCache)."""
    Lr, Lf = pad_geometry(cfg, read_bucket, ref_bucket, rescue_rounds)
    sds = jax.ShapeDtypeStruct
    return (sds((lanes, Lr), jnp.uint8), sds((lanes,), jnp.int32),
            sds((lanes, Lf), jnp.uint8), sds((lanes,), jnp.int32))


#: GPU lane-tile planning constants: the quantum is a warp (32 threads,
#: one lane per thread), the ceiling a CTA (1024 threads), and the budget
#: one SM's 32-bit register file (64K registers) — the live DP columns are
#: the Triton mapping's binding resource, not scratch bytes (the band
#: store is GMEM-backed on that path; see core.counting.gpu_*).
GPU_LANE_QUANTUM = 32
GPU_LANE_CEILING = 1024
GPU_REG_BUDGET_WORDS = 64 * 1024


def plan_lane_tile(cfg: AlignerConfig, vmem_budget_bytes: int = 16 * 2**20,
                   quantum: int = 128, ceiling: int = 4096,
                   reg_budget_words: int = GPU_REG_BUDGET_WORDS) -> int:
    """Largest lane tile whose kernels fit the backend's on-chip budget.

    TPU backends (and jnp, which shares their geometry when a pallas
    backend is swapped in later): the largest multiple of `quantum` (the
    VPU lane width) whose square fused kernel AND tail kernel VMEM scratch
    both fit `vmem_budget_bytes`.  This is where the tentpole's reclaimed
    bytes get *spent*: the tail kernel's store was the binding constraint,
    and the Scrooge-style band (cfg.tail_banded) roughly halves it at the
    default geometry, so the planner's ceiling doubles — more lanes per
    kernel launch, fewer grid steps per batch.

    backend='pallas_gpu': a *register* model instead — the Triton lowering
    keeps the band store in GMEM (no scratch memory) and the live DP
    columns in registers, so the tile is the largest multiple of a warp
    (GPU_LANE_QUANTUM) whose per-lane live state
    (core.counting.gpu_lane_state_words) fits `reg_budget_words`, capped
    at a CTA (GPU_LANE_CEILING).

    Sessions opt in with plan(..., lane_tile='auto') (repro.api); the
    bucket pad unit (lane_tile * n_shards) follows automatically through
    kernels.ops._pad_unit.  Raises ValueError (naming the W/k geometry and
    bytes) when even one quantum of lanes over-commits the budget —
    flooring silently would launch kernels past the budget."""
    from .counting import (gpu_lane_state_words, kernel_scratch_words,
                           tail_scratch_words)
    if cfg.backend == "pallas_gpu":
        per_lane = gpu_lane_state_words(cfg)
        tile = (reg_budget_words // (per_lane * GPU_LANE_QUANTUM)) \
            * GPU_LANE_QUANTUM
        if tile == 0:
            raise ValueError(
                f"one warp of live DP state does not fit the register "
                f"budget: geometry W={cfg.W} k={cfg.k} needs "
                f"{per_lane * GPU_LANE_QUANTUM:,} words for "
                f"{GPU_LANE_QUANTUM} lanes but reg_budget_words="
                f"{reg_budget_words:,}")
        return int(min(tile, GPU_LANE_CEILING))
    assert quantum > 0 and ceiling >= quantum
    per_lane = 4 * max(kernel_scratch_words(cfg, 1),
                       tail_scratch_words(cfg, 1))
    tile = (vmem_budget_bytes // (per_lane * quantum)) * quantum
    if tile == 0:
        # flooring to one quantum here would SILENTLY over-commit VMEM:
        # the caller asked for a budget the geometry cannot meet, and the
        # kernel would launch with more scratch than the budget allows
        raise ValueError(
            f"one lane quantum of scratch does not fit the VMEM budget: "
            f"geometry W={cfg.W} k={cfg.k} needs {per_lane * quantum:,} "
            f"bytes for {quantum} lanes but vmem_budget_bytes="
            f"{vmem_budget_bytes:,}")
    return int(min(tile, ceiling))


def _slice_rev(seq, pos, width, length):
    """Per-problem: take seq[pos:pos+width], reversed, with the `length` real
    chars packed at the front (sentinel padding after).  seq must be padded
    with >= width sentinels at the end."""
    def one(s, p, ln):
        w = jax.lax.dynamic_slice(s, (p,), (width,))
        rev = w[::-1]
        idx = (jnp.arange(width) + (width - ln)) % width
        return rev[idx]
    return jax.vmap(one)(seq, pos, length)


def _append_ops(buf, off, ops, nops, active):
    """Scatter window ops into the per-problem op buffer at offset `off`
    (vmapped per row: keeps the scatter local to each batch shard)."""
    B, max_w = ops.shape
    pos = off[:, None] + jnp.arange(max_w, dtype=jnp.int32)[None, :]
    valid = (jnp.arange(max_w)[None, :] < nops[:, None]) & active[:, None]
    pos = jnp.where(valid, pos, buf.shape[1])  # OOB -> dropped
    return jax.vmap(lambda row, px, ox: row.at[px].set(ox, mode="drop"))(
        buf, pos, ops)


@partial(jax.jit, static_argnames=("cfg", "max_read_len", "mesh"))
def align_pairs(reads, read_len, refs, ref_len, *, cfg: AlignerConfig,
                max_read_len: int, mesh=None):
    """Batched windowed alignment.

    reads: (B, Lr_pad) uint8 codes, sentinel-padded by >= W past read_len.
    refs:  (B, Lf_pad) uint8 codes, sentinel-padded by >= W+4k past ref_len.
    Returns dict with front-first op buffer, n_ops, dist, failed, read/ref
    consumption, and window ET stats.

    `mesh`: shard the pair axis over the mesh's data axes — the Pallas
    dispatches run under shard_map (each device fills/walks its local
    lanes on-chip) and the jnp paths are GSPMD-constrained.  Bit-identical
    to the unsharded run on every output (tests/test_multidevice.py).
    """
    from ..distributed.sharding import constrain_pairs
    reads, read_len, refs, ref_len = constrain_pairs(
        mesh, reads, read_len, refs, ref_len)
    B = reads.shape[0]
    W, O, k, stride = cfg.W, cfg.O, cfg.k, cfg.stride
    nm = n_main_windows(max_read_len, cfg)
    wt = self_tail_width(cfg)
    op_budget = total_op_budget(max_read_len, cfg)
    max_ops_w = cfg.tb_max_ops
    max_steps_w = cfg.tb_max_steps
    max_ops_t = W + wt
    max_steps_t = W + wt + 4

    read_len = jnp.asarray(read_len, jnp.int32)
    ref_len = jnp.asarray(ref_len, jnp.int32)

    def append_main(carry, _):
        (read_pos, ref_pos, off, dist, failed, levels), buf = carry
        active = (read_len - read_pos > W) & ~failed
        wfull = jnp.full((B,), W, jnp.int32)
        pat = _slice_rev(reads, read_pos, W, wfull)
        txt = _slice_rev(refs, ref_pos, W, wfull)
        if cfg.store == "band" and cfg.backend in ("pallas_fused",
                                                   "pallas_gpu"):
            # fused kernel: DC + committed traceback in one Pallas call, the
            # DENT band never leaves the chip — no host-side traceback walk
            # ('pallas_gpu' lowers the same kernel body through Triton)
            from ..kernels.ops import default_interpret, genasm_tb_fused_op
            tb = genasm_tb_fused_op(pat, txt, cfg=cfg, commit_limit=stride,
                                    max_ops=max_ops_w, max_steps=max_steps_w,
                                    interpret=default_interpret(cfg.backend),
                                    mesh=mesh)
            solved, levels_run = tb["solved"], tb["levels"]
        else:
            res = dc(pat, txt, wfull, wfull, cfg, mesh=mesh)
            tb = traceback(res.store, pat, txt, wfull, wfull,
                           res.dist, jnp.int32(stride), cfg=cfg,
                           mode=cfg.store, max_ops=max_ops_w,
                           max_steps=max_steps_w)
            solved, levels_run = res.solved, res.levels_run
        commit = active & solved
        buf = _append_ops(buf, off, tb["ops"], jnp.where(commit, tb["n_ops"], 0),
                          commit)
        st = (
            jnp.where(commit, read_pos + tb["read_adv"], read_pos),
            jnp.where(commit, ref_pos + tb["ref_adv"], ref_pos),
            jnp.where(commit, off + tb["n_ops"], off),
            jnp.where(commit, dist + tb["cost"], dist),
            failed | (active & ~solved),
            levels + levels_run,
        )
        return (st, buf), None

    buf = jnp.full((B, op_budget), OP_NONE, jnp.uint8)
    state = (jnp.zeros((B,), jnp.int32), jnp.zeros((B,), jnp.int32),
             jnp.zeros((B,), jnp.int32), jnp.zeros((B,), jnp.int32),
             jnp.zeros((B,), bool), jnp.int32(0))
    (state, buf), _ = jax.lax.scan(append_main, (state, buf), None, length=nm)
    read_pos, ref_pos, off, dist, failed, levels = state

    # ---- tail window: remaining read (in (O, W]) vs remaining ref, global ----
    m_tail = jnp.clip(read_len - read_pos, 0, W)
    n_rem = ref_len - ref_pos
    n_tail = jnp.clip(n_rem, 0, wt)
    tail_bad = (n_rem > wt) | (n_rem < jnp.maximum(m_tail - 2 * k, 0))
    pat_t = _slice_rev(reads, read_pos, W, m_tail)
    txt_t = _slice_rev(refs, ref_pos, wt, n_tail)
    if cfg.store == "band" and cfg.backend in ("pallas_fused", "pallas_gpu"):
        # rectangular-tail fused kernel: the tail's SENE store is walked
        # on-chip too, so whole-read alignment never ships DP state to
        # HBM (bit-identical to the jnp 'and'-store path below)
        from ..kernels.ops import default_interpret, genasm_tail_fused_op
        tb_t = genasm_tail_fused_op(pat_t, txt_t, m_tail, n_tail, cfg=cfg,
                                    n_text=wt, commit_limit=2 * (W + wt),
                                    max_ops=max_ops_t, max_steps=max_steps_t,
                                    interpret=default_interpret(cfg.backend),
                                    mesh=mesh)
        solved_t = tb_t["solved"]
    else:
        res_t = dc_jmajor(pat_t, txt_t, m_tail, n_tail, k=k, n=wt, nw=cfg.nw,
                          store="and")
        tb_t = traceback(res_t.store, pat_t, txt_t, m_tail, n_tail, res_t.dist,
                         jnp.int32(2 * (W + wt)), cfg=cfg, mode="and",
                         max_ops=max_ops_t, max_steps=max_steps_t)
        solved_t = res_t.solved
    t_ok = ~failed & ~tail_bad & solved_t
    buf = _append_ops(buf, off, tb_t["ops"], jnp.where(t_ok, tb_t["n_ops"], 0),
                      t_ok)
    n_ops = jnp.where(t_ok, off + tb_t["n_ops"], off)
    dist = jnp.where(t_ok, dist + tb_t["cost"], dist)
    failed = failed | tail_bad | ~solved_t
    read_end = jnp.where(t_ok, read_pos + tb_t["read_adv"], read_pos)
    ref_end = jnp.where(t_ok, ref_pos + tb_t["ref_adv"], ref_pos)

    return {"ops": buf, "n_ops": n_ops, "dist": dist, "failed": failed,
            "read_consumed": read_end, "ref_consumed": ref_end,
            "levels_run_total": levels, "n_main_windows": jnp.int32(nm)}


def rescue_schedule(cfg: AlignerConfig, rescue_rounds: int):
    """The k-doubling ladder: round r runs with k_r = min(k * 2**r, W - 1),
    deduplicated once the cap is hit.  Single source of truth for the
    host-loop and on-device rescue paths (and for padding geometry)."""
    cfgs = [cfg]
    for _ in range(rescue_rounds):
        new_k = min(cfgs[-1].k * 2, cfg.W - 1)
        if new_k == cfgs[-1].k:
            break
        cfgs.append(dataclasses.replace(cfgs[-1], k=new_k))
    return tuple(cfgs)


@partial(jax.jit,
         static_argnames=("cfg", "max_read_len", "rescue_rounds", "mesh"))
def align_pairs_rescued(reads, read_len, refs, ref_len, *, cfg: AlignerConfig,
                        max_read_len: int, rescue_rounds: int = 2, mesh=None):
    """Multi-round k-doubling rescue, entirely on-device: one compile, zero
    host round-trips between rounds.

    Round 0 is plain ``align_pairs``; each later round re-runs the whole
    batch with doubled k under a ``lax.cond`` gate (skipped outright when no
    lane is still failed), and a per-lane mask freezes already-solved lanes
    so their ops/dist/k_used never change — bit-identical per lane to the
    host numpy rescue loop in core.aligner.

    refs must be sentinel-padded for the FINAL round's tail width
    (``self_tail_width(rescue_schedule(cfg, rescue_rounds)[-1])``); reads
    need the usual >= W padding.  Returns the align_pairs dict plus k_used
    (0 where never solved), rounds_run and n_rounds.

    `mesh` threads through to every round's align_pairs: the whole ladder
    runs sharded over the pair axes, and the `any(failed)` round gate is a
    GLOBAL any (GSPMD reduces it across shards), so a round runs on every
    device whenever any shard still has a failed lane — exactly the
    single-device schedule, hence bit-identical results.
    """
    cfgs = rescue_schedule(cfg, rescue_rounds)
    B = reads.shape[0]
    budget = total_op_budget(max_read_len, cfgs[-1])
    ops = jnp.full((B, budget), OP_NONE, jnp.uint8)
    n_ops = jnp.zeros((B,), jnp.int32)
    dist = jnp.zeros((B,), jnp.int32)
    rcon = jnp.zeros((B,), jnp.int32)
    fcon = jnp.zeros((B,), jnp.int32)
    k_used = jnp.zeros((B,), jnp.int32)
    failed = jnp.ones((B,), bool)
    levels = jnp.int32(0)
    rounds_run = jnp.int32(0)

    for rnd, cfg_r in enumerate(cfgs):
        def run_round(cfg_r=cfg_r):
            return align_pairs(reads, read_len, refs, ref_len, cfg=cfg_r,
                               max_read_len=max_read_len, mesh=mesh)
        if rnd == 0:
            out = run_round()
            ran = jnp.bool_(True)
        else:
            ran = jnp.any(failed)
            spec = jax.eval_shape(run_round)

            def skip_round(spec=spec):
                z = jax.tree_util.tree_map(
                    lambda s: jnp.zeros(s.shape, s.dtype), spec)
                z["failed"] = jnp.ones((B,), bool)  # nothing merges
                return z

            out = jax.lax.cond(ran, run_round, skip_round)
        newly = failed & ~out["failed"]
        # final round also merges the partial progress (committed main-window
        # ops/dist) of still-failed lanes, so rescue_rounds=0 is bit-equal to
        # plain align_pairs; a skipped final round has no failed lanes.
        upd = newly
        if rnd == len(cfgs) - 1:
            upd = newly | (failed & out["failed"])
        ops_r = jnp.pad(out["ops"], ((0, 0), (0, budget - out["ops"].shape[1])),
                        constant_values=OP_NONE)
        ops = jnp.where(upd[:, None], ops_r, ops)
        n_ops = jnp.where(upd, out["n_ops"], n_ops)
        dist = jnp.where(upd, out["dist"], dist)
        rcon = jnp.where(upd, out["read_consumed"], rcon)
        fcon = jnp.where(upd, out["ref_consumed"], fcon)
        k_used = jnp.where(newly, jnp.int32(cfg_r.k), k_used)
        failed = failed & out["failed"]
        levels = levels + out["levels_run_total"]
        rounds_run = rounds_run + ran.astype(jnp.int32)

    return {"ops": ops, "n_ops": n_ops, "dist": dist, "failed": failed,
            "k_used": k_used, "read_consumed": rcon, "ref_consumed": fcon,
            "levels_run_total": levels, "rounds_run": rounds_run,
            "n_rounds": jnp.int32(len(cfgs))}
