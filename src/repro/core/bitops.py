"""Multi-word bitvector primitives for the GenASM family of algorithms.

TPU adaptation: TPU integer lanes are 32-bit, so an m-bit status vector is a
vector of ``NW = ceil(m/32)`` uint32 words, word 0 = least significant.  All
operations are elementwise VPU-friendly ops batched over arbitrary leading
dimensions; the word dimension is always the innermost axis.

Bit convention (GenASM / Wu-Manber "0-active"): bit i == 0 means *active*
("pattern prefix P[0..i] is alignable under the current budget").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

WORD_BITS = 32
_U1 = jnp.uint32(1)
_UFULL = jnp.uint32(0xFFFFFFFF)

# Alphabet + pad sentinels, shared by every layer that pads sequences
# (core.genasm, core.windowing, kernels.ops).  Both sentinels derive from
# the alphabet size and must stay distinct from each other:
#   * SENTINEL_PAT pads patterns/reads: out of any alphabet, so build_pm
#     leaves its bits 1 (never matches) and it never equals a text char.
#   * SENTINEL_TEXT pads texts/refs: any code >= N_SYMBOLS selects the
#     all-ones PM row (build_pm_ext) / the all-ones default in the Pallas
#     kernel's pm_lookup, and != SENTINEL_PAT so pad-vs-pad never matches.
N_SYMBOLS = 4
SENTINEL_PAT = 255
SENTINEL_TEXT = N_SYMBOLS + 5
assert SENTINEL_PAT != SENTINEL_TEXT and SENTINEL_TEXT >= N_SYMBOLS


def n_words(m_bits: int) -> int:
    return -(-m_bits // WORD_BITS)


def shift1(v: jnp.ndarray, carry_in) -> jnp.ndarray:
    """Shift a (..., NW) uint32 word-vector left by one bit.

    ``carry_in`` (0/1, scalar or broadcastable to v[..., 0]) enters at bit 0.
    GenASM uses this for the M/S/I terms; the carry bit encodes the DP's
    first-column boundary condition (see genasm.py).
    """
    carry_in = jnp.asarray(carry_in, jnp.uint32)
    hi = v >> jnp.uint32(WORD_BITS - 1)
    carry = jnp.concatenate(
        [jnp.broadcast_to(carry_in, v[..., :1].shape), hi[..., :-1]], axis=-1
    )
    return (v << _U1) | carry


def get_bit(v: jnp.ndarray, idx) -> jnp.ndarray:
    """Extract bit ``idx`` (int array broadcastable over v's batch dims) from a
    (..., NW) word vector.  Returns uint32 in {0, 1}."""
    idx = jnp.broadcast_to(jnp.asarray(idx, jnp.int32), v.shape[:-1])
    word = idx // WORD_BITS
    off = (idx % WORD_BITS).astype(jnp.uint32)
    w = jnp.take_along_axis(v, word[..., None], axis=-1)[..., 0]
    return (w >> off) & _U1


def ones_below(d, nw: int) -> jnp.ndarray:
    """Word vector whose ``d`` lowest bits are 0 and the rest 1:  ~0 << d.

    This is the GenASM-DC init for error level d (d pattern chars can be
    consumed by insertions before any text is read).  ``d`` may be an array;
    result shape = d.shape + (nw,).
    """
    d = jnp.asarray(d, jnp.int32)[..., None]
    base = jnp.arange(nw, dtype=jnp.int32) * WORD_BITS
    lo = jnp.clip(d - base, 0, WORD_BITS)
    # lo lowest bits of each word are zero
    return jnp.where(
        lo >= WORD_BITS,
        jnp.uint32(0),
        _UFULL << lo.astype(jnp.uint32),
    )


def build_pm(pat_codes: jnp.ndarray, nw: int,
             n_symbols: int = N_SYMBOLS) -> jnp.ndarray:
    """Pattern bitmasks PM[c]: bit i == 0 iff P[i] == c.

    pat_codes: (..., m) integer codes; positions past the true pattern length
    must hold an out-of-alphabet sentinel (SENTINEL_PAT) so their bits are 1
    (inactive). Returns (..., n_symbols, NW) uint32.
    """
    m_pad = nw * WORD_BITS
    pad = m_pad - pat_codes.shape[-1]
    if pad:
        pat_codes = jnp.pad(pat_codes, [(0, 0)] * (pat_codes.ndim - 1) + [(0, pad)],
                            constant_values=SENTINEL_PAT)
    sym = jnp.arange(n_symbols, dtype=pat_codes.dtype)
    # mismatch bit = 1 where P[i] != c
    mm = (pat_codes[..., None, :] != sym[:, None]).astype(jnp.uint32)
    mm = mm.reshape(*mm.shape[:-1], nw, WORD_BITS)
    weights = _U1 << jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(mm * weights, axis=-1, dtype=jnp.uint32)


def extract_window(v: jnp.ndarray, base, nwb: int) -> jnp.ndarray:
    """Funnel-shift extraction of an *unaligned* 32*nwb-bit window starting at
    bit ``base`` from a (..., NW) word vector.  This is the DENT sub-word
    store: only the traceback-reachable band of each bitvector is kept.

    base: int array broadcastable over batch dims, 0 <= base <= 32*NW - 32*nwb.
    Returns (..., nwb) uint32.
    """
    nw = v.shape[-1]
    base = jnp.asarray(base, jnp.int32)
    w0 = base // WORD_BITS
    s = (base % WORD_BITS).astype(jnp.uint32)
    idx = w0[..., None] + jnp.arange(nwb + 1, dtype=jnp.int32)
    idx = jnp.clip(idx, 0, nw - 1)
    words = jnp.take_along_axis(v, idx, axis=-1)  # (..., nwb+1)
    lo, hi = words[..., :nwb], words[..., 1:]
    s = s[..., None]
    # s == 0 must not compute hi << 32 (UB); select explicitly.
    shifted = jnp.where(s == 0, lo, (lo >> s) | (hi << (jnp.uint32(WORD_BITS) - s)))
    return shifted


def window_bit(win: jnp.ndarray, base, idx) -> jnp.ndarray:
    """Read absolute bit ``idx`` from a window stored with ``extract_window``
    at bit offset ``base``.  Caller guarantees base <= idx < base + 32*nwb."""
    return get_bit(win, jnp.asarray(idx, jnp.int32) - jnp.asarray(base, jnp.int32))
