"""Host<->device transfer accounting for the aligner pipelines.

The paper's bandwidth argument only holds end-to-end if the serving path
does not quietly round-trip batches through numpy between rescue rounds.
Every host->device upload and device->host download in core.aligner and
serve.engine goes through ``to_device`` / ``to_host`` below, so tests and
benchmarks can assert transfer *counts* (one upload + one download per
batch for the on-device rescue path, regardless of rescue rounds) and
report transfer *bytes* per round.  Pure bookkeeping — no behavior change.
"""
from __future__ import annotations

import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TransferStats:
    h2d_calls: int = 0
    h2d_bytes: int = 0
    d2h_calls: int = 0
    d2h_bytes: int = 0


_STATS = TransferStats()
# the session's background retire executor downloads concurrently with the
# dispatch thread's uploads; counter increments must stay exact for the
# 1-upload/1-download assertions (read-modify-write races otherwise)
_LOCK = threading.Lock()


def reset() -> None:
    global _STATS
    with _LOCK:
        _STATS = TransferStats()


def stats() -> TransferStats:
    """Snapshot of the counters since the last reset()."""
    with _LOCK:
        return dataclasses.replace(_STATS)


def _nbytes(tree) -> int:
    return sum(int(np.asarray(leaf).nbytes)
               for leaf in jax.tree_util.tree_leaves(tree))


def to_device(x):
    """Upload a host array (or pytree of arrays); counts as ONE transfer."""
    nb = _nbytes(x)
    with _LOCK:
        _STATS.h2d_calls += 1
        _STATS.h2d_bytes += nb
    return jax.tree_util.tree_map(jnp.asarray, x)


def to_host(x):
    """Download a device array (or pytree); counts as ONE transfer."""
    out = jax.device_get(x)
    nb = _nbytes(out)
    with _LOCK:
        _STATS.d2h_calls += 1
        _STATS.d2h_bytes += nb
    return out
