"""Host<->device transfer accounting for the aligner pipelines.

The paper's bandwidth argument only holds end-to-end if the serving path
does not quietly round-trip batches through numpy between rescue rounds.
Every host->device upload and device->host download in core.aligner and
serve.engine goes through ``to_device`` / ``to_host`` below, so tests and
benchmarks can assert transfer *counts* (one upload + one download per
batch for the on-device rescue path, regardless of rescue rounds) and
report transfer *bytes* per round.  Pure bookkeeping — no behavior change.

The counters live on the process-global :mod:`repro.obs` registry
(``transfer_h2d_calls_total`` etc.) — transfers are cross-cutting, not
per-session, so they sit beside the shared compile-cache counters.  The
legacy :func:`stats`/:func:`reset` contract is a view over those
registry counters and keeps its exact semantics (``reset()`` resets only
this family, never the whole registry).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import default_registry


@dataclasses.dataclass
class TransferStats:
    h2d_calls: int = 0
    h2d_bytes: int = 0
    d2h_calls: int = 0
    d2h_bytes: int = 0


# the session's background retire executor downloads concurrently with the
# dispatch thread's uploads; Counter.inc is locked, so the counts stay
# exact for the 1-upload/1-download assertions
_REG = default_registry()
_H2D_CALLS = _REG.counter("transfer_h2d_calls_total")
_H2D_BYTES = _REG.counter("transfer_h2d_bytes_total")
_D2H_CALLS = _REG.counter("transfer_d2h_calls_total")
_D2H_BYTES = _REG.counter("transfer_d2h_bytes_total")


def reset() -> None:
    for c in (_H2D_CALLS, _H2D_BYTES, _D2H_CALLS, _D2H_BYTES):
        c.reset()


def stats() -> TransferStats:
    """Snapshot of the counters since the last reset()."""
    return TransferStats(h2d_calls=_H2D_CALLS.value,
                         h2d_bytes=_H2D_BYTES.value,
                         d2h_calls=_D2H_CALLS.value,
                         d2h_bytes=_D2H_BYTES.value)


def _nbytes(tree) -> int:
    return sum(int(np.asarray(leaf).nbytes)
               for leaf in jax.tree_util.tree_leaves(tree))


def to_device(x):
    """Upload a host array (or pytree of arrays); counts as ONE transfer."""
    _H2D_CALLS.inc()
    _H2D_BYTES.inc(_nbytes(x))
    return jax.tree_util.tree_map(jnp.asarray, x)


def to_host(x):
    """Download a device array (or pytree); counts as ONE transfer."""
    out = jax.device_get(x)
    _D2H_CALLS.inc()
    _D2H_BYTES.inc(_nbytes(out))
    return out
