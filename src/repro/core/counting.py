"""Analytic DP-table footprint / memory-access model (paper §I claims).

GenASM-DC keeps its running bitvectors in registers; the *memory* pressure
is (a) writing the traceback table and (b) the traceback's reads.  These
counters mirror that accounting for each variant, in 32-bit words:

  baseline  (edges4, no ET, full vectors, all columns)   — GenASM (MICRO'20)
  +SENE     (store only R = M&S&D&I)                     — paper idea 1
  +ET       (only levels 0..d_min computed/stored)       — paper idea 2
  +DENT     (band words of reachable columns only)       — paper idea 3

Validated against instrumented empirical counts in tests/test_counting.py.
"""
from __future__ import annotations

import dataclasses

from .config import AlignerConfig


@dataclasses.dataclass(frozen=True)
class WindowCounts:
    footprint_words: int     # allocated traceback storage
    dc_writes: int           # words written to the traceback table
    tb_reads: int            # words read back by the traceback


def baseline_counts(cfg: AlignerConfig, tb_steps: float) -> WindowCounts:
    """Unimproved GenASM-TB: 4 full bitvectors per (column, level)."""
    cells = cfg.W * (cfg.k + 1)
    words = 4 * cfg.nw
    # traceback inspects the 4 stored edge vectors of the current cell
    return WindowCounts(cells * words, cells * words,
                        int(tb_steps * 4 * cfg.nw))


def improved_counts(cfg: AlignerConfig, tb_steps: float,
                    levels_run: float) -> WindowCounts:
    """SENE + DENT (+ET via levels_run = average levels actually filled)."""
    cols = cfg.ncols_band
    alloc = cols * (cfg.k + 1) * cfg.nwb
    writes = int(cols * levels_run * cfg.nwb)
    # SENE recomputation reads R[d][j-1], R[d-1][j-1], R[d-1][j] per step
    reads = int(tb_steps * 3 * cfg.nwb)
    return WindowCounts(alloc, writes, reads)


def sene_only_counts(cfg: AlignerConfig, tb_steps: float) -> WindowCounts:
    cells = cfg.W * (cfg.k + 1)
    return WindowCounts(cells * cfg.nw, cells * cfg.nw,
                        int(tb_steps * 3 * cfg.nw))


def reduction_report(cfg: AlignerConfig, avg_levels: float,
                     tb_steps: float | None = None) -> dict:
    """Footprint / access reduction factors for a steady-state main window.

    avg_levels: measured average of (d_min+1) per window (ET).
    tb_steps:   traceback walk length; defaults to stride + avg window cost.
    """
    if tb_steps is None:
        tb_steps = cfg.stride + (avg_levels - 1.0)
    base = baseline_counts(cfg, tb_steps)
    sene = sene_only_counts(cfg, tb_steps)
    impr = improved_counts(cfg, tb_steps, avg_levels)
    impr_alloc_touched = cfg.ncols_band * avg_levels * cfg.nwb
    return {
        "baseline_footprint_words": base.footprint_words,
        "improved_footprint_words": impr.footprint_words,
        "improved_touched_words": impr_alloc_touched,
        "footprint_reduction_alloc": base.footprint_words / impr.footprint_words,
        "footprint_reduction_touched": base.footprint_words / impr_alloc_touched,
        "sene_only_reduction": base.footprint_words / sene.footprint_words,
        "baseline_accesses": base.dc_writes + base.tb_reads,
        "improved_accesses": impr.dc_writes + impr.tb_reads,
        "access_reduction": (base.dc_writes + base.tb_reads)
                            / max(1, impr.dc_writes + impr.tb_reads),
        "vmem_bytes_per_problem": impr.footprint_words * 4,
    }
