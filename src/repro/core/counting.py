"""Analytic DP-table footprint / memory-access model (paper §I claims).

GenASM-DC keeps its running bitvectors in registers; the *memory* pressure
is (a) writing the traceback table and (b) the traceback's reads.  These
counters mirror that accounting for each variant, in 32-bit words:

  baseline  (edges4, no ET, full vectors, all columns)   — GenASM (MICRO'20)
  +SENE     (store only R = M&S&D&I)                     — paper idea 1
  +ET       (only levels 0..d_min computed/stored)       — paper idea 2
  +DENT     (band words of reachable columns only)       — paper idea 3

Validated against instrumented empirical counts in tests/test_counting.py.

This module is also the single source of truth for the Pallas kernels'
declared VMEM scratch (`kernel_scratch_words` / `tail_scratch_words`):
`kernels.genasm_dc.vmem_bytes*` delegate here, and the scratch-accounting
suite (tests/test_scratch_accounting.py) asserts the declared
`pltpu.VMEM` shapes, the `vmem_bytes*` numbers and this model agree word
for word — so the paper's 24x claim is computed from real scratch bytes.
"""
from __future__ import annotations

import dataclasses

from .config import AlignerConfig


@dataclasses.dataclass(frozen=True)
class WindowCounts:
    footprint_words: int     # allocated traceback storage
    dc_writes: int           # words written to the traceback table
    tb_reads: int            # words read back by the traceback


def baseline_counts(cfg: AlignerConfig, tb_steps: float) -> WindowCounts:
    """Unimproved GenASM-TB: 4 full bitvectors per (column, level)."""
    cells = cfg.W * (cfg.k + 1)
    words = 4 * cfg.nw
    # traceback inspects the 4 stored edge vectors of the current cell
    return WindowCounts(cells * words, cells * words,
                        int(tb_steps * 4 * cfg.nw))


def improved_counts(cfg: AlignerConfig, tb_steps: float,
                    levels_run: float) -> WindowCounts:
    """SENE + DENT (+ET via levels_run = average levels actually filled)."""
    cols = cfg.ncols_band
    alloc = cols * (cfg.k + 1) * cfg.nwb
    writes = int(cols * levels_run * cfg.nwb)
    # SENE recomputation reads R[d][j-1], R[d-1][j-1], R[d-1][j] per step
    reads = int(tb_steps * 3 * cfg.nwb)
    return WindowCounts(alloc, writes, reads)


def sene_only_counts(cfg: AlignerConfig, tb_steps: float) -> WindowCounts:
    cells = cfg.W * (cfg.k + 1)
    return WindowCounts(cells * cfg.nw, cells * cfg.nw,
                        int(tb_steps * 3 * cfg.nw))


def kernel_scratch_words(cfg: AlignerConfig, tile: int) -> int:
    """Declared VMEM scratch of the square fused/split kernels, in words,
    per problem tile: exactly the DENT band store — (k+1) levels x
    ncols_band reachable columns x nwb band words per lane.

    After the Scrooge-style store elimination the DC fill carries its two
    live columns in the loop state ("registers", the paper's framing
    above), so the band is the *only* materialised table.  This equals
    ``improved_counts(...).footprint_words * tile``: the analytic claim
    and the kernel's declared scratch are the same number."""
    return (cfg.k + 1) * cfg.ncols_band * cfg.nwb * tile


def tail_scratch_words(cfg: AlignerConfig, tile: int,
                       n_text: int | None = None,
                       banded: bool | None = None) -> int:
    """Declared VMEM scratch of the rectangular-tail fused kernel, in
    words, per problem tile.

    banded (default: cfg.tail_banded) — the DENT-style tail band keeps
    nwb words per (level, text column) around the per-lane diagonal,
    with column 0 analytic (ones_below needs no store); the full-store
    fallback keeps the whole (k+1, n_text+1, NW) SENE table."""
    if n_text is None:
        n_text = cfg.W + 4 * cfg.k
    if banded is None:
        banded = cfg.tail_banded
    if banded:
        return (cfg.k + 1) * n_text * cfg.nwb * tile
    return (cfg.k + 1) * (n_text + 1) * cfg.nw * tile


def gpu_store_words(cfg: AlignerConfig, tile: int) -> int:
    """Per-program DP-store words of the square fused kernel on the Triton
    (pallas_gpu) path.  The store is the *same* DENT band as the TPU
    path's VMEM scratch — only the memory space differs: jax's Triton
    lowering has no scratch memory, so the band rides a GMEM-backed output
    block (kernels.genasm_dc.gpu_fused_store_shapes, asserted equal in
    tests/test_scratch_accounting.py)."""
    return kernel_scratch_words(cfg, tile)


def gpu_tail_store_words(cfg: AlignerConfig, tile: int,
                         n_text: int | None = None,
                         banded: bool | None = None) -> int:
    """Per-program DP-store words of the rectangular-tail kernel on the
    Triton path (same words as tail_scratch_words, GMEM-backed)."""
    return tail_scratch_words(cfg, tile, n_text, banded)


def gpu_lane_state_words(cfg: AlignerConfig) -> int:
    """Register-resident live DP state per lane on the Triton path, in
    32-bit words: the column-major fill carries the previous AND current
    column's k+1 level vectors (nw words each) in the loop state — the
    lane-per-thread mapping's binding resource, so this is what the GPU
    lane-tile planner budgets against (core.windowing.plan_lane_tile)
    instead of the TPU's 16 MiB VMEM scratch budget."""
    return 2 * (cfg.k + 1) * cfg.nw


def reduction_report(cfg: AlignerConfig, avg_levels: float,
                     tb_steps: float | None = None) -> dict:
    """Footprint / access reduction factors for a steady-state main window.

    avg_levels: measured average of (d_min+1) per window (ET).
    tb_steps:   traceback walk length; defaults to stride + avg window cost.
    """
    if tb_steps is None:
        tb_steps = cfg.stride + (avg_levels - 1.0)
    base = baseline_counts(cfg, tb_steps)
    sene = sene_only_counts(cfg, tb_steps)
    impr = improved_counts(cfg, tb_steps, avg_levels)
    impr_alloc_touched = cfg.ncols_band * avg_levels * cfg.nwb
    return {
        "baseline_footprint_words": base.footprint_words,
        "improved_footprint_words": impr.footprint_words,
        "improved_touched_words": impr_alloc_touched,
        "footprint_reduction_alloc": base.footprint_words / impr.footprint_words,
        "footprint_reduction_touched": base.footprint_words / impr_alloc_touched,
        "sene_only_reduction": base.footprint_words / sene.footprint_words,
        "baseline_accesses": base.dc_writes + base.tb_reads,
        "improved_accesses": impr.dc_writes + impr.tb_reads,
        "access_reduction": (base.dc_writes + base.tb_reads)
                            / max(1, impr.dc_writes + impr.tb_reads),
        # == kernel_scratch_words(cfg, tile) * 4 / tile: the fused kernel's
        # declared band scratch, not an independent estimate (satellite
        # reconciliation, asserted in tests/test_scratch_accounting.py)
        "vmem_bytes_per_problem": impr.footprint_words * 4,
    }
