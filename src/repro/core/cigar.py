"""CIGAR utilities: 2-bit packing, run-length encoding, host-side decode."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .oracle import OP_CHARS
from .traceback import OP_NONE


def pack_ops(ops: jnp.ndarray) -> jnp.ndarray:
    """Pack (B, L) uint8 op codes (0..3; OP_NONE padding -> 0) into
    (B, ceil(L/16)) uint32 words, 2 bits per op."""
    B, L = ops.shape
    pad = (-L) % 16
    o = jnp.pad(ops, ((0, 0), (0, pad)))
    o = jnp.where(o == OP_NONE, 0, o).astype(jnp.uint32)
    o = o.reshape(B, -1, 16)
    sh = (jnp.arange(16, dtype=jnp.uint32) * 2)
    return jnp.sum(o << sh, axis=-1, dtype=jnp.uint32)


def unpack_ops(packed: np.ndarray, n_ops: np.ndarray) -> list[np.ndarray]:
    """Host-side inverse of pack_ops."""
    out = []
    for row, n in zip(np.asarray(packed), np.asarray(n_ops)):
        # op t lives in word t//16 at bit offset 2*(t%16)
        ops = np.stack([(row >> np.uint32(2 * i)) & 3 for i in range(16)],
                       axis=1).reshape(-1)
        out.append(ops[:n].astype(np.uint8))
    return out


def ops_to_string(ops: np.ndarray) -> str:
    """Run-length encode an op array into a CIGAR string (=XID alphabet)."""
    ops = np.asarray(ops)
    if ops.size == 0:
        return ""
    change = np.nonzero(np.diff(ops))[0] + 1
    bounds = np.concatenate([[0], change, [len(ops)]])
    return "".join(
        f"{bounds[i+1]-bounds[i]}{OP_CHARS[ops[bounds[i]]]}"
        for i in range(len(bounds) - 1)
    )


# --------------------------------------------------------------------------
# batch decode — THE host-side decode entrypoint for retired dispatches.
#
# Pure numpy over already-downloaded buffers: no jax calls, no global
# state, copies out of device_get's read-only views.  That is what lets
# repro.api's background retire executor run this concurrently with the
# dispatch thread (and what GenASMAligner reuses synchronously).
# --------------------------------------------------------------------------

def decode_batch(host: dict, n: int, default_k: int):
    """Decode the first `n` lanes of one downloaded align-step output dict
    into mutable per-lane state arrays.

    Returns (failed, dist, k_used, rcon, fcon, all_ops): writable arrays
    (rescue merges mutate them in place) plus per-lane op arrays (None for
    failed lanes).  `default_k` fills k_used for executables that do not
    report it (the plain per-rung step used by bucket rescue)."""
    failed = np.array(host["failed"][:n], bool)
    dist = np.asarray(host["dist"])[:n].astype(np.int64)
    n_ops = np.asarray(host["n_ops"])[:n]
    ops_buf = np.asarray(host["ops"])[:n]
    rcon = np.asarray(host["read_consumed"])[:n].astype(np.int32)
    fcon = np.asarray(host["ref_consumed"])[:n].astype(np.int32)
    if "k_used" in host:
        k_used = np.asarray(host["k_used"])[:n].astype(np.int32)
    else:
        k_used = np.where(failed, 0, default_k).astype(np.int32)
    all_ops = [ops_buf[i, :n_ops[i]].copy() if not failed[i] else None
               for i in range(n)]
    return failed, dist, k_used, rcon, fcon, all_ops


def records_from_state(failed, dist, k_used, rcon, fcon, all_ops) -> list:
    """Finalize decoded (possibly rescue-merged) state into per-lane result
    records {ok, dist, cigar, k_used, ops, read_consumed, ref_consumed} —
    the one record shape the session futures, the serving engine and
    AlignResult.from_records share.  Failed lanes report zeros and an
    empty CIGAR."""
    recs = []
    for i in range(len(all_ops)):
        bad = bool(failed[i])
        ops = all_ops[i] if all_ops[i] is not None else np.zeros(0, np.uint8)
        recs.append({
            "ok": not bad,
            "dist": 0 if bad else int(dist[i]),
            "cigar": "" if bad else ops_to_string(ops),
            "k_used": 0 if bad else int(k_used[i]),
            "ops": ops,
            "read_consumed": 0 if bad else int(rcon[i]),
            "ref_consumed": 0 if bad else int(fcon[i]),
        })
    return recs
