"""CIGAR utilities: 2-bit packing, run-length encoding, host-side decode."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .oracle import OP_CHARS
from .traceback import OP_NONE


def pack_ops(ops: jnp.ndarray) -> jnp.ndarray:
    """Pack (B, L) uint8 op codes (0..3; OP_NONE padding -> 0) into
    (B, ceil(L/16)) uint32 words, 2 bits per op."""
    B, L = ops.shape
    pad = (-L) % 16
    o = jnp.pad(ops, ((0, 0), (0, pad)))
    o = jnp.where(o == OP_NONE, 0, o).astype(jnp.uint32)
    o = o.reshape(B, -1, 16)
    sh = (jnp.arange(16, dtype=jnp.uint32) * 2)
    return jnp.sum(o << sh, axis=-1, dtype=jnp.uint32)


def unpack_ops(packed: np.ndarray, n_ops: np.ndarray) -> list[np.ndarray]:
    """Host-side inverse of pack_ops."""
    out = []
    for row, n in zip(np.asarray(packed), np.asarray(n_ops)):
        # op t lives in word t//16 at bit offset 2*(t%16)
        ops = np.stack([(row >> np.uint32(2 * i)) & 3 for i in range(16)],
                       axis=1).reshape(-1)
        out.append(ops[:n].astype(np.uint8))
    return out


def ops_to_string(ops: np.ndarray) -> str:
    """Run-length encode an op array into a CIGAR string (=XID alphabet)."""
    ops = np.asarray(ops)
    if ops.size == 0:
        return ""
    change = np.nonzero(np.diff(ops))[0] + 1
    bounds = np.concatenate([[0], change, [len(ops)]])
    return "".join(
        f"{bounds[i+1]-bounds[i]}{OP_CHARS[ops[bounds[i]]]}"
        for i in range(len(bounds) - 1)
    )
