"""GenASM-TB: batched traceback over the three storage modes.

* 'edges4' (unimproved GenASM): reads the stored M/S/D/I edge bitvectors.
* 'and'    (SENE): stores only R = M & S & D & I; edge availability is
  *recomputed* from neighbouring stored R values + the pattern masks — the
  paper's idea 1.
* 'band'   (SENE+DENT): like 'and' but reads the stored sub-word band
  windows; positions outside the band are provably unreachable (idea 3).

All modes emit identical CIGARs (same =,X,D,I preference order); tests
assert this equivalence, which is the correctness claim of the paper's
compression ideas.

The traceback runs forward over *reversed* windows, so operations come out
front-first and the walk stops after ``commit_limit`` read chars — GenASM's
windowing trick that bounds both the walk length and the reachable columns.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .bitops import WORD_BITS, get_bit
from .config import AlignerConfig
from .oracle import OP_DEL, OP_INS, OP_MATCH, OP_SUBST

OP_NONE = 255


def _zbit_full(r_bt, b_idx, d, j, i, k):
    """bit i of stored R_j[d] == 0 (full-vector storage); i == -1 encodes the
    DP's first column: ED(0, j) <= d  ⟺  j <= d.

    r_bt: (B, C, K1, NW) — batch-leading, gathered with a vmapped dynamic
    index so GSPMD keeps the lookup local to each batch shard (a flattened
    (C*B*K1) gather forces a full all-gather of the store; §Perf)."""
    B, C, K1, NW = r_bt.shape
    jj = jnp.clip(j, 0, C - 1)
    dd = jnp.clip(d, 0, K1 - 1)
    words = jax.vmap(lambda rc, jx, dx: jax.lax.dynamic_index_in_dim(
        jax.lax.dynamic_index_in_dim(rc, jx, 0, keepdims=False),
        dx, 0, keepdims=False))(r_bt, jj, dd)
    bit = get_bit(words, jnp.clip(i, 0, NW * WORD_BITS - 1))
    return jnp.where(i < 0, j <= d, bit == 0)


def _zbit_band(rb_bt, bases, col0, b_idx, d, j, i, k):
    """bit i of the stored band window of column j, level d == 0.
    rb_bt: (B, K1, CB, NWB) batch-leading (see _zbit_full note).

    This is the parity reference for every banded in-kernel walk: the
    fused square kernel's zbit mirrors it with the same static bases, and
    the banded *tail* kernel (kernels.genasm_dc._kernel_tail_banded)
    generalises the base to the per-lane diagonal — same in-band mask,
    same i < 0 first-row analytics, plus an analytic j <= 0 column
    (R_0[d] = ones_below(d), never stored there)."""
    B, K1, CB, NWB = rb_bt.shape
    s = jnp.clip(j - col0, 0, CB - 1)
    dd = jnp.clip(d, 0, K1 - 1)
    words = jax.vmap(lambda rc, dx, sx: jax.lax.dynamic_index_in_dim(
        jax.lax.dynamic_index_in_dim(rc, dx, 0, keepdims=False),
        sx, 0, keepdims=False))(rb_bt, dd, s)
    off = i - bases[jnp.clip(j, 0, bases.shape[0] - 1)]
    inband = (off >= 0) & (off < NWB * WORD_BITS)
    bit = get_bit(words, jnp.clip(off, 0, NWB * WORD_BITS - 1))
    return jnp.where(i < 0, j <= d, (bit == 0) & inband)


def _ebit(edges_bt, b_idx, d, j, i, which):
    """edges4 mode: stored edge bit (0=M,1=S,2=D,3=I) of column j, level d.
    edges_bt: (B, C, K1, NW, 4) batch-leading."""
    B, C, K1, NW, _ = edges_bt.shape
    jj = jnp.clip(j, 0, C - 1)
    dd = jnp.clip(d, 0, K1 - 1)
    words = jax.vmap(lambda e, jx, dx: jax.lax.dynamic_index_in_dim(
        jax.lax.dynamic_index_in_dim(e, jx, 0, keepdims=False),
        dx, 0, keepdims=False))(edges_bt, jj, dd)[..., which]
    return get_bit(words, jnp.clip(i, 0, NW * WORD_BITS - 1)) == 0


@partial(jax.jit, static_argnames=("cfg", "mode", "max_ops", "max_steps"))
def traceback(store, pat_codes, text_codes, m_len, n_len, dist, commit_limit,
              *, cfg: AlignerConfig, mode: str, max_ops: int, max_steps: int):
    """Walk the stored DP from the (m_len-1, n_len) corner.

    Returns dict: ops (B, max_ops) uint8 front-first, n_ops, read_adv,
    ref_adv, cost (edits spent on committed ops), ok (internal invariant).
    Problems with dist > k are skipped (ok stays True, n_ops = 0).
    """
    B = pat_codes.shape[0]
    k = cfg.k
    b_idx = jnp.arange(B, dtype=jnp.int32)

    if mode == "band":
        rb_bt = jnp.transpose(store["Rb"], (2, 0, 1, 3))   # (B, K1, CB, NWB)
        n = text_codes.shape[1]
        col0 = n + 1 - cfg.ncols_band
        bases = jnp.array([cfg.band_base(j, cfg.m_pad) for j in range(n + 1)],
                          jnp.int32)
        zbit = partial(_zbit_band, rb_bt, bases, col0, b_idx)
    else:
        r_bt = jnp.transpose(store["R"], (1, 0, 2, 3))     # (B, C, K1, NW)
        zbit = partial(_zbit_full, r_bt, b_idx)

    edges_bt = (jnp.transpose(store["edges"], (1, 0, 2, 3, 4))
                if mode == "edges4" else None)

    def avail(i, j, d):
        """(mA, sA, dA, iA) edge availability at cell (i, j) level d."""
        if mode == "edges4":
            e = edges_bt
            mA = (j > 0) & _ebit(e, b_idx, d, j, i, 0)
            sA = (j > 0) & (d > 0) & _ebit(e, b_idx, d, j, i, 1)
            dA = (j > 0) & (d > 0) & _ebit(e, b_idx, d, j, i, 2)
            iA = (d > 0) & _ebit(e, b_idx, d, j, i, 3)
        else:
            pj = jnp.take_along_axis(
                pat_codes, jnp.clip(i, 0, pat_codes.shape[1] - 1)[:, None],
                axis=1)[:, 0]
            tj = jnp.take_along_axis(
                text_codes, jnp.clip(j - 1, 0, text_codes.shape[1] - 1)[:, None],
                axis=1)[:, 0]
            peq = pj == tj
            mA = (j > 0) & peq & zbit(d, j - 1, i - 1, k)
            sA = (j > 0) & (d > 0) & zbit(d - 1, j - 1, i - 1, k)
            dA = (j > 0) & (d > 0) & zbit(d - 1, j - 1, i, k)
            iA = (d > 0) & zbit(d - 1, j, i - 1, k)
        return mA, sA, dA, iA

    def body(state):
        i, j, d, nops, ops, rd, rf, done, ok, steps = state
        tail = i < 0
        stopped = rd >= commit_limit
        active = ~done & ~stopped

        mA, sA, dA, iA = avail(i, j, d)
        # tail: pattern exhausted, drain remaining text as deletions
        tail_emit = tail & (j > 0)
        mA &= ~tail; sA &= ~tail; dA &= ~tail; iA &= ~tail

        any_edge = mA | sA | dA | iA | tail_emit
        # exclusive choice with GenASM's =,X,D,I preference
        cM = mA
        cS = ~mA & sA
        cD = ~mA & ~sA & dA
        cI = ~mA & ~sA & ~dA & iA
        op = jnp.where(cM, OP_MATCH,
             jnp.where(cS, OP_SUBST,
             jnp.where(cD, OP_DEL,
             jnp.where(cI, OP_INS, OP_DEL))))  # tail_emit -> DEL

        takes_read = active & (cM | cS | cI)
        takes_ref = active & (cM | cS | cD | tail_emit)
        costs = active & (cS | cD | cI | tail_emit)

        new_i = jnp.where(takes_read, i - 1, i)
        new_j = jnp.where(takes_ref, j - 1, j)
        new_d = jnp.where(costs, d - 1, d)
        new_rd = rd + takes_read
        new_rf = rf + takes_ref

        slot = jnp.where(active & any_edge, nops, max_ops)
        ops = jax.vmap(lambda row, sx, ox: row.at[sx].set(ox, mode="drop"))(
            ops, slot, op.astype(jnp.uint8))
        nops = nops + (active & any_edge)

        finished = (new_i < 0) & (new_j <= 0)
        new_done = done | (active & finished)
        # invariant: an active, unfinished cell always has an available edge
        ok &= jnp.where(active & ~finished, any_edge | ((i < 0) & (j <= 0)), True)
        return (new_i, new_j, new_d, nops, ops, new_rd, new_rf,
                new_done | stopped, ok, steps + 1)

    def cond(state):
        *_, done, ok, steps = state
        return jnp.any(~done) & (steps < max_steps)

    skip = dist > k
    init = (
        jnp.asarray(m_len, jnp.int32) - 1,
        jnp.asarray(n_len, jnp.int32),
        jnp.asarray(dist, jnp.int32),
        jnp.zeros((B,), jnp.int32),
        jnp.full((B, max_ops), OP_NONE, jnp.uint8),
        jnp.zeros((B,), jnp.int32),
        jnp.zeros((B,), jnp.int32),
        skip,
        jnp.ones((B,), bool),
        jnp.int32(0),
    )
    i, j, d, nops, ops, rd, rf, done, ok, _ = jax.lax.while_loop(cond, body, init)
    cost = jnp.where(skip, 0, jnp.asarray(dist, jnp.int32) - d)
    return {"ops": ops, "n_ops": nops, "read_adv": rd, "ref_adv": rf,
            "cost": cost, "ok": ok, "d_final": d}
