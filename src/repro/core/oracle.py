"""Reference oracles: classic DP edit distance + traceback, CIGAR validation.

Pure numpy, deliberately simple — these define the semantics the GenASM
implementations (jnp and Pallas) are tested against.
"""
from __future__ import annotations

import numpy as np

# CIGAR op codes used throughout the repo (2-bit packable)
OP_MATCH = 0  # '='  consumes read + ref
OP_SUBST = 1  # 'X'  consumes read + ref
OP_INS = 2    # 'I'  consumes read only  (insertion w.r.t. the reference)
OP_DEL = 3    # 'D'  consumes ref only   (deletion  w.r.t. the reference)
OP_CHARS = "=XID"


def levenshtein(p: np.ndarray, t: np.ndarray) -> int:
    """Edit distance between code arrays p (pattern/read) and t (text/ref)."""
    m, n = len(p), len(t)
    prev = np.arange(n + 1)
    for i in range(1, m + 1):
        cur = np.empty(n + 1, dtype=np.int64)
        cur[0] = i
        sub = prev[:-1] + (t != p[i - 1])
        # cur[j] = min(sub[j-1], prev[j] + 1, cur[j-1] + 1) -- resolve the
        # cur[j-1] dependency with a serial pass (n is small in tests).
        best = np.minimum(sub, prev[1:] + 1)
        run = cur[0]
        for j in range(1, n + 1):
            run = min(best[j - 1], run + 1)
            cur[j] = run
        prev = cur
    return int(prev[n])


def dp_table(p: np.ndarray, t: np.ndarray) -> np.ndarray:
    m, n = len(p), len(t)
    D = np.zeros((m + 1, n + 1), dtype=np.int64)
    D[:, 0] = np.arange(m + 1)
    D[0, :] = np.arange(n + 1)
    for i in range(1, m + 1):
        for j in range(1, n + 1):
            D[i, j] = min(
                D[i - 1, j - 1] + (p[i - 1] != t[j - 1]),
                D[i - 1, j] + 1,
                D[i, j - 1] + 1,
            )
    return D


def dp_traceback(p: np.ndarray, t: np.ndarray) -> tuple[int, list[int]]:
    """Optimal CIGAR (front-first op list) preferring =, X, D, I like the
    GenASM traceback implementations (D = consume text only)."""
    D = dp_table(p, t)
    i, j = len(p), len(t)
    ops: list[int] = []
    while i > 0 or j > 0:
        d = D[i, j]
        if i > 0 and j > 0 and p[i - 1] == t[j - 1] and D[i - 1, j - 1] == d:
            ops.append(OP_MATCH); i -= 1; j -= 1
        elif i > 0 and j > 0 and D[i - 1, j - 1] == d - 1:
            ops.append(OP_SUBST); i -= 1; j -= 1
        elif j > 0 and D[i, j - 1] == d - 1:
            ops.append(OP_DEL); j -= 1
        else:
            ops.append(OP_INS); i -= 1
    ops.reverse()
    return int(D[len(p), len(t)]), ops


def validate_cigar(p: np.ndarray, t: np.ndarray, ops, expected_dist=None) -> None:
    """Assert a front-first op list is a valid alignment of p against t."""
    i = j = cost = 0
    for op in ops:
        if op == OP_MATCH:
            assert i < len(p) and j < len(t) and p[i] == t[j], \
                f"bad match at read {i} / ref {j}"
            i += 1; j += 1
        elif op == OP_SUBST:
            assert i < len(p) and j < len(t) and p[i] != t[j], \
                f"subst on equal chars at read {i} / ref {j}"
            i += 1; j += 1; cost += 1
        elif op == OP_INS:
            assert i < len(p); i += 1; cost += 1
        elif op == OP_DEL:
            assert j < len(t); j += 1; cost += 1
        else:
            raise AssertionError(f"unknown op {op}")
    assert i == len(p), f"read not fully consumed: {i} != {len(p)}"
    assert j == len(t), f"ref not fully consumed: {j} != {len(t)}"
    if expected_dist is not None:
        assert cost == expected_dist, f"cigar cost {cost} != distance {expected_dist}"


def ops_to_cigar_string(ops) -> str:
    """Run-length encode a front-first op list into a CIGAR-like string."""
    out = []
    prev, run = None, 0
    for op in list(ops) + [None]:
        if op == prev:
            run += 1
        else:
            if prev is not None:
                out.append(f"{run}{OP_CHARS[prev]}")
            prev, run = op, 1
    return "".join(out)
