"""Aligner configuration (the paper's knobs, plus TPU-mapping knobs)."""
from __future__ import annotations

import dataclasses
import hashlib

from .bitops import WORD_BITS, n_words

#: valid knob choices, named so validation errors, docs and the docs-CI
#: coverage checker share one source of truth
BACKENDS = ("jnp", "pallas", "pallas_fused", "pallas_gpu")
#: the backends that dispatch Pallas kernels (lane-tile pad quantum applies)
PALLAS_BACKENDS = ("pallas", "pallas_fused", "pallas_gpu")
STORES = ("edges4", "and", "band")
TAIL_STORES = ("auto", "band", "full")


@dataclasses.dataclass(frozen=True)
class AlignerConfig:
    """GenASM window/threshold configuration.

    W, O follow GenASM (MICRO'20): align W-char windows, commit the first
    W-O traceback operations, advance.  ``k`` is the per-window edit budget.

    store:
      'edges4' — unimproved GenASM-TB: all four M/S/D/I bitvectors per entry
      'and'    — paper idea 1 (SENE): only R = M & S & D & I per entry
      'band'   — ideas 1+3 (SENE + DENT): only the traceback-reachable
                 diagonal band words of R, for the reachable columns
    early_term — paper idea 2 (ET): level-major fill stops once a level
                 holds the solution.

    backend (requires store='band' for the pallas variants; interpret mode
    on CPU, compiled on the matching accelerator — see docs/backends.md):
      'jnp'          — pure-jnp fills (core.genasm) + host traceback
      'pallas'       — Pallas DC kernel, band shipped to HBM, jnp traceback
      'pallas_fused' — Pallas DC+TB kernel (TPU lowering): traceback walks
                       the DENT band in VMEM scratch; only ops/meta leave
                       the chip
      'pallas_gpu'   — the same fused DC+TB kernels lowered through
                       Pallas's Triton backend for CUDA GPUs: the Triton
                       path has no scratch memory, so the band rides a
                       GMEM-backed output block and the live DP columns
                       stay in registers (core.counting.gpu_* model)
    """
    W: int = 64
    O: int = 24
    k: int = 12
    store: str = "band"
    early_term: bool = True
    tb_margin: int = 3          # extra stored columns beyond the provable band
    backend: str = "jnp"        # 'jnp' | 'pallas' | 'pallas_fused' | 'pallas_gpu'
    n_symbols: int = 4
    lane_tile: int = 128        # problems per Pallas grid step (one VPU-lane
                                # tile); also the per-shard batch pad unit
    tail_store: str = "auto"    # rectangular-tail SENE store: 'band' keeps
                                # only the provably-reachable diagonal window
                                # (Scrooge-style store elimination), 'full'
                                # the whole (k+1, n_text+1, NW) table;
                                # 'auto' = band whenever it is a strict win

    def __post_init__(self):
        # ValueError (not assert): these run under ``python -O`` too, and
        # each names the offending knob plus the valid choices — the error
        # IS the documentation when a typo'd backend reaches resolve_config
        if not 0 < self.O < self.W:
            raise ValueError(f"O={self.O} must satisfy 0 < O < W "
                             f"(W={self.W}: the overlap is a strict part "
                             f"of every window)")
        if not 0 < self.k < self.W:
            raise ValueError(f"k={self.k} must satisfy 0 < k < W "
                             f"(W={self.W}: the edit budget cannot exceed "
                             f"the window)")
        if self.lane_tile <= 0:
            raise ValueError(f"lane_tile={self.lane_tile} must be a "
                             f"positive lane count")
        if self.store not in STORES:
            raise ValueError(f"store={self.store!r} is not one of {STORES}")
        if self.tail_store not in TAIL_STORES:
            raise ValueError(f"tail_store={self.tail_store!r} is not one "
                             f"of {TAIL_STORES}")
        if self.backend not in BACKENDS:
            raise ValueError(f"backend={self.backend!r} is not one of "
                             f"{BACKENDS}")
        # the Pallas kernels implement the fully-improved (banded) DP only
        if self.backend != "jnp" and self.store != "band":
            raise ValueError(f"backend={self.backend!r} requires "
                             f"store='band' (got store={self.store!r}): "
                             f"the Pallas kernels implement the banded DP "
                             f"only")

    @property
    def nw(self) -> int:
        """words per full bitvector (pattern dim padded to words)"""
        return n_words(self.W)

    @property
    def m_pad(self) -> int:
        return self.nw * WORD_BITS

    @property
    def nwb(self) -> int:
        """words per DENT band window: covers [center-k-1, center+k+1]."""
        need = 2 * self.k + 3
        return min(self.nw, -(-need // WORD_BITS))

    @property
    def stride(self) -> int:
        return self.W - self.O

    @property
    def tb_max_ops(self) -> int:
        """Op budget of one committed main-window traceback walk (stride
        read chars + <= k non-read ops + slack).  Single source of truth
        for core.windowing, the fused kernel and the benchmarks."""
        return self.stride + self.k + 2

    @property
    def tb_max_steps(self) -> int:
        return self.stride + self.k + 4

    @property
    def ncols_band(self) -> int:
        """columns (incl. col 0) kept by DENT column pruning: the traceback
        commits <= W-O read chars, hence visits <= W-O+k text columns."""
        return min(self.W + 1, self.stride + self.k + self.tb_margin)

    @property
    def tail_band_supported(self) -> bool:
        """True when the tail's DENT-style band proof buys a strictly
        narrower store: the traceback-reachable window around the per-lane
        diagonal spans 2k+3 bits, so whenever that fits in fewer words than
        the full pattern vector (nwb < nw) the banded store is a win.  When
        nwb == nw the band window *is* the full vector (its base clips to
        word 0) — correct, but no bytes saved."""
        return self.nwb < self.nw

    @property
    def tail_banded(self) -> bool:
        """Resolved tail_store policy: does the tail kernel store the band?"""
        if self.tail_store == "band":
            return True
        if self.tail_store == "full":
            return False
        return self.tail_band_supported

    def replace(self, **overrides) -> "AlignerConfig":
        """A copy with `overrides` applied (re-validated by __post_init__)."""
        return dataclasses.replace(self, **overrides)

    def fingerprint(self) -> str:
        """Stable content hash of every knob that shapes an executable.

        The process-wide shared CompileCache (repro.api) keys executables
        by (spec-hash, bucket, mesh-fingerprint) so that N sessions of the
        same spec — constructed independently, possibly from different
        AlignerConfig *objects* — resolve to the same cache entry.  Field
        values, not object identity, are what's hashed; two equal configs
        always fingerprint equal."""
        blob = ";".join(f"{f.name}={getattr(self, f.name)!r}"
                        for f in dataclasses.fields(self))
        return hashlib.sha1(blob.encode()).hexdigest()[:16]

    def band_base(self, j, m_pad: int | None = None):
        """Lowest stored bit of column j's band window (static per column
        for square W x W windows: band center = j-1)."""
        m_pad = m_pad or self.m_pad
        lo = j - 2 - self.k
        hi = m_pad - WORD_BITS * self.nwb
        return max(0, min(lo, hi)) if isinstance(j, int) else None


def resolve_config(cfg: AlignerConfig | None = None,
                   **overrides) -> AlignerConfig:
    """Resolve a cfg-like spec into ONE validated AlignerConfig.

    Accepts an existing config (or None for defaults) plus keyword
    overrides; None-valued overrides are ignored so callers can thread
    optional knobs straight through (e.g. the legacy ``backend=``
    parameter of GenASMAligner / AlignmentEngine).  Validation happens
    once, here, via the dataclass __post_init__ — the single funnel the
    session front door (repro.api.plan) and the legacy shims share.

    ``lane_tile='auto'`` resolves to the bucket planner's VMEM-budgeted
    tile (core.windowing.plan_lane_tile) against the *final* geometry —
    i.e. after every other override, including ``tail_store``, has been
    applied — so banded-tail configs automatically get the wider tiles
    their smaller scratch affords."""
    cfg = cfg if cfg is not None else AlignerConfig()
    # reject typo'd knobs even when their value is None (optional params
    # threaded through with =None defaults must still name real fields)
    unknown = set(overrides) - {f.name
                                for f in dataclasses.fields(AlignerConfig)}
    if unknown:
        raise TypeError(f"unknown AlignerConfig knobs: {sorted(unknown)}")
    real = {k: v for k, v in overrides.items() if v is not None}
    auto_tile = real.get("lane_tile") == "auto"
    if auto_tile:
        del real["lane_tile"]
    cfg = dataclasses.replace(cfg, **real) if real else cfg
    if auto_tile:
        from .windowing import plan_lane_tile   # runtime: avoids the cycle
        cfg = dataclasses.replace(cfg, lane_tile=plan_lane_tile(cfg))
    return cfg
