"""GenASM-DC (distance calculation) in JAX — baseline and improved variants.

Semantics (exact, testable): after consuming j text chars, bit i of R_j[d]
is 0  ⟺  Levenshtein(P[0..i], T[0..j-1]) <= d.  The recurrence is GenASM's
(MICRO'20 Alg. 1) with exact first-column boundary bits carried as scalars:

    M = (R_{j-1}[d]   << 1 | [j-1 >  d  ]) | PM[T[j-1]]
    S = (R_{j-1}[d-1] << 1 | [j-1 >= d  ])
    D =  R_{j-1}[d-1]
    I = (R_j  [d-1]   << 1 | [j-1 >= d-1])
    R_j[d] = M & S & D & I            (R_j[0] = M)

Two fill orders are provided:
  * ``dc_jmajor`` — text-major streaming fill (the unimproved GenASM order),
    storing full bitvectors per (column, level): 'edges4' (all of M,S,D,I —
    baseline GenASM-TB) or 'and' (SENE, paper idea 1).
  * ``dc_dmajor`` — level-major fill with early termination (paper idea 2)
    and DENT band storage (paper idea 3): only the traceback-reachable
    diagonal band words of R are stored, for the reachable columns only.
    Requires uniform square windows (m = n = W), the windowed long-read
    path's steady state.

Inputs are *reversed* windows (GenASM processes text right-to-left) so that
the traceback emits operations front-first and can stop after W-O commits.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .bitops import (N_SYMBOLS, SENTINEL_PAT, SENTINEL_TEXT, WORD_BITS,
                     build_pm, extract_window, get_bit, ones_below, shift1)
from .config import AlignerConfig


@partial(jax.tree_util.register_dataclass,
         data_fields=("dist", "solved", "r_final", "store", "levels_run"),
         meta_fields=())
@dataclasses.dataclass
class DCResult:
    dist: jnp.ndarray          # (B,) int32; k+1 where no level solved
    solved: jnp.ndarray        # (B,) bool
    r_final: jnp.ndarray       # (B, k+1, NW) final column (full modes) or last col
    store: dict                # storage for traceback, mode-dependent
    levels_run: jnp.ndarray    # () int32: levels actually computed (ET)


def _boundary_bits(j, d):
    """Shift-in bits for column j, level d (see module docstring)."""
    t = j - 1
    bM = (t > d).astype(jnp.uint32)
    bS = (t >= d).astype(jnp.uint32)
    bI = (t >= d - 1).astype(jnp.uint32)
    return bM, bS, bI


def _lookup_pm(pm, codes_j):
    """pm: (B, n_sym+1, NW); codes_j: (B,) — returns (B, NW).  Out-of-alphabet
    (sentinel) text chars map to the all-ones mask (row n_sym)."""
    n_sym = pm.shape[1] - 1
    idx = jnp.clip(codes_j.astype(jnp.int32), 0, n_sym)
    return jnp.take_along_axis(pm, idx[:, None, None], axis=1)[:, 0]


def build_pm_ext(pat_codes, nw, n_symbols=N_SYMBOLS):
    """PM with an extra all-ones row for sentinel text characters (any text
    code >= n_symbols, e.g. SENTINEL_TEXT, selects it via _lookup_pm)."""
    pm = build_pm(pat_codes, nw, n_symbols)
    ones = jnp.full(pm.shape[:-2] + (1, pm.shape[-1]), 0xFFFFFFFF, jnp.uint32)
    return jnp.concatenate([pm, ones], axis=-2)


def _dist_from_final(r_final, m_len, k):
    """min d whose target bit (m_len-1) is 0, else k+1."""
    bits = get_bit(r_final, jnp.asarray(m_len)[:, None] - 1)  # (B, k+1)
    d_arange = jnp.arange(k + 1, dtype=jnp.int32)
    cand = jnp.where(bits == 0, d_arange[None, :], k + 1)
    dist = jnp.min(cand, axis=1).astype(jnp.int32)
    return dist, dist <= k


@partial(jax.jit, static_argnames=("k", "n", "store", "nw"))
def dc_jmajor(pat_codes, text_codes, m_len, n_len, *, k: int, n: int,
              nw: int, store: str = "and") -> DCResult:
    """Text-major GenASM-DC with full-bitvector storage.

    pat_codes: (B, <=m_pad) int; positions >= m_len hold sentinel 255.
    text_codes: (B, n) int; positions >= n_len hold sentinel (>=n_symbols).
    Returns storage with column axis leading: (n+1, B, k+1, NW[, 4]).
    """
    B = pat_codes.shape[0]
    pm = build_pm_ext(pat_codes, nw)
    d_ar = jnp.arange(k + 1, dtype=jnp.int32)
    r0 = jnp.broadcast_to(ones_below(d_ar, nw), (B, k + 1, nw))

    def step(r_prev, j):
        cj = text_codes[:, j - 1]
        pm_j = _lookup_pm(pm, cj)[:, None, :]                   # (B,1,NW)
        bM, bS, bI = _boundary_bits(j, d_ar)                    # (k+1,)
        # All-level match term (vectorized over d); the I term couples levels
        # sequentially, resolved with an unrolled level pass below.
        M = shift1(r_prev, bM[None, :, None]) | pm_j
        S = shift1(r_prev[:, :-1], bS[None, 1:, None])
        Dl = r_prev[:, :-1]
        rows = [M[:, 0]]
        full = jnp.full_like(rows[0], 0xFFFFFFFF)
        Ms, Ss, Ds, Is = [M[:, 0]], [full], [full], [full]
        for d in range(1, k + 1):
            I = shift1(rows[d - 1], bI[d])
            r_d = M[:, d] & S[:, d - 1] & Dl[:, d - 1] & I
            rows.append(r_d)
            if store == "edges4":
                Ms.append(M[:, d]); Ss.append(S[:, d - 1])
                Ds.append(Dl[:, d - 1]); Is.append(I)
        r_new = jnp.stack(rows, axis=1)
        # freeze columns beyond each problem's true text length
        live = (j <= n_len)[:, None, None]
        r_new = jnp.where(live, r_new, r_prev)
        if store == "edges4":
            edges = jnp.stack([jnp.stack(v, 1) for v in (Ms, Ss, Ds, Is)], -1)
            ys = (r_new, jnp.where(live[..., None], edges,
                                   jnp.full_like(edges, 0xFFFFFFFF)))
        else:
            ys = (r_new, None)
        return r_new, ys

    r_fin, (r_cols, edge_cols) = jax.lax.scan(step, r0, jnp.arange(1, n + 1))
    r_cols = jnp.concatenate([r0[None], r_cols], axis=0)        # (n+1,B,k+1,NW)
    dist, solved = _dist_from_final(r_fin, m_len, k)
    st = {"R": r_cols}
    if store == "edges4":
        init_edges = jnp.full((1,) + edge_cols.shape[1:], 0xFFFFFFFF, jnp.uint32)
        st["edges"] = jnp.concatenate([init_edges, edge_cols], axis=0)
    return DCResult(dist, solved, r_fin, st, jnp.int32(k + 1))


@partial(jax.jit, static_argnames=("cfg",))
def dc_dmajor(pat_codes, text_codes, *, cfg: AlignerConfig) -> DCResult:
    """Level-major improved GenASM-DC: ET + SENE + DENT band storage.

    Uniform square windows: pat_codes (B, m_pad) with sentinel padding past W,
    text_codes (B, W).  Whole-batch early termination: the level loop stops
    as soon as every problem's solution is contained in the computed levels
    (per-problem ET is accounted exactly by `levels_needed` = dist+1).
    """
    B = pat_codes.shape[0]
    W, k, nw, nwb = cfg.W, cfg.k, cfg.nw, cfg.nwb
    n = W
    ncb = cfg.ncols_band
    col0 = n + 1 - ncb
    pm = build_pm_ext(pat_codes, nw)
    tgt = jnp.int32(W - 1)

    bases = jnp.array([cfg.band_base(j) for j in range(n + 1)], jnp.int32)

    def fill_level(d, prev_row):
        """Fill level d (traced, >= 1) given full prev row (n+1, B, NW)."""
        def stepj(r_prev, j):
            cj = text_codes[:, j - 1]
            pm_j = _lookup_pm(pm, cj)
            bM, bS, bI = _boundary_bits(j, d)
            M = shift1(r_prev, bM) | pm_j
            S = shift1(prev_row[j - 1], bS)
            Dl = prev_row[j - 1]
            I = shift1(prev_row[j], bI)
            r = M & S & Dl & I
            return r, r
        r_init = ones_below(jnp.full((B,), d, jnp.int32), nw)
        _, cols = jax.lax.scan(stepj, r_init, jnp.arange(1, n + 1))
        return jnp.concatenate([r_init[None], cols], axis=0)   # (n+1, B, NW)

    def extract_band(row):
        # row: (n+1, B, NW) -> (ncb, B, NWB) band windows for stored columns
        return extract_window(row[col0:], bases[col0:, None], nwb)

    # --- level 0 (recurrence differs: R = M only) ---
    def step0(r_prev, j):
        pm_j = _lookup_pm(pm, text_codes[:, j - 1])
        bM, _, _ = _boundary_bits(j, 0)
        r = shift1(r_prev, bM) | pm_j
        return r, r
    r_init0 = ones_below(jnp.zeros((B,), jnp.int32), nw)
    _, cols0 = jax.lax.scan(step0, r_init0, jnp.arange(1, n + 1))
    row0 = jnp.concatenate([r_init0[None], cols0], axis=0)

    band_buf = jnp.zeros((k + 1, ncb, B, nwb), jnp.uint32)
    band_buf = band_buf.at[0].set(extract_band(row0))
    dist = jnp.where(get_bit(row0[n], tgt) == 0, 0, k + 1).astype(jnp.int32)

    # --- levels 1..k with (optional) whole-batch early termination ---
    def level_body(state):
        d, prev_row, band_buf, dist = state
        row = fill_level(d, prev_row)
        band_buf = band_buf.at[d].set(extract_band(row))
        hit = get_bit(row[n], tgt) == 0
        dist = jnp.where((dist > k) & hit, d, dist)
        return d + 1, row, band_buf, dist

    def level_cond(state):
        d, _, _, dist = state
        go = d <= k
        if cfg.early_term:
            go &= jnp.any(dist > k)
        return go

    d_end, _, band_buf, dist = jax.lax.while_loop(
        level_cond, level_body, (jnp.int32(1), row0, band_buf, dist))

    solved = dist <= k
    store = {"Rb": band_buf}
    r_fin = jnp.zeros((B, k + 1, nw), jnp.uint32)  # not used in band mode
    return DCResult(dist, solved, r_fin, store, d_end)


def dc(pat_codes, text_codes, m_len, n_len, cfg: AlignerConfig,
       mesh=None) -> DCResult:
    """Dispatch: improved configs use the level-major banded fill when the
    batch is uniform square (m_len = n_len = W); otherwise the full fill.
    cfg.backend routes the banded fill to the Pallas DC kernel ('pallas' /
    'pallas_fused' — the fused TB entry point lives in kernels.ops and is
    dispatched by core.windowing, which also owns the traceback).  `mesh`
    shard_maps the kernel dispatch over the mesh's pair axes (jnp fills
    ignore it — GSPMD shards them from the caller's constraints)."""
    if cfg.store == "band":
        if cfg.backend in ("pallas", "pallas_fused", "pallas_gpu"):
            # local import: kernels.ops imports build_pm_ext from this module
            from ..kernels.ops import default_interpret, genasm_dc_op
            dist, band, lvl = genasm_dc_op(
                pat_codes, text_codes, cfg=cfg,
                interpret=default_interpret(cfg.backend), mesh=mesh)
            B = pat_codes.shape[0]
            r_fin = jnp.zeros((B, cfg.k + 1, cfg.nw), jnp.uint32)
            return DCResult(dist, dist <= cfg.k, r_fin, {"Rb": band}, lvl)
        return dc_dmajor(pat_codes, text_codes, cfg=cfg)
    return dc_jmajor(pat_codes, text_codes, m_len, n_len, k=cfg.k,
                     n=text_codes.shape[1], nw=cfg.nw, store=cfg.store)
