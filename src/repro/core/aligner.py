"""Public aligner API: batch alignment of (read, candidate-ref) pairs with
failure rescue, host-side padding, and CIGAR decoding."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .config import AlignerConfig
from .oracle import OP_CHARS
from .cigar import ops_to_string
from .traceback import OP_NONE
from .windowing import SENTINEL_READ, SENTINEL_REF, align_pairs, self_tail_width

DNA = "ACGT"


def encode(seq: str) -> np.ndarray:
    lut = np.full(128, SENTINEL_READ, np.uint8)
    for i, c in enumerate(DNA):
        lut[ord(c)] = i
        lut[ord(c.lower())] = i
    return lut[np.frombuffer(seq.encode(), np.uint8)]


@dataclasses.dataclass
class AlignResult:
    dist: np.ndarray          # (B,) edit cost of the produced alignment
    cigars: list[str]         # run-length encoded, front-first, '=XID'
    ops: list[np.ndarray]     # raw op arrays
    failed: np.ndarray        # (B,) True if unalignable within rescue budget
    k_used: np.ndarray        # (B,) per-window threshold that succeeded


class GenASMAligner:
    """Batch long-read aligner implementing the paper's improved GenASM.

    cfg.store/early_term select the variant (defaults = all three paper
    improvements on); cfg.backend (or the `backend` override) selects the
    execution path — 'jnp', 'pallas' (kernel DC + host traceback) or
    'pallas_fused' (DC+TB fused on-chip).  Pairs whose per-window edit
    distance exceeds cfg.k are retried with doubled k up to `rescue_rounds`
    times (host-side), mirroring common practice for threshold-based
    aligners; rescue rounds reuse the same backend with the doubled k.
    """

    def __init__(self, cfg: AlignerConfig = AlignerConfig(),
                 rescue_rounds: int = 2, backend: str | None = None):
        if backend is not None:
            cfg = dataclasses.replace(cfg, backend=backend)
        self.cfg = cfg
        self.rescue_rounds = rescue_rounds

    def _pad(self, seqs, width, pad_val):
        B = len(seqs)
        out = np.full((B, width), pad_val, np.uint8)
        lens = np.zeros(B, np.int32)
        for i, s in enumerate(seqs):
            lens[i] = len(s)
            out[i, :len(s)] = s
        return out, lens

    def align(self, reads, refs) -> AlignResult:
        """reads/refs: lists of np.uint8 code arrays (see `encode`)."""
        assert len(reads) == len(refs)
        B = len(reads)
        max_r = max(len(r) for r in reads)
        cfg = self.cfg
        dist = np.zeros(B, np.int64)
        failed = np.ones(B, bool)
        k_used = np.zeros(B, np.int32)
        all_ops: list[np.ndarray | None] = [None] * B
        todo = np.arange(B)
        for rnd in range(self.rescue_rounds + 1):
            if len(todo) == 0:
                break
            sub_reads = [reads[i] for i in todo]
            sub_refs = [refs[i] for i in todo]
            max_read_len = max(len(r) for r in sub_reads)
            wt = self_tail_width(cfg)
            rpad, rlen = self._pad(sub_reads, max_read_len + cfg.W + 1,
                                   SENTINEL_READ)
            fpad, flen = self._pad(sub_refs,
                                   max(len(f) for f in sub_refs) + cfg.W + wt + 1,
                                   SENTINEL_REF)
            out = align_pairs(jnp.asarray(rpad), jnp.asarray(rlen),
                              jnp.asarray(fpad), jnp.asarray(flen),
                              cfg=cfg, max_read_len=max_read_len)
            ops = np.asarray(out["ops"])
            n_ops = np.asarray(out["n_ops"])
            ok = ~np.asarray(out["failed"])
            d = np.asarray(out["dist"])
            for loc, glob in enumerate(todo):
                if ok[loc]:
                    all_ops[glob] = ops[loc, :n_ops[loc]]
                    dist[glob] = d[loc]
                    failed[glob] = False
                    k_used[glob] = cfg.k
            todo = todo[~ok[np.arange(len(todo))]] if len(todo) else todo
            todo = np.array([g for g in todo if failed[g]])
            # rescue: double k (capped below W so the band math stays valid)
            new_k = min(cfg.k * 2, cfg.W - 1)
            if new_k == cfg.k:
                break
            cfg = dataclasses.replace(cfg, k=new_k)
        cigars = [ops_to_string(o) if o is not None else "" for o in all_ops]
        ops_out = [o if o is not None else np.zeros(0, np.uint8) for o in all_ops]
        return AlignResult(dist, cigars, ops_out, failed, k_used)
