"""Public aligner API: batch alignment of (read, candidate-ref) pairs with
failure rescue, host-side padding, and CIGAR decoding.

Rescue (pairs whose per-window edit distance exceeds cfg.k retried with
doubled k) runs in one of two modes:

* ``device`` (default) — a single jitted ``align_pairs_rescued`` call: all
  k-doubling rounds execute on-device under a per-lane mask, so a batch is
  uploaded once and downloaded once no matter how many rounds run.
* ``host`` — the legacy numpy loop (re-pad and re-upload the failed subset
  every round).  Kept as the differential reference: both modes are
  bit-identical per lane (ops, dist, k_used, failed — see
  tests/test_rescue.py) and both are transfer-accounted via core.transfer.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import transfer
from .config import AlignerConfig, resolve_config
from .cigar import decode_batch, ops_to_string, records_from_state
from .windowing import (SENTINEL_READ, SENTINEL_REF, align_pairs,
                        align_pairs_rescued, pad_geometry)

DNA = "ACGT"


def encode(seq: str) -> np.ndarray:
    """Encode a READ: non-ACGT chars (N, IUPAC codes) -> SENTINEL_READ,
    which never matches any reference character."""
    lut = np.full(128, SENTINEL_READ, np.uint8)
    for i, c in enumerate(DNA):
        lut[ord(c)] = i
        lut[ord(c.lower())] = i
    return lut[np.frombuffer(seq.encode(), np.uint8)]


def encode_ref(seq: str) -> np.ndarray:
    """Encode a REFERENCE: non-ACGT chars -> SENTINEL_REF (the all-ones PM
    row), which never matches any read character — including a read 'N'.

    Refs must NOT be encoded with ``encode``: a ref 'N' mapped to
    SENTINEL_READ would raw-compare equal to a read 'N' in the jnp
    traceback while the DP's pattern masks say mismatch, diverging from
    the PM-based Pallas kernels.  ``encode_ref`` keeps all backends (and
    the DP itself) consistent: N never matches anything.
    """
    lut = np.full(128, SENTINEL_REF, np.uint8)
    for i, c in enumerate(DNA):
        lut[ord(c)] = i
        lut[ord(c.lower())] = i
    return lut[np.frombuffer(seq.encode(), np.uint8)]


@dataclasses.dataclass
class AlignResult:
    dist: np.ndarray          # (B,) edit cost of the produced alignment
    cigars: list[str]         # run-length encoded, front-first, '=XID'
    ops: list[np.ndarray]     # raw op arrays
    failed: np.ndarray        # (B,) True if unalignable within rescue budget
    k_used: np.ndarray        # (B,) per-window threshold that succeeded
    read_consumed: np.ndarray | None = None  # (B,) read chars CIGAR consumes
    ref_consumed: np.ndarray | None = None   # (B,) ref chars CIGAR consumes

    def summary(self, n: int | None = None,
                base_k: int | None = None) -> dict:
        """Aggregate stats over the first `n` lanes (all by default) — the
        one summary dict the serving engine, the session front door and the
        benchmarks share instead of ad-hoc per-caller dicts.  Pass `n` to
        exclude padding lanes, `base_k` (the pre-rescue threshold) to also
        count rescued lanes."""
        n = len(self.cigars) if n is None else n
        failed = np.asarray(self.failed[:n], bool)
        ok = ~failed
        out = {
            "n_pairs": int(n),
            "n_aligned": int(ok.sum()),
            "n_failed": int(failed.sum()),
            "total_edits": int(np.asarray(self.dist[:n])[ok].sum()),
            "total_ops": int(sum(len(self.ops[i]) for i in range(n)
                                 if ok[i])),
            "max_k_used": int(np.asarray(self.k_used[:n]).max(initial=0)),
        }
        if base_k is not None:
            out["n_rescued"] = int(
                (np.asarray(self.k_used[:n])[ok] > base_k).sum())
        if self.read_consumed is not None:
            out["read_bp"] = int(np.asarray(self.read_consumed[:n])[ok].sum())
        if self.ref_consumed is not None:
            out["ref_bp"] = int(np.asarray(self.ref_consumed[:n])[ok].sum())
        return out

    @classmethod
    def from_records(cls, recs: list) -> "AlignResult":
        """Assemble a batch AlignResult from per-lane result records (the
        shape produced by core.cigar.records_from_state and returned by
        session futures) — the one assembly both doors share."""
        return cls(
            np.array([r["dist"] for r in recs], np.int64),
            [r["cigar"] for r in recs],
            [r["ops"] for r in recs],
            np.array([not r["ok"] for r in recs], bool),
            np.array([r["k_used"] for r in recs], np.int32),
            np.array([r["read_consumed"] for r in recs], np.int32),
            np.array([r["ref_consumed"] for r in recs], np.int32))


class GenASMAligner:
    """Batch long-read aligner implementing the paper's improved GenASM.

    cfg.store/early_term select the variant (defaults = all three paper
    improvements on); cfg.backend (or the `backend` override) selects the
    execution path — 'jnp', 'pallas' (kernel DC + host traceback) or
    'pallas_fused' (DC+TB fused on-chip, including the rectangular tail
    window).  Pairs whose per-window edit distance exceeds cfg.k are
    retried with doubled k up to `rescue_rounds` times; `rescue_mode`
    selects the on-device masked multi-round path (default) or the legacy
    host loop (see module docstring).

    .. deprecated:: PR 4
        This is the legacy exact-shape door: pad widths derive from each
        batch's max_read_len, so every new length triggers a fresh jit
        trace.  New code should plan a ``repro.api.AlignSession`` (length
        -bucketed AOT-compiled executables, streaming submit/results) —
        see docs/api.md for the migration table.  Kept indefinitely as the
        bit-exactness reference the session is tested against.
    """

    def __init__(self, cfg: AlignerConfig = AlignerConfig(),
                 rescue_rounds: int = 2, backend: str | None = None,
                 rescue_mode: str = "device", mesh=None):
        cfg = resolve_config(cfg, backend=backend)
        assert rescue_mode in ("device", "host")
        self.cfg = cfg
        self.rescue_rounds = rescue_rounds
        self.rescue_mode = rescue_mode
        # mesh: shard every align call's pair axis over the mesh's data
        # axes (shard_map'd Pallas dispatch + GSPMD jnp) — results are
        # bit-identical to mesh=None (tests/test_multidevice.py)
        self.mesh = mesh

    def _pad(self, seqs, width, pad_val):
        B = len(seqs)
        out = np.full((B, width), pad_val, np.uint8)
        lens = np.zeros(B, np.int32)
        for i, s in enumerate(seqs):
            lens[i] = len(s)
            out[i, :len(s)] = s
        return out, lens

    def align(self, reads, refs) -> AlignResult:
        """reads/refs: lists of np.uint8 code arrays (see `encode` /
        `encode_ref`)."""
        assert len(reads) == len(refs)
        if self.rescue_mode == "host":
            return self._align_host_loop(reads, refs)
        return self._align_device(reads, refs)

    def _align_device(self, reads, refs) -> AlignResult:
        """One upload, one jitted multi-round rescue, one download."""
        cfg = self.cfg
        max_read_len = max(len(r) for r in reads)
        # pad ref sentinels for the FINAL rescue round's tail width
        Lr, Lf = pad_geometry(cfg, max_read_len, max(len(f) for f in refs),
                              self.rescue_rounds)
        rpad, rlen = self._pad(reads, Lr, SENTINEL_READ)
        fpad, flen = self._pad(refs, Lf, SENTINEL_REF)
        dev = transfer.to_device((rpad, rlen, fpad, flen))
        out = align_pairs_rescued(*dev, cfg=cfg, max_read_len=max_read_len,
                                  rescue_rounds=self.rescue_rounds,
                                  mesh=self.mesh)
        host = transfer.to_host({key: out[key] for key in
                                 ("ops", "n_ops", "dist", "failed", "k_used",
                                  "read_consumed", "ref_consumed")})
        # the same decode entrypoint the session's retire executor runs
        # off-thread (failed lanes report zeros either way)
        return AlignResult.from_records(
            records_from_state(*decode_batch(host, len(reads), cfg.k)))

    def _align_host_loop(self, reads, refs) -> AlignResult:
        """Legacy rescue: re-pad and re-upload the failed subset per round."""
        B = len(reads)
        cfg = self.cfg
        dist = np.zeros(B, np.int64)
        failed = np.ones(B, bool)
        k_used = np.zeros(B, np.int32)
        rcon = np.zeros(B, np.int32)
        fcon = np.zeros(B, np.int32)
        all_ops: list[np.ndarray | None] = [None] * B
        todo = np.arange(B)
        for rnd in range(self.rescue_rounds + 1):
            if len(todo) == 0:
                break
            sub_reads = [reads[i] for i in todo]
            sub_refs = [refs[i] for i in todo]
            max_read_len = max(len(r) for r in sub_reads)
            Lr, Lf = pad_geometry(cfg, max_read_len,
                                  max(len(f) for f in sub_refs), 0)
            rpad, rlen = self._pad(sub_reads, Lr, SENTINEL_READ)
            fpad, flen = self._pad(sub_refs, Lf, SENTINEL_REF)
            dev = transfer.to_device((rpad, rlen, fpad, flen))
            out = align_pairs(*dev, cfg=cfg, max_read_len=max_read_len,
                              mesh=self.mesh)
            host = transfer.to_host({key: out[key] for key in
                                     ("ops", "n_ops", "dist", "failed",
                                      "read_consumed", "ref_consumed")})
            ops = host["ops"]
            n_ops = host["n_ops"]
            ok = ~host["failed"]
            d = host["dist"]
            for loc, glob in enumerate(todo):
                if ok[loc]:
                    all_ops[glob] = ops[loc, :n_ops[loc]]
                    dist[glob] = d[loc]
                    failed[glob] = False
                    k_used[glob] = cfg.k
                    rcon[glob] = host["read_consumed"][loc]
                    fcon[glob] = host["ref_consumed"][loc]
            todo = np.array([g for g in todo if failed[g]])
            # rescue: double k (capped below W so the band math stays valid)
            new_k = min(cfg.k * 2, cfg.W - 1)
            if new_k == cfg.k:
                break
            cfg = dataclasses.replace(cfg, k=new_k)
        cigars = [ops_to_string(o) if o is not None else "" for o in all_ops]
        ops_out = [o if o is not None else np.zeros(0, np.uint8) for o in all_ops]
        return AlignResult(dist, cigars, ops_out, failed, k_used, rcon, fcon)
