"""Alignment serving engine — now a thin shim over the session front door
(`repro.api.AlignSession`): the engine keeps its micro-batching queue and
legacy stats/results surface, but every batch executes through the
session's length-bucketed, AOT-compiled executables, so a ragged request
stream no longer re-traces per distinct batch shape.

.. deprecated:: PR 4
    New code should ``plan()`` a session directly (submit/futures,
    double-buffered dispatch, warm-up as a method — see docs/api.md).
    This class remains for the engine-shaped call sites and tests."""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from ..api import plan
from ..core.config import AlignerConfig
from ..distributed.sharding import pair_pad_multiple, quantise_lanes


@dataclasses.dataclass
class AlignRequest:
    rid: int
    read: np.ndarray
    ref: np.ndarray


class AlignmentEngine:
    """Micro-batching server: collects requests to batches of `batch_size`
    (or `max_wait_s`), aligns through an AlignSession, returns per-request
    results.  Failed pairs (k exceeded after rescue) are reported
    unaligned, mirroring aligner thresholds in production mappers.

    Ragged final batches are padded up (stable shapes) by REPEATING the
    last real pair: a repeated real pair is exactly as alignable as its
    twin, so padding lanes can neither keep the rescue ladder running
    extra k-doubling rounds nor leak into per-request stats — padded
    lanes are dropped before results/stats are recorded.  (The session
    applies the same trick again at its lane quantum.)

    Sharded serving: pass `mesh` and every batch runs sharded over the
    mesh's pair axes (shard_map'd Pallas hot path — see kernels.ops).
    Batch sizes are quantised to `pair_pad_multiple(cfg, mesh)` =
    lane_tile * n_devices for the Pallas backends (n_devices for jnp), so
    a ragged batch can never hand devices unequal shards or split a
    kernel tile across devices; `batch_size` itself is rounded up to that
    quantum at construction.  Unsharded (mesh=None) the quantum is 1 and
    behaviour is unchanged."""

    def __init__(self, cfg: AlignerConfig = AlignerConfig(),
                 batch_size: int = 64, max_wait_s: float = 0.05,
                 backend: str | None = None, rescue_rounds: int = 2,
                 pad_to_batch: bool = True, mesh=None,
                 executor: str = "sync", adaptive_lanes: bool = False,
                 cache="shared", obs=None):
        # the engine's aligner IS a planned session: one spec resolution,
        # bucketed AOT executables, compacted bucket rescue.  executor /
        # adaptive_lanes / cache / obs pass straight through to the
        # session (background retire thread, occupancy-adaptive lane
        # classes, process-shared compile cache, observability domain —
        # see docs/api.md and docs/observability.md)
        self.aligner = plan(cfg, backend=backend,
                            rescue_rounds=rescue_rounds,
                            batch_lanes=batch_size, mesh=mesh,
                            executor=executor,
                            adaptive_lanes=adaptive_lanes, cache=cache,
                            obs=obs)
        self.obs = self.aligner.obs
        self.pad_multiple = pair_pad_multiple(self.aligner.cfg, mesh)
        self.batch_size = quantise_lanes(batch_size, self.aligner.cfg, mesh)
        self.max_wait_s = max_wait_s
        self.pad_to_batch = pad_to_batch
        self.queue: deque[AlignRequest] = deque()
        self.results: dict[int, dict] = {}
        self.stats = {"batches": 0, "aligned": 0, "failed": 0,
                      "padded_lanes": 0, "wall_s": 0.0}

    def submit(self, req: AlignRequest):
        self.queue.append(req)

    def _pad_target(self, n: int) -> int:
        """Lanes this batch is padded to: batch_size when pad_to_batch,
        else the next pair_pad_multiple (both keep shards equal and
        tile-aligned on a mesh; the session further quantises lanes to
        its power-of-two batch classes)."""
        base = self.batch_size if self.pad_to_batch else n
        return quantise_lanes(base, self.aligner.cfg, self.aligner.mesh)

    def _run_batch(self, batch):
        t0 = time.time()
        reads = [r.read for r in batch]
        refs = [r.ref for r in batch]
        n_pad = self._pad_target(len(batch)) - len(batch)
        if n_pad > 0:
            reads = reads + [reads[-1]] * n_pad
            refs = refs + [refs[-1]] * n_pad
        res = self.aligner.align(reads, refs)
        dt = time.time() - t0
        s = res.summary(len(batch))        # padding lanes never counted
        self.stats["batches"] += 1
        self.stats["padded_lanes"] += max(0, n_pad)
        self.stats["wall_s"] += dt
        self.stats["aligned"] += s["n_aligned"]
        self.stats["failed"] += s["n_failed"]
        for i, r in enumerate(batch):
            self.results[r.rid] = {
                "ok": not res.failed[i], "dist": int(res.dist[i]),
                "cigar": res.cigars[i], "k_used": int(res.k_used[i]),
            }

    def flush(self):
        while self.queue:
            batch = [self.queue.popleft()
                     for _ in range(min(self.batch_size, len(self.queue)))]
            self._run_batch(batch)

    def serve_until_empty(self):
        self.flush()
        return self.stats

    def gateway(self, policy=None, clock=None, auto_pump: bool = True):
        """A multi-tenant Gateway fronting this engine's session: priority
        lanes, per-request deadlines, cancellation and load shedding over
        the same executables (see docs/api.md, "The multi-tenant
        gateway").  The caller owns the returned gateway's close(); the
        engine keeps owning the session."""
        from ..api import Gateway, GatewayPolicy
        return Gateway(self.aligner, policy or GatewayPolicy(),
                       clock=clock, auto_pump=auto_pump)

    def close(self):
        """Shut down the underlying session (stops its background retire
        thread when executor='thread'; a no-op for the sync executor)."""
        self.aligner.close()
