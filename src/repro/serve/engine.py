"""Alignment serving engine: batched request queue over the sharded
aligner — the GPU-batching analogue from the paper mapped to a pod
(requests fan out over the ('pod','data') mesh axes; each device runs the
GenASM kernel/jnp path on its shard).

Also provides a minimal LM decode engine (fixed batch slots + greedy
sampling) for the serving example of the transformer stack."""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from ..core.aligner import GenASMAligner
from ..core.config import AlignerConfig


@dataclasses.dataclass
class AlignRequest:
    rid: int
    read: np.ndarray
    ref: np.ndarray


class AlignmentEngine:
    """Micro-batching server: collects requests to batches of `batch_size`
    (or `max_wait_s`), aligns, returns per-request results.  Failed pairs
    (k exceeded after rescue) are reported unaligned, mirroring aligner
    thresholds in production mappers."""

    def __init__(self, cfg: AlignerConfig = AlignerConfig(),
                 batch_size: int = 64, max_wait_s: float = 0.05,
                 backend: str | None = None):
        self.aligner = GenASMAligner(cfg, backend=backend)
        self.batch_size = batch_size
        self.max_wait_s = max_wait_s
        self.queue: deque[AlignRequest] = deque()
        self.results: dict[int, dict] = {}
        self.stats = {"batches": 0, "aligned": 0, "failed": 0,
                      "wall_s": 0.0}

    def submit(self, req: AlignRequest):
        self.queue.append(req)

    def _run_batch(self, batch):
        t0 = time.time()
        res = self.aligner.align([r.read for r in batch],
                                 [r.ref for r in batch])
        dt = time.time() - t0
        self.stats["batches"] += 1
        self.stats["wall_s"] += dt
        for i, r in enumerate(batch):
            ok = not res.failed[i]
            self.stats["aligned" if ok else "failed"] += 1
            self.results[r.rid] = {
                "ok": ok, "dist": int(res.dist[i]),
                "cigar": res.cigars[i], "k_used": int(res.k_used[i]),
            }

    def flush(self):
        while self.queue:
            batch = [self.queue.popleft()
                     for _ in range(min(self.batch_size, len(self.queue)))]
            self._run_batch(batch)

    def serve_until_empty(self):
        self.flush()
        return self.stats
