"""Alignment serving engine: batched request queue over the sharded
aligner — the GPU-batching analogue from the paper mapped to a pod
(requests fan out over the ('pod','data') mesh axes; each device runs the
GenASM kernel/jnp path on its shard).

Also provides a minimal LM decode engine (fixed batch slots + greedy
sampling) for the serving example of the transformer stack."""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from ..core.aligner import GenASMAligner
from ..core.config import AlignerConfig
from ..distributed.sharding import pair_pad_multiple


@dataclasses.dataclass
class AlignRequest:
    rid: int
    read: np.ndarray
    ref: np.ndarray


class AlignmentEngine:
    """Micro-batching server: collects requests to batches of `batch_size`
    (or `max_wait_s`), aligns, returns per-request results.  Failed pairs
    (k exceeded after rescue) are reported unaligned, mirroring aligner
    thresholds in production mappers.

    Ragged final batches are padded up (stable jit shapes, no per-tail
    recompile) by REPEATING the last real pair: a repeated real pair is
    exactly as alignable as its twin, so padding lanes can neither keep
    the on-device rescue loop running extra k-doubling rounds (its round
    gate is `any(failed)`) nor leak into per-request stats — padded lanes
    are dropped before results/stats are recorded.

    Sharded serving: pass `mesh` and every batch runs sharded over the
    mesh's pair axes (shard_map'd Pallas hot path — see kernels.ops).
    Batch sizes are then quantised to `pair_pad_multiple(cfg, mesh)` =
    lane_tile * n_devices for the Pallas backends (n_devices for jnp), so
    a ragged batch can never hand devices unequal shards or split a
    kernel tile across devices; `batch_size` itself is rounded up to that
    quantum at construction.  Unsharded (mesh=None) the quantum is 1 and
    behaviour is unchanged."""

    def __init__(self, cfg: AlignerConfig = AlignerConfig(),
                 batch_size: int = 64, max_wait_s: float = 0.05,
                 backend: str | None = None, rescue_rounds: int = 2,
                 pad_to_batch: bool = True, mesh=None):
        self.aligner = GenASMAligner(cfg, rescue_rounds=rescue_rounds,
                                     backend=backend, mesh=mesh)
        self.pad_multiple = pair_pad_multiple(self.aligner.cfg, mesh)
        self.batch_size = -(-batch_size // self.pad_multiple) \
            * self.pad_multiple
        self.max_wait_s = max_wait_s
        self.pad_to_batch = pad_to_batch
        self.queue: deque[AlignRequest] = deque()
        self.results: dict[int, dict] = {}
        self.stats = {"batches": 0, "aligned": 0, "failed": 0,
                      "padded_lanes": 0, "wall_s": 0.0}

    def submit(self, req: AlignRequest):
        self.queue.append(req)

    def _pad_target(self, n: int) -> int:
        """Lanes this batch is padded to: batch_size when pad_to_batch,
        else the next pair_pad_multiple (both keep shards equal and
        tile-aligned on a mesh)."""
        base = self.batch_size if self.pad_to_batch else n
        return -(-base // self.pad_multiple) * self.pad_multiple

    def _run_batch(self, batch):
        t0 = time.time()
        reads = [r.read for r in batch]
        refs = [r.ref for r in batch]
        n_pad = self._pad_target(len(batch)) - len(batch)
        if n_pad > 0:
            reads = reads + [reads[-1]] * n_pad
            refs = refs + [refs[-1]] * n_pad
        res = self.aligner.align(reads, refs)
        dt = time.time() - t0
        self.stats["batches"] += 1
        self.stats["padded_lanes"] += max(0, n_pad)
        self.stats["wall_s"] += dt
        for i, r in enumerate(batch):      # padding lanes never reach here
            ok = not res.failed[i]
            self.stats["aligned" if ok else "failed"] += 1
            self.results[r.rid] = {
                "ok": ok, "dist": int(res.dist[i]),
                "cigar": res.cigars[i], "k_used": int(res.k_used[i]),
            }

    def flush(self):
        while self.queue:
            batch = [self.queue.popleft()
                     for _ in range(min(self.batch_size, len(self.queue)))]
            self._run_batch(batch)

    def serve_until_empty(self):
        self.flush()
        return self.stats
