"""Distributed alignment step: the paper's batched aligner sharded over
the production mesh (embarrassingly data-parallel across pairs; stats are
psum'd by GSPMD when reduced).  Used by the alignment service and the
aligner dry-run/roofline cell."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..core.config import AlignerConfig
from ..core.windowing import align_pairs, self_tail_width


def align_step(reads, read_len, refs, ref_len, *, cfg: AlignerConfig,
               max_read_len: int):
    out = align_pairs(reads, read_len, refs, ref_len, cfg=cfg,
                      max_read_len=max_read_len)
    # summary stats reduce across the whole batch (collectives over dp axes)
    summary = {
        "n_failed": jnp.sum(out["failed"].astype(jnp.int32)),
        "total_edits": jnp.sum(out["dist"]),
        "total_ops": jnp.sum(out["n_ops"]),
    }
    return out, summary


def make_align_step(cfg: AlignerConfig, max_read_len: int, mesh):
    """out_shardings are explicit: without them GSPMD replicates the CIGAR
    buffer to every device (a ~1.7 GB all-gather for 128k pairs — §Perf
    aligner iteration in EXPERIMENTS.md)."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    bsh = NamedSharding(mesh, P(dp, None))
    vsh = NamedSharding(mesh, P(dp))
    rep = NamedSharding(mesh, P())
    out_sh = ({"ops": bsh, "n_ops": vsh, "dist": vsh, "failed": vsh,
               "read_consumed": vsh, "ref_consumed": vsh,
               "levels_run_total": rep, "n_main_windows": rep},
              {"n_failed": rep, "total_edits": rep, "total_ops": rep})
    fn = partial(align_step, cfg=cfg, max_read_len=max_read_len)
    return jax.jit(fn, in_shardings=(bsh, vsh, bsh, vsh),
                   out_shardings=out_sh)


def align_input_specs(batch: int, read_len: int, cfg: AlignerConfig):
    """ShapeDtypeStructs for the aligner dry-run cell."""
    wt = self_tail_width(cfg)
    Lr = read_len + cfg.W + 1
    Lf = int(read_len * 1.3) + cfg.W + wt + 1
    sds = jax.ShapeDtypeStruct
    return (sds((batch, Lr), jnp.uint8), sds((batch,), jnp.int32),
            sds((batch, Lf), jnp.uint8), sds((batch,), jnp.int32))
