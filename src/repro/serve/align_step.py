"""Distributed alignment step: the paper's batched aligner sharded over
the production mesh (embarrassingly data-parallel across pairs; stats are
psum'd by GSPMD when reduced).  Used by the alignment service and the
aligner dry-run/roofline cell.

One factory serves every variant: ``make_align_step(cfg, L, mesh)`` is the
plain windowed step, ``make_align_step(cfg, L, mesh, rescue_rounds=r)``
the on-device k-doubling ladder — both thread the mesh all the way into
``core.windowing`` so the Pallas hot path runs shard_map'd per device
(kernels.ops), not just the jnp fills.  The former trio of near-identical
factories (plain / rescued / per-call wrappers) collapsed into this one;
``make_align_step_rescued`` remains as a thin alias."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core.config import AlignerConfig
from ..core.windowing import (align_pairs, align_pairs_rescued,
                              bucket_avals)
from ..distributed.sharding import pair_shardings


def align_step(reads, read_len, refs, ref_len, *, cfg: AlignerConfig,
               max_read_len: int, rescue_rounds: int | None = None,
               mesh=None):
    """One batched alignment step + summary stats.  rescue_rounds=None runs
    plain ``align_pairs``; an int runs the on-device k-doubling ladder
    (every round inside this one jitted step — no host round-trips between
    rounds on any shard).  Summary stats reduce across the whole batch
    (collectives over the pair axes when sharded)."""
    if rescue_rounds is None:
        out = align_pairs(reads, read_len, refs, ref_len, cfg=cfg,
                          max_read_len=max_read_len, mesh=mesh)
    else:
        out = align_pairs_rescued(reads, read_len, refs, ref_len, cfg=cfg,
                                  max_read_len=max_read_len,
                                  rescue_rounds=rescue_rounds, mesh=mesh)
    summary = {
        "n_failed": jnp.sum(out["failed"].astype(jnp.int32)),
        "total_edits": jnp.sum(out["dist"]),
        "total_ops": jnp.sum(out["n_ops"]),
    }
    if rescue_rounds is not None:
        summary["n_rescued"] = jnp.sum(
            (~out["failed"] & (out["k_used"] > cfg.k)).astype(jnp.int32))
        summary["rounds_run"] = out["rounds_run"]
    return out, summary


def make_align_step(cfg: AlignerConfig, max_read_len: int, mesh,
                    rescue_rounds: int | None = None):
    """The align-step factory (plain or rescued, one code path) — also the
    executable builder behind ``repro.api.AlignSession``: the session
    AOT-lowers this jit per length bucket (``.lower(*bucket_avals)
    .compile()``) so steady-state serving never re-traces.

    With ``mesh=None`` it is a plain jit (single device, no shardings).
    Sharded, out_shardings are explicit: without them GSPMD replicates the
    CIGAR buffer to every device (a ~1.7 GB all-gather for 128k pairs —
    §Perf aligner iteration in EXPERIMENTS.md).  Per-lane outputs (k_used,
    the op buffer, consumption) shard with the batch; scalar stats and
    round counters replicate."""
    fn = partial(align_step, cfg=cfg, max_read_len=max_read_len,
                 rescue_rounds=rescue_rounds, mesh=mesh)
    if mesh is None:
        return jax.jit(fn)
    bsh, vsh, rep = pair_shardings(mesh)
    out_lanes = {"ops": bsh, "n_ops": vsh, "dist": vsh, "failed": vsh,
                 "read_consumed": vsh, "ref_consumed": vsh,
                 "levels_run_total": rep, "n_main_windows": rep}
    sum_sh = {"n_failed": rep, "total_edits": rep, "total_ops": rep}
    if rescue_rounds is not None:
        out_lanes = dict(out_lanes, k_used=vsh, rounds_run=rep, n_rounds=rep)
        del out_lanes["n_main_windows"]
        sum_sh = dict(sum_sh, n_rescued=rep, rounds_run=rep)
    return jax.jit(fn, in_shardings=(bsh, vsh, bsh, vsh),
                   out_shardings=(out_lanes, sum_sh))


def make_align_step_rescued(cfg: AlignerConfig, max_read_len: int, mesh,
                            rescue_rounds: int = 2):
    """Alias for make_align_step(..., rescue_rounds=rescue_rounds)."""
    return make_align_step(cfg, max_read_len, mesh,
                           rescue_rounds=rescue_rounds)


def align_input_specs(batch: int, read_len: int, cfg: AlignerConfig,
                      rescue_rounds: int = 0):
    """ShapeDtypeStructs for the aligner dry-run cell — the bucket_avals
    geometry with the dry-run's 1.3x read->ref length model.  With
    rescue_rounds, the ref padding covers the FINAL round's tail width
    (the contract of align_pairs_rescued)."""
    return bucket_avals(cfg, batch, read_len, int(read_len * 1.3),
                        rescue_rounds)
