"""Distributed alignment step: the paper's batched aligner sharded over
the production mesh (embarrassingly data-parallel across pairs; stats are
psum'd by GSPMD when reduced).  Used by the alignment service and the
aligner dry-run/roofline cell."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..core.config import AlignerConfig
from ..core.windowing import (align_pairs, align_pairs_rescued,
                              rescue_schedule, self_tail_width)


def align_step(reads, read_len, refs, ref_len, *, cfg: AlignerConfig,
               max_read_len: int):
    out = align_pairs(reads, read_len, refs, ref_len, cfg=cfg,
                      max_read_len=max_read_len)
    # summary stats reduce across the whole batch (collectives over dp axes)
    summary = {
        "n_failed": jnp.sum(out["failed"].astype(jnp.int32)),
        "total_edits": jnp.sum(out["dist"]),
        "total_ops": jnp.sum(out["n_ops"]),
    }
    return out, summary


def align_step_rescued(reads, read_len, refs, ref_len, *, cfg: AlignerConfig,
                       max_read_len: int, rescue_rounds: int):
    """Sharded alignment with the on-device k-doubling rescue: every rescue
    round stays inside the one jitted step (no host round-trips between
    rounds on any shard)."""
    out = align_pairs_rescued(reads, read_len, refs, ref_len, cfg=cfg,
                              max_read_len=max_read_len,
                              rescue_rounds=rescue_rounds)
    summary = {
        "n_failed": jnp.sum(out["failed"].astype(jnp.int32)),
        "n_rescued": jnp.sum((~out["failed"] &
                              (out["k_used"] > cfg.k)).astype(jnp.int32)),
        "total_edits": jnp.sum(out["dist"]),
        "total_ops": jnp.sum(out["n_ops"]),
        "rounds_run": out["rounds_run"],
    }
    return out, summary


def make_align_step(cfg: AlignerConfig, max_read_len: int, mesh):
    """out_shardings are explicit: without them GSPMD replicates the CIGAR
    buffer to every device (a ~1.7 GB all-gather for 128k pairs — §Perf
    aligner iteration in EXPERIMENTS.md)."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    bsh = NamedSharding(mesh, P(dp, None))
    vsh = NamedSharding(mesh, P(dp))
    rep = NamedSharding(mesh, P())
    out_sh = ({"ops": bsh, "n_ops": vsh, "dist": vsh, "failed": vsh,
               "read_consumed": vsh, "ref_consumed": vsh,
               "levels_run_total": rep, "n_main_windows": rep},
              {"n_failed": rep, "total_edits": rep, "total_ops": rep})
    fn = partial(align_step, cfg=cfg, max_read_len=max_read_len)
    return jax.jit(fn, in_shardings=(bsh, vsh, bsh, vsh),
                   out_shardings=out_sh)


def make_align_step_rescued(cfg: AlignerConfig, max_read_len: int, mesh,
                            rescue_rounds: int = 2):
    """Sharded on-device-rescue step (see make_align_step for the sharding
    rationale; k_used shards with the batch, round counters replicate)."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    bsh = NamedSharding(mesh, P(dp, None))
    vsh = NamedSharding(mesh, P(dp))
    rep = NamedSharding(mesh, P())
    out_sh = ({"ops": bsh, "n_ops": vsh, "dist": vsh, "failed": vsh,
               "k_used": vsh, "read_consumed": vsh, "ref_consumed": vsh,
               "levels_run_total": rep, "rounds_run": rep, "n_rounds": rep},
              {"n_failed": rep, "n_rescued": rep, "total_edits": rep,
               "total_ops": rep, "rounds_run": rep})
    fn = partial(align_step_rescued, cfg=cfg, max_read_len=max_read_len,
                 rescue_rounds=rescue_rounds)
    return jax.jit(fn, in_shardings=(bsh, vsh, bsh, vsh),
                   out_shardings=out_sh)


def align_input_specs(batch: int, read_len: int, cfg: AlignerConfig,
                      rescue_rounds: int = 0):
    """ShapeDtypeStructs for the aligner dry-run cell.  With rescue_rounds,
    the ref padding covers the FINAL round's tail width (the contract of
    align_pairs_rescued)."""
    wt = self_tail_width(rescue_schedule(cfg, rescue_rounds)[-1])
    Lr = read_len + cfg.W + 1
    Lf = int(read_len * 1.3) + cfg.W + wt + 1
    sds = jax.ShapeDtypeStruct
    return (sds((batch, Lr), jnp.uint8), sds((batch,), jnp.int32),
            sds((batch, Lf), jnp.uint8), sds((batch,), jnp.int32))
