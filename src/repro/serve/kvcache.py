"""KV-cache utilities: pad prefill caches to serving length, greedy decode
loop used by tests and the serving example."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pad_cache(cache, to_len: int):
    """Pad the sequence axis (axis 2 of kv leaves) up to `to_len`."""
    def one(path, x):
        keys = [getattr(k, "key", None) for k in path]
        if "kv" in keys and x.ndim == 5:
            pad = to_len - x.shape[2]
            if pad > 0:
                return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return x
    return jax.tree_util.tree_map_with_path(one, cache)


def greedy_generate(model, params, tokens, n_new: int, max_len: int):
    """prefill + n_new greedy decode steps.  tokens: (B, S0)."""
    B, S0 = tokens.shape
    logits, cache = model.prefill(params, {"tokens": tokens})
    cache = pad_cache(cache, max_len)
    out = []
    tok = jnp.argmax(logits[:, -1, :model.cfg.vocab], axis=-1)[:, None]
    for i in range(n_new):
        out.append(tok)
        logits, cache = model.decode_step(
            params, {"tokens": tok.astype(jnp.int32),
                     "cache_pos": jnp.int32(S0 + i)}, cache)
        tok = jnp.argmax(logits[:, -1, :model.cfg.vocab], axis=-1)[:, None]
    return jnp.concatenate(out, axis=1)
