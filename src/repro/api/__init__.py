"""repro.api — the one front door for alignment serving.

    from repro.api import plan
    session = plan(W=64, O=24, k=12, backend="pallas_fused",
                   rescue_rounds=2, executor="thread")
    session.warmup([(10_000, 13_000)])       # AOT-compile before traffic
    fut = session.submit(read_codes, ref_codes)
    ...
    print(fut.result()["cigar"], session.session_stats())
    session.close()                          # or use it as a context manager

See docs/api.md for the session lifecycle, the background retire
executor's thread model, bucketing, the process-shared compile cache and
the deprecation table for the legacy GenASMAligner / AlignmentEngine
entry points.
"""
from .session import (AlignFuture, AlignSession, AlignSpec, CompileCache,
                      SessionPoisonedError, plan, shared_compile_cache)

__all__ = ["AlignFuture", "AlignSession", "AlignSpec", "CompileCache",
           "SessionPoisonedError", "plan", "shared_compile_cache"]
