"""repro.api — the one front door for alignment serving.

    from repro.api import plan
    session = plan(W=64, O=24, k=12, backend="pallas_fused",
                   rescue_rounds=2, executor="thread")
    session.warmup([(10_000, 13_000)])       # AOT-compile before traffic
    fut = session.submit(read_codes, ref_codes)
    ...
    print(fut.result()["cigar"], session.session_stats())
    session.close()                          # or use it as a context manager

For concurrent multi-tenant serving with SLOs (priority lanes, deadlines,
cancellation, load shedding), put a Gateway in front:

    gw = Gateway(session, GatewayPolicy(capacity=256))
    latency = gw.tenant("short-reads", priority=0, deadline_s=0.5)
    fut = latency.submit(read, ref)          # may raise ShedError
    fut.result(timeout=1.0)

See docs/api.md for the session lifecycle, the background retire
executor's thread model, bucketing, the process-shared compile cache,
the gateway's concurrency contract and the deprecation table for the
legacy GenASMAligner / AlignmentEngine entry points.
"""
from .gateway import (DeadlineExceeded, Gateway, GatewayClosedError,
                      GatewayFuture, GatewayPolicy, ShedError, Tenant)
from .session import (AlignFuture, AlignSession, AlignSpec, CompileCache,
                      RequestCancelled, SessionPoisonedError, plan,
                      shared_compile_cache)

__all__ = ["AlignFuture", "AlignSession", "AlignSpec", "CompileCache",
           "DeadlineExceeded", "Gateway", "GatewayClosedError",
           "GatewayFuture", "GatewayPolicy", "RequestCancelled",
           "SessionPoisonedError", "ShedError", "Tenant", "plan",
           "shared_compile_cache"]
