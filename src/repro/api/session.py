"""One front door for alignment: plan an `AlignSession`, then stream.

The paper's GPU speedups come from keeping the chip busy; a serving path
dies on compile stalls if pad widths derive from each batch's ragged
``max_read_len`` (every new length = a fresh jit trace).  The session
fixes that the way Scrooge / AnySeq-style production aligners do — a thin
facade over pre-planned, shape-stable executables:

* ``plan(cfg-like spec)`` resolves one validated :class:`AlignSpec`
  (merging the knobs formerly scattered over ``GenASMAligner`` /
  ``AlignmentEngine`` / ``make_align_step``) and returns a session.
* Lengths are quantised to power-of-two **buckets**
  (``core.windowing.pow2_bucket``); lane counts to the batch quantum
  (``distributed.sharding.bucket_lanes``).  One executable exists per
  (spec, bucket, mesh), AOT-lowered via ``jit(...).lower().compile()``
  into a **process-shared** :class:`CompileCache` keyed by (spec-hash,
  bucket, mesh-fingerprint): N sessions of the same spec lower each
  bucket exactly once across the process.  Each session keeps its own
  hit/miss/lowering counters (a :class:`_SessionCacheView`) — they are
  the compile-stability contract (tests/test_api.py, tests/test_executor.py).
* ``warmup()`` is a *method*, not a side effect: compile before traffic.
* ``submit()`` routes requests to buckets and returns an
  :class:`AlignFuture`; ``executor='thread'`` retires dispatches on a
  background thread (bounded queue = backpressure), so host CIGAR decode
  and compacted rescue overlap the dispatch thread's padding and the
  device's compute.  ``executor='sync'`` (default) retires inline under
  jax async dispatch — bit-identical either way: the executor reorders
  work in time, never in value.
* ``adaptive_lanes=True`` tracks per-bucket fill over a sliding window
  and steps the dispatch lane class down/up the quantised ladder
  (``distributed.sharding.lane_classes``), so sparse traffic stops
  padding to the worst case.
* Rescue (``rescue_mode='bucket'``, the default) gathers still-failed
  lanes and compacts them into the next-smaller length/lane bucket per
  k-doubling rung, so solved lanes' windows are never recomputed and the
  rung executables are cached like any other bucket.  Bit-identical to
  the legacy host loop and the on-device ladder (tests/test_rescue.py).

A session's mutating API (submit/flush/results/close) is safe to drive
from MANY client threads: an internal submit lock serialises queue
mutation and dispatch, so concurrent submitters interleave at request
granularity and per-request results are bit-identical to a serial run
(per-lane outputs are batch-composition independent — the hammer suite in
tests/test_gateway.py holds ≥8 client threads to that).  The background
retire thread is the session's own.  Exceptions on either thread poison
the session: the owning dispatch's futures carry the original exception,
every other outstanding future fails with :class:`SessionPoisonedError`,
and later submits refuse immediately — nothing blocks forever on a dead
dispatch.  ``repro.api.gateway`` builds the multi-tenant scheduling layer
(priorities, deadlines, admission control) on top of this surface.

``GenASMAligner`` (exact shapes) and ``AlignmentEngine`` (now a shim over
this session) remain as the reference implementations — docs/api.md has
the deprecation table.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from collections import deque

import numpy as np

from ..core import transfer
from ..core.aligner import AlignResult
from ..core.cigar import decode_batch, records_from_state
from ..core.config import AlignerConfig, resolve_config
from ..core.windowing import (SENTINEL_READ, SENTINEL_REF, bucket_avals,
                              pad_geometry, pow2_bucket, rescue_schedule)
from ..distributed.sharding import (bucket_lanes, lane_classes,
                                    mesh_fingerprint)
from ..obs import MetricsRegistry, default_registry, resolve_obs


class SessionPoisonedError(RuntimeError):
    """The session hit an unrecoverable dispatch/retire error: every
    outstanding future fails with this (the owning dispatch's futures
    carry the original exception) and further submits are refused."""


class RequestCancelled(RuntimeError):
    """The request was cancelled (AlignFuture.cancel / gateway deadline
    sweep) before its dispatch: its queue slot was freed and result()
    raises this instead of blocking.  Deliberately NOT the stdlib
    CancelledError (BaseException since 3.8) so a bare ``except
    Exception`` in serving loops still catches it."""


# --------------------------------------------------------------------------
# spec
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AlignSpec:
    """Everything a session needs, resolved and validated ONCE at plan time
    (the former GenASMAligner/AlignmentEngine/make_align_step knob trio).

    cfg           — the aligner geometry/backend (see core.config).
    rescue_rounds — k-doubling ladder depth past the base k.
    rescue_mode   — 'bucket' (compact failed lanes into smaller bucket
                    executables per rung; default) or 'device' (the
                    on-device masked ladder: 1 upload + 1 download total).
    batch_lanes   — lanes per full dispatch (quantised up to the pair
                    quantum at plan time); the adaptive ceiling.
    bucket_floor  — smallest power-of-two length bucket.
    max_inflight  — dispatches in flight before backpressure: the sync
                    executor retires the oldest inline (2 = double
                    buffering); the threaded executor bounds its retire
                    queue at this depth.  With adaptive_inflight this is
                    the *starting* depth, not a constant.
    executor      — 'sync' (retire inline on the dispatch thread) or
                    'thread' (background retire thread overlaps host
                    decode with dispatch — see docs/api.md).
    adaptive_lanes / occupancy_window — occupancy-driven lane classes:
                    track per-bucket fill over the last `occupancy_window`
                    dispatches and step the lane class down/up the
                    quantised ladder (never above batch_lanes).
    adaptive_inflight / inflight_ceiling — occupancy-driven in-flight
                    window: the same sliding fill signal, session-wide,
                    widens max_inflight by one (up to inflight_ceiling)
                    when every windowed dispatch saturated its lane class,
                    and narrows it by one (down to 1) when every windowed
                    dispatch was partial/flush-driven.  Backpressure stays
                    bounded (the threaded retire queue is allocated at the
                    ceiling; the *current* bound is what the dispatch
                    thread enforces) and poison-on-exception semantics are
                    unchanged.
    mesh          — optional device mesh; every executable is lowered
                    against it (shard_map'd Pallas / GSPMD jnp paths).
    """
    cfg: AlignerConfig = AlignerConfig()
    rescue_rounds: int = 2
    rescue_mode: str = "bucket"
    batch_lanes: int = 64
    bucket_floor: int = 32
    max_inflight: int = 2
    executor: str = "sync"
    adaptive_lanes: bool = False
    occupancy_window: int = 8
    adaptive_inflight: bool = False
    inflight_ceiling: int = 8
    mesh: object = None

    def __post_init__(self):
        assert self.rescue_mode in ("bucket", "device"), self.rescue_mode
        assert self.executor in ("sync", "thread"), self.executor
        assert self.rescue_rounds >= 0
        assert self.batch_lanes >= 1
        assert self.bucket_floor >= 1
        assert self.max_inflight >= 1
        assert self.occupancy_window >= 1
        assert self.inflight_ceiling >= 1
        if self.adaptive_inflight:
            assert self.inflight_ceiling >= self.max_inflight, \
                (self.inflight_ceiling, self.max_inflight)

    def key(self):
        """Hashable identity of everything that shapes an executable —
        the spec-hash component of the shared CompileCache key.  Content-
        hashed (cfg.fingerprint), so independently-planned equal specs
        share executables process-wide.  Executor/batching/inflight knobs
        are deliberately absent: they schedule work, they don't shape it
        (mesh is a separate key component)."""
        return (self.cfg.fingerprint(), self.rescue_rounds, self.rescue_mode)

    def read_bucket(self, read_len: int) -> int:
        return pow2_bucket(read_len, self.bucket_floor)

    def ref_bucket(self, ref_len: int) -> int:
        return pow2_bucket(ref_len, self.bucket_floor)


def plan(cfg: AlignerConfig | None = None, *, backend: str | None = None,
         rescue_rounds: int = 2, rescue_mode: str = "bucket",
         batch_lanes: int = 64, bucket_floor: int = 32,
         max_inflight: int = 2, executor: str = "sync",
         adaptive_lanes: bool = False, occupancy_window: int = 8,
         adaptive_inflight: bool = False, inflight_ceiling: int = 8,
         mesh=None, cache: "CompileCache | str" = "shared",
         clock=None, obs=None, **cfg_overrides) -> "AlignSession":
    """Resolve a cfg-like spec into a planned :class:`AlignSession`.

    Accepts an AlignerConfig (or None for defaults) plus any AlignerConfig
    field as a keyword override (``backend=``, ``W=``, ``k=``, ...) and the
    session knobs above.  This is the one validation funnel — nothing
    downstream re-derives or re-checks knobs.

    ``cache`` selects the executable store: ``'shared'`` (default) joins
    the process-wide CompileCache so same-spec sessions lower each bucket
    once per process; ``'private'`` isolates this session; an explicit
    :class:`CompileCache` instance shares exactly with whoever else holds
    it (tests).

    ``clock`` injects the time source for the session's wall-clock stats
    (default ``time.monotonic``) — the gateway's deterministic-clock test
    layer threads a fake clock through here so zero ``time.sleep`` is
    needed to test scheduling behaviour.

    ``obs`` selects the observability domain (see repro.obs): ``None``
    (default) gives the session a private enabled bundle on the same
    clock; ``'off'`` disables all telemetry for zero hot-path overhead
    (``session.stats`` then reads zeros — the trade is explicit); an
    :class:`repro.obs.Obs` shares a caller-scoped bundle (benchmarks
    label one registry per backend).
    """
    cfg = resolve_config(cfg, backend=backend, **cfg_overrides)
    spec = AlignSpec(cfg=cfg, rescue_rounds=rescue_rounds,
                     rescue_mode=rescue_mode,
                     batch_lanes=bucket_lanes(batch_lanes, cfg, mesh),
                     bucket_floor=bucket_floor, max_inflight=max_inflight,
                     executor=executor, adaptive_lanes=adaptive_lanes,
                     occupancy_window=occupancy_window,
                     adaptive_inflight=adaptive_inflight,
                     inflight_ceiling=inflight_ceiling, mesh=mesh)
    return AlignSession(spec, cache=cache, clock=clock, obs=obs)


# --------------------------------------------------------------------------
# compile cache — process-shared store + per-session counter views
# --------------------------------------------------------------------------

class _Pending:
    """Placeholder for a key whose build is in progress on another thread;
    waiters block on the event instead of the store lock."""

    __slots__ = ("event",)

    def __init__(self):
        self.event = threading.Event()


class CompileCache:
    """Thread-safe AOT-executable store keyed by (spec-hash, bucket,
    mesh-fingerprint), with process-level counters.

    ``fetch(key, build)`` returns ``(executable, was_built)``; the build
    (``jax.jit(...).lower(*avals).compile()`` — one trace + one lowering)
    is serialized PER KEY, not store-wide: the store lock is only held to
    reserve the key, so tenant B's cold bucket never waits behind tenant
    A's multi-second lowering of an unrelated key (no head-of-line
    blocking), while two sessions racing on the SAME key still lower it
    exactly once.  The module-level instance behind
    :func:`shared_compile_cache` is what makes serving multi-tenant: N
    sessions of the same spec lower each bucket exactly once per process.
    Per-session accounting lives in :class:`_SessionCacheView`.

    Counters live on a metrics registry (``compile_cache_*_total``): the
    process-shared instance sits on the obs default registry beside the
    transfer family; privately-constructed caches (tests) get a private
    registry so they never pollute the process totals.  The ``hits`` /
    ``misses`` / ``lowerings`` attributes remain the public contract —
    now read-only views over those counters (``bucket_hits`` stays a
    plain dict: per-key cardinality belongs in the stats dump, not the
    metric namespace)."""

    def __init__(self, registry=None):
        self._lock = threading.RLock()
        self._exe: dict = {}
        self._reg = registry if registry is not None else MetricsRegistry()
        self._m_hits = self._reg.counter("compile_cache_hits_total")
        self._m_misses = self._reg.counter("compile_cache_misses_total")
        self._m_lowerings = self._reg.counter(
            "compile_cache_lowerings_total")
        self.bucket_hits: dict = {}     # key -> times served from cache

    @property
    def hits(self) -> int:
        return self._m_hits.value

    @property
    def misses(self) -> int:
        return self._m_misses.value

    @property
    def lowerings(self) -> int:
        return self._m_lowerings.value

    def fetch(self, key, build):
        while True:
            with self._lock:
                entry = self._exe.get(key)
                if entry is None:
                    pending = self._exe[key] = _Pending()
                    self._m_misses.inc()
                    self._m_lowerings.inc()
                    break                       # this thread builds
                if not isinstance(entry, _Pending):
                    self._m_hits.inc()
                    self.bucket_hits[key] = self.bucket_hits.get(key, 0) + 1
                    return entry, False
            # someone else is building this key: wait off-lock, then
            # re-read (on builder failure the key is gone and the loop
            # retries the build itself, raising its own error)
            entry.event.wait()
        try:
            exe = build()
        except BaseException:
            with self._lock:
                self._exe.pop(key, None)        # builds stay retryable
            pending.event.set()
            raise
        with self._lock:
            self._exe[key] = exe
        pending.event.set()
        return exe, True

    def get(self, key, build):
        return self.fetch(key, build)[0]

    def clear(self):
        with self._lock:
            self._exe.clear()

    def __len__(self):
        with self._lock:
            return sum(1 for v in self._exe.values()
                       if not isinstance(v, _Pending))

    def stats(self) -> dict:
        with self._lock:
            n = sum(1 for v in self._exe.values()
                    if not isinstance(v, _Pending))
            return {"hits": self.hits, "misses": self.misses,
                    "lowerings": self.lowerings, "executables": n,
                    "bucket_hits": {str(k): v
                                    for k, v in self.bucket_hits.items()}}


_PROCESS_CACHE = CompileCache(registry=default_registry())


def shared_compile_cache() -> CompileCache:
    """The process-wide executable store every ``plan(cache='shared')``
    session joins (multi-tenant serving: one lowering per bucket per
    process, however many sessions)."""
    return _PROCESS_CACHE


class _SessionCacheView:
    """One session's window onto a (possibly shared) CompileCache.

    Counters are per-session — ``lowerings`` counts builds performed on
    behalf of THIS session, ``hits`` fetches served from the store, and
    ``shared_hits`` the subset of hits whose executable some *other*
    session lowered (first-touch hits).  They reconcile with the store:
    summed over sessions, hits+misses equals the store's and lowerings
    equals the store's (tests/test_executor.py).  The counters live on
    the owning session's obs registry (``session_cache_*_total``); the
    attribute names stay the public contract as read-only views."""

    def __init__(self, store: CompileCache, registry=None):
        self.store = store
        self._lock = threading.Lock()
        self._seen: set = set()
        reg = registry if registry is not None else MetricsRegistry()
        self._m_hits = reg.counter("session_cache_hits_total")
        self._m_misses = reg.counter("session_cache_misses_total")
        self._m_lowerings = reg.counter("session_cache_lowerings_total")
        self._m_shared_hits = reg.counter("session_cache_shared_hits_total")
        self.bucket_hits: dict = {}

    @property
    def hits(self) -> int:
        return self._m_hits.value

    @property
    def misses(self) -> int:
        return self._m_misses.value

    @property
    def lowerings(self) -> int:
        return self._m_lowerings.value

    @property
    def shared_hits(self) -> int:
        return self._m_shared_hits.value

    def get(self, key, build):
        exe, built = self.store.fetch(key, build)
        with self._lock:
            first = key not in self._seen
            self._seen.add(key)
            if built:
                self._m_misses.inc()
                self._m_lowerings.inc()
            else:
                self._m_hits.inc()
                self.bucket_hits[key] = self.bucket_hits.get(key, 0) + 1
                if first:
                    self._m_shared_hits.inc()
        return exe

    def __len__(self):
        return len(self._seen)

    def stats(self) -> dict:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "lowerings": self.lowerings, "executables": len(self._seen),
                    "shared_hits": self.shared_hits,
                    "bucket_hits": {str(k): v
                                    for k, v in self.bucket_hits.items()},
                    "process": self.store.stats()}


# --------------------------------------------------------------------------
# futures
# --------------------------------------------------------------------------

class AlignFuture:
    """Handle for one submitted pair; fulfilled (or failed) when its
    dispatch retires — on the dispatch thread (executor='sync') or the
    session's background retire thread (executor='thread')."""

    __slots__ = ("rid", "_session", "_value", "_error", "_event",
                 "_cancelled", "_callbacks")

    def __init__(self, session: "AlignSession", rid: int):
        self._session = session
        self.rid = rid
        self._value = None
        self._error = None
        self._event = threading.Event()
        self._cancelled = False
        self._callbacks: list = []

    def done(self) -> bool:
        return self._event.is_set()

    def cancelled(self) -> bool:
        return self._cancelled

    def result(self, timeout: float | None = None) -> dict:
        """Block until this pair's result is available and return it:
        {ok, dist, cigar, k_used, ops, read_consumed, ref_consumed}.
        Raises the dispatch's exception (or SessionPoisonedError /
        RequestCancelled) if it will never resolve.  ``timeout`` bounds
        the WAIT in seconds — on expiry a ``TimeoutError`` is raised and
        the future stays collectable (a later result() can still return
        the value; timeout-then-fulfill is tested).  The sync executor
        retires inline on this thread, so its forcing work is not
        interruptible mid-retire; the bound applies to waiting on the
        background executor.  Collecting here counts as collecting: the
        session forgets the rid (it will not appear in results()),
        keeping long-lived streaming memory bounded by what is in
        flight."""
        if not self._event.is_set():
            self._session._force(self, timeout=timeout)
        if not self._event.is_set():
            raise TimeoutError(
                f"align result rid={self.rid} not ready within {timeout}s")
        self._session._forget(self.rid)
        if self._error is not None:
            raise self._error
        return self._value

    def cancel(self) -> bool:
        """Cancel this request if it is still QUEUED (not yet dispatched):
        its bucket-queue slot is freed atomically under the submit lock —
        the slot cannot also dispatch, so a lane is never freed twice —
        and result() raises RequestCancelled.  Returns True when cancelled
        (idempotently, including repeat calls), False when the pair
        already dispatched or completed: a committed lane cannot be
        recalled, its result simply arrives."""
        return self._session._cancel(self)

    def add_done_callback(self, fn) -> None:
        """Run ``fn(self)`` when the future resolves (fulfil, fail, or
        cancel) — immediately if already done.  Callbacks fire on
        whichever thread resolves the future (retire thread under
        executor='thread'); exceptions from callbacks are swallowed and
        recorded on the session's ``callback_errors`` counter
        (``session_callback_errors_total``).  This is the gateway's
        completion hook (deadline-hit accounting needs the completion
        TIME, not the collection time)."""
        self._callbacks.append(fn)
        if self._event.is_set():
            self._run_callbacks()

    def _run_callbacks(self) -> None:
        # list.pop is atomic under the GIL: when a resolver races an
        # add_done_callback, each callback still runs exactly once
        while True:
            try:
                fn = self._callbacks.pop()
            except IndexError:
                return
            try:
                fn(self)
            except BaseException as e:  # noqa: BLE001 — callbacks NEVER
                # poison: these run on whichever thread resolves the
                # future (the retire thread under executor='thread'), so
                # even a BaseException (KeyboardInterrupt in a client
                # hook) must be swallowed-and-recorded, not allowed to
                # unwind into _retire_loop and poison the session
                self._session._callback_error(e)

    # internal — called by the session (either thread)
    def _fulfill(self, value) -> None:
        self._value = value
        self._event.set()
        self._run_callbacks()

    def _fail(self, err: BaseException) -> None:
        if not self._event.is_set():
            self._error = err
            self._event.set()
        self._run_callbacks()


@dataclasses.dataclass
class _Dispatch:
    """One in-flight bucket batch: device outputs + what retiring needs."""
    futures: list          # n_real AlignFutures, lane order
    reads: list            # n_real host code arrays (for bucket rescue)
    refs: list
    out: dict              # device arrays (async) from the executable


_SHUTDOWN = object()       # retire-queue sentinel for close()


# --------------------------------------------------------------------------
# session
# --------------------------------------------------------------------------

class AlignSession:
    """The planned front door: shape-stable, AOT-compiled, streaming.

    Lifecycle: ``plan(...)`` -> optional ``warmup(...)`` -> ``submit(...)``
    per request (or ``align(reads, refs)`` for a one-shot batch) ->
    ``flush()`` / ``results()`` / ``future.result()`` -> ``close()`` (a
    context manager does it for you; only required for executor='thread').
    """

    #: legacy stats key -> registry metric name: ``session.stats`` is a
    #: read-only view building this dict from the obs counters (the
    #: docs/observability.md catalogue mirrors this table)
    STAT_METRICS = {
        "dispatches": "session_dispatches_total",
        "lanes": "session_lanes_total",
        "pad_lanes": "session_pad_lanes_total",
        "requests": "session_requests_total",
        "cancelled": "session_cancelled_total",
        "rescue_dispatches": "session_rescue_dispatches_total",
        "rescue_lanes": "session_rescue_lanes_total",
        "lane_class_steps": "session_lane_class_steps_total",
        "inflight_steps": "session_inflight_steps_total",
        "callback_errors": "session_callback_errors_total",
        "wall_s": "session_wall_seconds_total",
        "retire_wall_s": "session_retire_wall_seconds_total",
    }

    def __init__(self, spec: AlignSpec, cache: CompileCache | str = "shared",
                 clock=None, obs=None):
        self.spec = spec
        self.cfg = spec.cfg          # resolved; exposed for shims/stats
        self.mesh = spec.mesh
        self._clock = clock if clock is not None else time.monotonic
        # one observability domain per session (registry + tracer on the
        # session clock); 'off' -> the null bundle, zero hot-path cost
        self.obs = resolve_obs(obs, clock=self._clock)
        # metric objects are fetched ONCE here; the hot path pays a
        # locked += per event (or a no-op call when obs='off')
        self._m = {k: self.obs.counter(name)
                   for k, name in self.STAT_METRICS.items()}
        if cache == "shared":
            store = _PROCESS_CACHE
        elif cache == "private":
            store = CompileCache()
        else:
            assert isinstance(cache, CompileCache), cache
            store = cache
        self.cache = _SessionCacheView(store, registry=self.obs.registry)
        self._mesh_fp = mesh_fingerprint(spec.mesh)
        self._queues: dict[tuple, list] = {}   # bucket -> [(future, r, f)]
        self._inflight: deque[_Dispatch] = deque()   # sync executor only
        self._open: dict[int, AlignFuture] = {}   # not yet handed out
        self._next_rid = 0
        self._lock = threading.Lock()          # _open + poisoning
        # serialises queue mutation + dispatch across CLIENT threads (the
        # retire thread never takes it — no deadlock with close/_drain);
        # re-entrant because flush()/close() nest dispatches under it
        self._submit_lock = threading.RLock()
        self._poisoned: BaseException | None = None
        self._closed = False
        # threaded retire executor (started lazily at first dispatch)
        self._retire_q: queue.Queue | None = None
        self._retire_thread: threading.Thread | None = None
        # occupancy-adaptive lane classes
        self._ladder = lane_classes(spec.batch_lanes, spec.cfg, spec.mesh)
        self._lane_class: dict[tuple, int] = {}    # bucket -> current class
        self._fills: dict[tuple, deque] = {}       # bucket -> recent fills
        # occupancy-adaptive in-flight window (session-wide, not per bucket
        # — in-flight depth is a property of the pipeline, not of a shape)
        self._max_inflight = spec.max_inflight
        self._inflight_win: deque = deque(maxlen=spec.occupancy_window)

    @property
    def stats(self) -> dict:
        """Serving counters as the legacy dict — a point-in-time view
        over the obs registry (asserted equal to registry reads in
        tests/test_obs.py).  Zeros when ``obs='off'``."""
        return {k: m.value for k, m in self._m.items()}

    def _callback_error(self, exc: BaseException) -> None:
        """Swallow-and-record for done-callbacks (see
        AlignFuture._run_callbacks): must never raise."""
        self._m["callback_errors"].inc()

    # ---- context management / shutdown --------------------------------

    def __enter__(self) -> "AlignSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(drain=exc_type is None and self._poisoned is None)

    def close(self, drain: bool = True) -> None:
        """Shut the session down cleanly.  drain=True (default) dispatches
        partial queues and retires everything in flight first — already-
        obtained futures stay collectable afterwards.  drain=False
        abandons queued/in-flight work: its futures fail fast with
        SessionPoisonedError (both executors).  Always stops the
        background retire thread (sentinel + join); idempotent.  A closed
        session refuses further submits.

        Safe against concurrent client threads: the closed flag flips
        under the submit lock BEFORE draining, so a racing submit either
        lands (and is drained here) or refuses — it can never slip into a
        queue nobody will dispatch (the close()-while-outstanding race,
        tests/test_gateway.py)."""
        with self._submit_lock:
            was_closed, self._closed = self._closed, True
            if drain and self._poisoned is None and not was_closed:
                self.flush()
        if drain and self._poisoned is None and not was_closed:
            self._drain()
        if not drain and self._poisoned is None:
            # fail-fast every outstanding future (and whatever the retire
            # queue still holds) so nothing waits on abandoned work
            self._poison(SessionPoisonedError(
                "session closed without drain"))
        self._closed = True
        t = self._retire_thread
        if t is not None and t.is_alive():
            if self._poisoned is not None:
                self._retire_q.join()     # fail-fast drain so join ends
            self._retire_q.put(_SHUTDOWN)
            t.join()
        self._retire_thread = None

    # ---- planning / warm-up -------------------------------------------

    def bucket_for(self, read_len: int, ref_len: int) -> tuple[int, int]:
        """The (read_bucket, ref_bucket) length class a pair routes to."""
        return (self.spec.read_bucket(read_len),
                self.spec.ref_bucket(ref_len))

    def warmup(self, length_classes, lanes: int | None = None) -> dict:
        """AOT-compile executables ahead of traffic — an explicit method,
        not a side effect of the first submit.

        length_classes: iterable of (read_len, ref_len) pairs; each is
        bucketed and compiled at the `lanes` lane class (default
        spec.batch_lanes) — for 'bucket' rescue, every k-doubling rung is
        compiled at that same bucket/lane class too.  Note the residual
        stall this cannot remove: a compacted rescue round re-derives its
        length bucket and lane class from however many lanes actually
        failed, which is unknowable ahead of traffic — if that smaller
        class was never warmed (call warmup again with smaller `lanes` /
        lengths to cover expected failure rates), its first occurrence
        lowers mid-traffic.  The same applies to adaptive_lanes: a class
        the occupancy controller steps down to is lowered on first use
        unless warmed here.  rescue_mode='device' has no such stall (the
        whole ladder is one executable).  Returns the cache stats
        snapshot."""
        lanes = self.spec.batch_lanes if lanes is None else lanes
        for read_len, ref_len in length_classes:
            rb, fb = self.bucket_for(read_len, ref_len)
            nb = bucket_lanes(lanes, self.cfg, self.mesh)
            if self.spec.rescue_mode == "device":
                self._executable(self.cfg, nb, rb, fb,
                                 rescue_rounds=self.spec.rescue_rounds)
            else:
                self._executable(self.cfg, nb, rb, fb, rescue_rounds=None)
                for cfg_r in rescue_schedule(self.cfg,
                                             self.spec.rescue_rounds)[1:]:
                    self._executable(cfg_r, nb, rb, fb, rescue_rounds=None)
        return self.cache.stats()

    # ---- executables ---------------------------------------------------

    def _executable(self, cfg, lanes, read_bucket, ref_bucket,
                    rescue_rounds):
        """The (spec-hash, bucket, mesh-fingerprint)-keyed AOT executable
        for one batch shape.  rescue_rounds=None -> plain align step (one
        ladder rung); an int -> the whole on-device ladder.  Content-
        hashed keys, so equal specs share across sessions; safe to call
        from the retire thread (rescue rungs lower on demand)."""
        key = (self.spec.key(), cfg.fingerprint(), lanes, read_bucket,
               ref_bucket, rescue_rounds, self._mesh_fp)

        def build():
            from ..serve.align_step import make_align_step
            step = make_align_step(cfg, read_bucket, self.mesh,
                                   rescue_rounds=rescue_rounds)
            avals = bucket_avals(cfg, lanes, read_bucket, ref_bucket,
                                 rescue_rounds or 0)
            return step.lower(*avals).compile()

        return self.cache.get(key, build)

    # ---- streaming -----------------------------------------------------

    def _check_poisoned(self):
        if self._poisoned is not None:
            raise SessionPoisonedError(
                "session is poisoned; no further dispatches") \
                from self._poisoned

    def _check_usable(self):
        self._check_poisoned()
        if self._closed:
            raise RuntimeError("session is closed")

    def submit(self, read: np.ndarray, ref: np.ndarray) -> AlignFuture:
        """Queue one encoded (read, ref) pair; dispatches fire whenever a
        bucket queue reaches its current lane class (earlier batches keep
        computing — the executor overlaps them with padding and, when
        threaded, with host decode).  Callable from many client threads:
        the submit lock serialises queue mutation + dispatch."""
        with self._submit_lock:
            self._check_usable()
            fut = AlignFuture(self, self._next_rid)
            self._next_rid += 1
            with self._lock:
                self._open[fut.rid] = fut
            self._m["requests"].inc()
            bucket = self.bucket_for(len(read), len(ref))
            q = self._queues.setdefault(bucket, [])
            q.append((fut, read, ref))
            if len(q) >= self._current_lanes(bucket):
                self._dispatch(bucket, self._queues.pop(bucket))
            return fut

    def flush(self):
        """Dispatch every partially-filled bucket queue (thread-safe)."""
        with self._submit_lock:
            for bucket in list(self._queues):
                self._dispatch(bucket, self._queues.pop(bucket))

    def results(self) -> dict[int, dict]:
        """Flush, retire every in-flight dispatch, and return
        {rid: result dict} for every request not yet collected.  Collected
        rids are forgotten, so a long-lived session's memory stays bounded
        by what is in flight.  Raises SessionPoisonedError if the session
        was poisoned (individual futures carry the underlying errors)."""
        self.flush()
        self._drain()
        if self._poisoned is not None:
            raise SessionPoisonedError(
                "session poisoned while draining") from self._poisoned
        with self._lock:
            done = {rid: fut._value for rid, fut in self._open.items()
                    if fut.done() and fut._error is None}
            for rid in done:
                del self._open[rid]
        return done

    def align(self, reads, refs) -> AlignResult:
        """One-shot batch: submit all pairs, drain, and assemble an
        AlignResult in input order — drop-in for GenASMAligner.align and
        bit-identical to it (tests/test_api.py)."""
        assert len(reads) == len(refs)
        futs = [self.submit(r, f) for r, f in zip(reads, refs)]
        self.flush()
        recs = [f.result() for f in futs]   # result() collects each rid
        return AlignResult.from_records(recs)

    # ---- adaptive lane classes -----------------------------------------

    def _current_lanes(self, bucket) -> int:
        return self._lane_class.get(bucket, self.spec.batch_lanes)

    def _adapt(self, bucket, n_real: int) -> None:
        """Occupancy-driven lane-class negotiation, between batches: track
        this bucket's fill over a sliding window; once the window is full,
        step DOWN one ladder rung when every recent dispatch would fit a
        smaller class (sparse traffic stops padding to the worst case),
        and back UP one rung when every recent dispatch saturated the
        current class.  Steps walk distributed.sharding.lane_classes —
        always quantised, never above spec.batch_lanes.  Purely a shape
        choice: results are lane-class invariant (pads are repeated real
        pairs), so adaptation cannot change values, only padding waste."""
        if not self.spec.adaptive_lanes or len(self._ladder) < 2:
            return
        win = self._fills.setdefault(
            bucket, deque(maxlen=self.spec.occupancy_window))
        win.append(n_real)
        if len(win) < win.maxlen:
            return
        cur = self._current_lanes(bucket)
        i = self._ladder.index(cur) if cur in self._ladder \
            else len(self._ladder) - 1
        if min(win) >= cur and i + 1 < len(self._ladder):
            self._lane_class[bucket] = self._ladder[i + 1]
        elif i > 0 and bucket_lanes(max(max(win), 1), self.cfg,
                                    self.mesh) < cur:
            self._lane_class[bucket] = self._ladder[i - 1]
        else:
            return
        win.clear()                      # fresh window for the new class
        self._m["lane_class_steps"].inc()

    # ---- adaptive in-flight window -------------------------------------

    def _adapt_inflight(self, saturated: bool) -> None:
        """Occupancy-driven in-flight depth, from the same sliding signal
        as _adapt but session-wide: `saturated` records whether this
        dispatch filled its (pre-step) lane class.  Once the window is
        full, widen the in-flight bound by one (denser pipelining pays
        when traffic keeps every batch full) up to spec.inflight_ceiling;
        narrow by one toward 1 when every windowed dispatch was partial
        (flush-driven traffic gains nothing from a deep pipeline and the
        shallower bound retires results sooner).  Purely a scheduling
        choice: like lane classes, it cannot change values — the sync
        backpressure loop and the threaded queue guard just read the
        current bound.  _max_inflight is only written under the submit
        lock (every dispatch holds it), so readers need no extra lock
        (the retire thread never reads it)."""
        if not self.spec.adaptive_inflight:
            return
        win = self._inflight_win
        win.append(bool(saturated))
        if len(win) < win.maxlen:
            return
        cur = self._max_inflight
        if all(win) and cur < self.spec.inflight_ceiling:
            self._max_inflight = cur + 1
        elif not any(win) and cur > 1:
            self._max_inflight = cur - 1
        else:
            return
        win.clear()                      # fresh window for the new bound
        self._m["inflight_steps"].inc()

    # ---- dispatch ------------------------------------------------------

    def _dispatch(self, bucket, items):
        """Pad one bucket batch on host, upload once, launch the executable
        (async — control returns while the device computes), and hand the
        dispatch to the executor: the sync path retires the oldest inline
        once max_inflight is exceeded (double buffering); the threaded
        path enqueues it for the background retire thread (bounded queue —
        the put blocks when retire falls max_inflight behind, which is the
        backpressure).  A raising dispatch poisons the session: its own
        futures carry the exception, all other outstanding futures fail
        with SessionPoisonedError, and the exception re-raises here.
        Callers hold the submit lock (submit/flush/_force/close)."""
        self._check_poisoned()
        try:
            self._dispatch_inner(bucket, items)
        except BaseException as e:
            self._poison(e, owning=[it[0] for it in items])
            raise

    def _dispatch_inner(self, bucket, items):
        threaded = self.spec.executor == "thread"
        cls = self._current_lanes(bucket)   # pre-step class, for saturation
        if not threaded:
            while len(self._inflight) >= self._max_inflight:
                self._retire_guarded(self._inflight.popleft())
        t0 = self._clock()
        futs = [it[0] for it in items]
        reads = [it[1] for it in items]
        refs = [it[2] for it in items]
        rb, fb = bucket
        lanes = bucket_lanes(len(items), self.cfg, self.mesh)
        with self.obs.span("session.dispatch", bucket=f"{rb}x{fb}",
                           lanes=lanes, n_real=len(items)):
            device_mode = self.spec.rescue_mode == "device"
            rounds = self.spec.rescue_rounds if device_mode else None
            exe = self._executable(self.cfg, lanes, rb, fb,
                                   rescue_rounds=rounds)
            Lr, Lf = pad_geometry(self.cfg, rb, fb, rounds or 0)
            dev = transfer.to_device(
                self._pad_batch(reads, refs, lanes, Lr, Lf))
            # the launch is async under jax dispatch: this span covers
            # upload + enqueue, not device occupancy
            with self.obs.span("device.execute", lanes=lanes):
                out, _ = exe(*dev)
        d = _Dispatch(futs, reads, refs, out)
        if threaded:
            self._enqueue_retire(d)
        else:
            self._inflight.append(d)
        self._m["dispatches"].inc()
        self._m["lanes"].inc(lanes)
        self._m["pad_lanes"].inc(lanes - len(items))
        self._m["wall_s"].inc(self._clock() - t0)
        self._adapt(bucket, len(items))
        self._adapt_inflight(len(items) >= cls)

    def _pad_batch(self, reads, refs, lanes, Lr, Lf):
        """Pad to `lanes` rows of (Lr, Lf) sentinels; ragged lane tails are
        REPEATS of the last real pair (exactly as alignable as its twin,
        so pads can't keep rescue gates open or skew stats — the engine
        trick, now session-wide)."""
        n = len(reads)
        reads = list(reads) + [reads[-1]] * (lanes - n)
        refs = list(refs) + [refs[-1]] * (lanes - n)
        rpad = np.full((lanes, Lr), SENTINEL_READ, np.uint8)
        fpad = np.full((lanes, Lf), SENTINEL_REF, np.uint8)
        rlen = np.zeros(lanes, np.int32)
        flen = np.zeros(lanes, np.int32)
        for i, (r, f) in enumerate(zip(reads, refs)):
            rpad[i, :len(r)] = r
            rlen[i] = len(r)
            fpad[i, :len(f)] = f
            flen[i] = len(f)
        return rpad, rlen, fpad, flen

    # ---- the background retire executor --------------------------------

    def _ensure_retire_thread(self):
        if self._retire_thread is None or not self._retire_thread.is_alive():
            # allocate at the ceiling so a widened bound never needs a new
            # queue; the *current* bound is enforced in _enqueue_retire
            depth = (self.spec.inflight_ceiling
                     if self.spec.adaptive_inflight
                     else self.spec.max_inflight)
            self._retire_q = queue.Queue(maxsize=depth)
            self._retire_thread = threading.Thread(
                target=self._retire_loop, name="align-retire", daemon=True)
            self._retire_thread.start()

    def _enqueue_retire(self, d: _Dispatch):
        """Bounded-queue backpressure at the *current* in-flight bound:
        block while retire is >= _max_inflight behind.  The qsize check is
        race-free here because this (dispatch) thread is the only producer
        — the retire thread only ever shrinks the queue.  The 0.1s tick
        doubles as the liveness check: a dead retire thread with a backed-
        up queue poisons the submit instead of hanging it."""
        self._ensure_retire_thread()
        while True:
            if self._retire_q.qsize() < self._max_inflight:
                try:
                    self._retire_q.put(d, timeout=0.1)
                    return
                except queue.Full:
                    pass
            else:
                time.sleep(0.005)
            if not self._retire_thread.is_alive():
                raise SessionPoisonedError(
                    "retire thread died with its queue full")

    def _retire_loop(self):
        """The background executor: drain ready device results and run the
        host-side decode (core.cigar.decode_batch — pure numpy) plus any
        compacted rescue rounds concurrently with the dispatch thread.
        Exceptions never die silently: the failing dispatch's futures get
        the exception, the session is poisoned, and the loop keeps
        consuming (fail-fast) so the bounded queue can always drain."""
        while True:
            d = self._retire_q.get()
            try:
                if d is _SHUTDOWN:
                    return
                if self._poisoned is not None:
                    for fut in d.futures:
                        fut._fail(SessionPoisonedError(
                            "dispatch abandoned: session poisoned"))
                else:
                    self._retire(d)
            except BaseException as e:      # noqa: BLE001 — must not be lost
                self._poison(e, owning=d.futures)
            finally:
                self._retire_q.task_done()

    def _drain(self):
        """Block until every launched dispatch has retired (both
        executors); errors surface on the futures / via poisoning."""
        if self._retire_thread is not None:
            self._retire_q.join()
        with self._submit_lock:
            while self._inflight:
                self._retire_guarded(self._inflight.popleft())

    def _retire_guarded(self, d: _Dispatch):
        """Sync-path retire: a raising retire poisons the session (its
        futures carry the exception) and re-raises to the caller."""
        try:
            self._retire(d)
        except BaseException as e:
            self._poison(e, owning=d.futures)
            raise

    # ---- retire / rescue (either thread) -------------------------------

    def _retire(self, d: _Dispatch):
        """Force one dispatch: download once, decode via the off-thread
        entrypoint (core.cigar), run compacted bucket-rescue rounds if
        needed, fulfill futures."""
        t0 = self._clock()
        n = len(d.futures)
        with self.obs.span("retire.decode", n=n):
            keys = ("ops", "n_ops", "dist", "failed", "read_consumed",
                    "ref_consumed") + (("k_used",)
                                       if "k_used" in d.out else ())
            host = transfer.to_host({k: d.out[k] for k in keys})
            failed, dist, k_used, rcon, fcon, all_ops = \
                decode_batch(host, n, self.cfg.k)
            if self.spec.rescue_mode == "bucket" and failed.any():
                self._rescue_compacted(d, failed, dist, k_used, rcon, fcon,
                                       all_ops)
            recs = records_from_state(failed, dist, k_used, rcon, fcon,
                                      all_ops)
            for fut, rec in zip(d.futures, recs):
                fut._fulfill(rec)
        self._m["retire_wall_s"].inc(self._clock() - t0)

    def _rescue_compacted(self, d, failed, dist, k_used, rcon, fcon,
                          all_ops):
        """The ROADMAP rescue-efficiency item: instead of recomputing every
        lane's windows each k-doubling round (the on-device ladder) or
        re-tracing ragged subsets (the host loop), gather the still-failed
        lanes and compact them into the next-smaller length/lane bucket —
        solved lanes never recompute, shapes stay bucket-stable, and the
        rung executables live in the same CompileCache.  Bit-identical to
        rescue_mode='host' per lane (tests/test_rescue.py).  Runs on
        whichever thread retires the dispatch."""
        todo = [i for i in range(len(d.futures)) if failed[i]]
        for cfg_r in rescue_schedule(self.cfg, self.spec.rescue_rounds)[1:]:
            if not todo:
                return
            reads = [d.reads[i] for i in todo]
            refs = [d.refs[i] for i in todo]
            rb = self.spec.read_bucket(max(len(r) for r in reads))
            fb = self.spec.ref_bucket(max(len(f) for f in refs))
            lanes = bucket_lanes(len(todo), cfg_r, self.mesh)
            with self.obs.span("rescue.rung", k=cfg_r.k, lanes=lanes,
                               n_todo=len(todo)):
                exe = self._executable(cfg_r, lanes, rb, fb,
                                       rescue_rounds=None)
                Lr, Lf = pad_geometry(cfg_r, rb, fb, 0)
                dev = transfer.to_device(
                    self._pad_batch(reads, refs, lanes, Lr, Lf))
                out, _ = exe(*dev)
                host = transfer.to_host(
                    {k: out[k] for k in ("ops", "n_ops", "dist", "failed",
                                         "read_consumed", "ref_consumed")})
            self._m["rescue_dispatches"].inc()
            self._m["rescue_lanes"].inc(lanes)
            ok = ~np.asarray(host["failed"])
            for loc, glob in enumerate(todo):
                if ok[loc]:
                    nops = int(host["n_ops"][loc])
                    all_ops[glob] = np.asarray(
                        host["ops"])[loc, :nops].copy()
                    dist[glob] = int(host["dist"][loc])
                    k_used[glob] = cfg_r.k
                    rcon[glob] = int(host["read_consumed"][loc])
                    fcon[glob] = int(host["ref_consumed"][loc])
                    failed[glob] = False
            todo = [g for g in todo if failed[g]]

    # ---- poisoning / forcing -------------------------------------------

    def _poison(self, exc: BaseException, owning=()):
        """Unrecoverable error: remember the first cause, fail the owning
        dispatch's futures with the original exception and every other
        outstanding future with SessionPoisonedError — nothing is left to
        block forever, and further submits refuse."""
        with self._lock:
            if self._poisoned is None:
                self._poisoned = exc
        for fut in owning:
            fut._fail(exc)
        perr = SessionPoisonedError(
            f"session poisoned by {type(exc).__name__}: {exc}")
        perr.__cause__ = exc
        with self._lock:
            open_futs = list(self._open.values())
        for fut in open_futs:
            fut._fail(perr)
        self._queues.clear()
        self._inflight.clear()

    def _forget(self, rid: int) -> None:
        with self._lock:
            self._open.pop(rid, None)

    def _cancel(self, fut: AlignFuture) -> bool:
        """Cancel `fut` if still queued: remove its (future, read, ref)
        slot under the submit lock — atomic vs dispatch, so the slot
        either cancels or dispatches, never both (a lane can't be freed
        twice) — fail the future with RequestCancelled, and forget the
        rid.  True when cancelled (idempotent on repeats), False once
        dispatched or done."""
        with self._submit_lock:
            if fut.done():
                return fut._cancelled
            for bucket, q in list(self._queues.items()):
                for i, it in enumerate(q):
                    if it[0] is fut:
                        del q[i]
                        if not q:
                            del self._queues[bucket]
                        fut._cancelled = True
                        fut._fail(RequestCancelled(
                            f"request rid={fut.rid} cancelled before "
                            f"dispatch"))
                        self._forget(fut.rid)
                        self._m["cancelled"].inc()
                        return True
            return False                     # dispatched: lane committed

    def load(self) -> dict:
        """The occupancy/in-flight signal a gateway's admission control
        reads: dispatches in flight (retire-queue depth under the
        threaded executor, the inline deque under sync), the current
        in-flight bound (adaptive or static) and queued-but-undispatched
        pairs.  Cheap — safe to call per admission decision."""
        if self._retire_q is not None:
            inflight = self._retire_q.qsize()
        else:
            inflight = len(self._inflight)
        with self._submit_lock:
            queued = sum(len(q) for q in self._queues.values())
        return {"inflight": inflight, "max_inflight": self._max_inflight,
                "queued_pairs": queued}

    def _force(self, fut: AlignFuture, timeout: float | None = None):
        """Resolve one future: dispatch its queue if still held, then
        retire until it is done — inline (sync) or by waiting on the
        background executor (threaded), with a liveness check so a dead
        retire thread can never hang the caller.  `timeout` bounds the
        threaded wait (monotonic deadline); on expiry the future is left
        unresolved for the caller to raise TimeoutError — a later force
        can still collect it (timeout-then-fulfill)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._submit_lock:
            for bucket, q in list(self._queues.items()):
                if any(it[0] is fut for it in q):
                    self._dispatch(bucket, self._queues.pop(bucket))
                    break
            while self._inflight and not fut.done():
                self._retire_guarded(self._inflight.popleft())
        if self._retire_thread is not None:
            while not fut._event.wait(0.05):
                if not self._retire_thread.is_alive():
                    fut._fail(SessionPoisonedError(
                        "retire thread died before this future resolved"))
                    return
                if deadline is not None and time.monotonic() >= deadline:
                    return

    def session_stats(self) -> dict:
        """Serving + compile-cache counters in one dict (benchmarks/CI).
        With adaptive_lanes, `occupancy` reports each bucket's negotiated
        lane class and recent fills."""
        out = self.stats                 # registry-backed property
        out["compile_cache"] = self.cache.stats()
        if self.spec.adaptive_lanes:
            out["occupancy"] = {
                str(b): {"lane_class": self._current_lanes(b),
                         "recent_fills": list(self._fills.get(b, ()))}
                for b in set(self._lane_class) | set(self._fills)}
        if self.spec.adaptive_inflight:
            out["inflight"] = {"max_inflight": self._max_inflight,
                               "ceiling": self.spec.inflight_ceiling,
                               "recent_saturated": list(self._inflight_win)}
        return out
