"""One front door for alignment: plan an `AlignSession`, then stream.

The paper's GPU speedups come from keeping the chip busy; a serving path
dies on compile stalls if pad widths derive from each batch's ragged
``max_read_len`` (every new length = a fresh jit trace).  The session
fixes that the way Scrooge / AnySeq-style production aligners do — a thin
facade over pre-planned, shape-stable executables:

* ``plan(cfg-like spec)`` resolves one validated :class:`AlignSpec`
  (merging the knobs formerly scattered over ``GenASMAligner`` /
  ``AlignmentEngine`` / ``make_align_step``) and returns a session.
* Lengths are quantised to power-of-two **buckets**
  (``core.windowing.pow2_bucket``); lane counts to the batch quantum
  (``distributed.sharding.bucket_lanes``).  One executable exists per
  (spec, bucket, mesh), AOT-lowered via ``jit(...).lower().compile()``
  into an explicit :class:`CompileCache` whose hit/miss/lowering counters
  are the compile-stability contract (tests/test_api.py).
* ``warmup()`` is a *method*, not a side effect: compile before traffic.
* ``submit()`` routes requests to buckets and returns an
  :class:`AlignFuture`; dispatches are double-buffered — batch N+1 is
  encoded/padded on host while batch N computes under jax async dispatch
  — and ``results()`` / ``future.result()`` stream decoded CIGARs back.
* Rescue (``rescue_mode='bucket'``, the default) gathers still-failed
  lanes and compacts them into the next-smaller length/lane bucket per
  k-doubling rung, so solved lanes' windows are never recomputed and the
  rung executables are cached like any other bucket.  Bit-identical to
  the legacy host loop and the on-device ladder (tests/test_rescue.py).

``GenASMAligner`` (exact shapes) and ``AlignmentEngine`` (now a shim over
this session) remain as the reference implementations — docs/api.md has
the deprecation table.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from ..core import transfer
from ..core.aligner import AlignResult
from ..core.cigar import ops_to_string
from ..core.config import AlignerConfig, resolve_config
from ..core.windowing import (SENTINEL_READ, SENTINEL_REF, bucket_avals,
                              pad_geometry, pow2_bucket, rescue_schedule)
from ..distributed.sharding import bucket_lanes


# --------------------------------------------------------------------------
# spec
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AlignSpec:
    """Everything a session needs, resolved and validated ONCE at plan time
    (the former GenASMAligner/AlignmentEngine/make_align_step knob trio).

    cfg           — the aligner geometry/backend (see core.config).
    rescue_rounds — k-doubling ladder depth past the base k.
    rescue_mode   — 'bucket' (compact failed lanes into smaller bucket
                    executables per rung; default) or 'device' (the
                    on-device masked ladder: 1 upload + 1 download total).
    batch_lanes   — lanes per full dispatch (quantised up to the pair
                    quantum at plan time).
    bucket_floor  — smallest power-of-two length bucket.
    max_inflight  — dispatches in flight before the oldest is retired
                    (2 = double buffering: pad N+1 while N computes).
    mesh          — optional device mesh; every executable is lowered
                    against it (shard_map'd Pallas / GSPMD jnp paths).
    """
    cfg: AlignerConfig = AlignerConfig()
    rescue_rounds: int = 2
    rescue_mode: str = "bucket"
    batch_lanes: int = 64
    bucket_floor: int = 32
    max_inflight: int = 2
    mesh: object = None

    def __post_init__(self):
        assert self.rescue_mode in ("bucket", "device"), self.rescue_mode
        assert self.rescue_rounds >= 0
        assert self.batch_lanes >= 1
        assert self.bucket_floor >= 1
        assert self.max_inflight >= 1

    def key(self):
        """Hashable identity of everything that shapes an executable
        (mesh excluded — it is a separate component of the cache key)."""
        return (self.cfg, self.rescue_rounds, self.rescue_mode)

    def read_bucket(self, read_len: int) -> int:
        return pow2_bucket(read_len, self.bucket_floor)

    def ref_bucket(self, ref_len: int) -> int:
        return pow2_bucket(ref_len, self.bucket_floor)


def plan(cfg: AlignerConfig | None = None, *, backend: str | None = None,
         rescue_rounds: int = 2, rescue_mode: str = "bucket",
         batch_lanes: int = 64, bucket_floor: int = 32,
         max_inflight: int = 2, mesh=None, **cfg_overrides) -> "AlignSession":
    """Resolve a cfg-like spec into a planned :class:`AlignSession`.

    Accepts an AlignerConfig (or None for defaults) plus any AlignerConfig
    field as a keyword override (``backend=``, ``W=``, ``k=``, ...) and the
    session knobs above.  This is the one validation funnel — nothing
    downstream re-derives or re-checks knobs.
    """
    cfg = resolve_config(cfg, backend=backend, **cfg_overrides)
    spec = AlignSpec(cfg=cfg, rescue_rounds=rescue_rounds,
                     rescue_mode=rescue_mode,
                     batch_lanes=bucket_lanes(batch_lanes, cfg, mesh),
                     bucket_floor=bucket_floor, max_inflight=max_inflight,
                     mesh=mesh)
    return AlignSession(spec)


# --------------------------------------------------------------------------
# compile cache
# --------------------------------------------------------------------------

class CompileCache:
    """Explicit AOT-executable cache keyed by (spec, bucket, mesh).

    ``get(key, build)`` returns the cached executable or AOT-lowers a new
    one via ``build()`` (``jax.jit(...).lower(*avals).compile()`` — one
    trace + one lowering, counted).  The counters ARE the compile-
    stability contract: a ragged stream must show ``misses == lowerings ==
    number of distinct buckets`` and hits for everything else.
    """

    def __init__(self):
        self._exe: dict = {}
        self.hits = 0
        self.misses = 0
        self.lowerings = 0
        self.bucket_hits: dict = {}     # key -> times served from cache

    def get(self, key, build):
        exe = self._exe.get(key)
        if exe is None:
            self.misses += 1
            self.lowerings += 1
            exe = self._exe[key] = build()
        else:
            self.hits += 1
            self.bucket_hits[key] = self.bucket_hits.get(key, 0) + 1
        return exe

    def __len__(self):
        return len(self._exe)

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "lowerings": self.lowerings, "executables": len(self),
                "bucket_hits": {str(k): v
                                for k, v in self.bucket_hits.items()}}


# --------------------------------------------------------------------------
# futures
# --------------------------------------------------------------------------

class AlignFuture:
    """Handle for one submitted pair; fulfilled when its dispatch retires."""

    __slots__ = ("rid", "_session", "_value")

    def __init__(self, session: "AlignSession", rid: int):
        self._session = session
        self.rid = rid
        self._value = None

    def done(self) -> bool:
        return self._value is not None

    def result(self) -> dict:
        """Block until this pair's result is available and return it:
        {ok, dist, cigar, k_used, ops, read_consumed, ref_consumed}.
        Collecting here counts as collecting: the session forgets the rid
        (it will not appear in results()), keeping long-lived streaming
        memory bounded by what is in flight."""
        if self._value is None:
            self._session._force(self)
        assert self._value is not None
        self._session._open.pop(self.rid, None)
        return self._value


@dataclasses.dataclass
class _Dispatch:
    """One in-flight bucket batch: device outputs + what retiring needs."""
    futures: list          # n_real AlignFutures, lane order
    reads: list            # n_real host code arrays (for bucket rescue)
    refs: list
    out: dict              # device arrays (async) from the executable


# --------------------------------------------------------------------------
# session
# --------------------------------------------------------------------------

class AlignSession:
    """The planned front door: shape-stable, AOT-compiled, streaming.

    Lifecycle: ``plan(...)`` -> optional ``warmup(...)`` -> ``submit(...)``
    per request (or ``align(reads, refs)`` for a one-shot batch) ->
    ``flush()`` / ``results()`` / ``future.result()``.
    """

    def __init__(self, spec: AlignSpec):
        self.spec = spec
        self.cfg = spec.cfg          # resolved; exposed for shims/stats
        self.mesh = spec.mesh
        self.cache = CompileCache()
        self._queues: dict[tuple, list] = {}   # bucket -> [(future, r, f)]
        self._inflight: deque[_Dispatch] = deque()
        self._open: dict[int, AlignFuture] = {}   # not yet handed out
        self._next_rid = 0
        self.stats = {"dispatches": 0, "lanes": 0, "pad_lanes": 0,
                      "requests": 0, "rescue_dispatches": 0,
                      "rescue_lanes": 0, "wall_s": 0.0}

    # ---- planning / warm-up -------------------------------------------

    def bucket_for(self, read_len: int, ref_len: int) -> tuple[int, int]:
        """The (read_bucket, ref_bucket) length class a pair routes to."""
        return (self.spec.read_bucket(read_len),
                self.spec.ref_bucket(ref_len))

    def warmup(self, length_classes, lanes: int | None = None) -> dict:
        """AOT-compile executables ahead of traffic — an explicit method,
        not a side effect of the first submit.

        length_classes: iterable of (read_len, ref_len) pairs; each is
        bucketed and compiled at the `lanes` lane class (default
        spec.batch_lanes) — for 'bucket' rescue, every k-doubling rung is
        compiled at that same bucket/lane class too.  Note the residual
        stall this cannot remove: a compacted rescue round re-derives its
        length bucket and lane class from however many lanes actually
        failed, which is unknowable ahead of traffic — if that smaller
        class was never warmed (call warmup again with smaller `lanes` /
        lengths to cover expected failure rates), its first occurrence
        lowers mid-traffic.  rescue_mode='device' has no such stall (the
        whole ladder is one executable).  Returns the cache stats
        snapshot."""
        lanes = self.spec.batch_lanes if lanes is None else lanes
        for read_len, ref_len in length_classes:
            rb, fb = self.bucket_for(read_len, ref_len)
            nb = bucket_lanes(lanes, self.cfg, self.mesh)
            if self.spec.rescue_mode == "device":
                self._executable(self.cfg, nb, rb, fb,
                                 rescue_rounds=self.spec.rescue_rounds)
            else:
                self._executable(self.cfg, nb, rb, fb, rescue_rounds=None)
                for cfg_r in rescue_schedule(self.cfg,
                                             self.spec.rescue_rounds)[1:]:
                    self._executable(cfg_r, nb, rb, fb, rescue_rounds=None)
        return self.cache.stats()

    # ---- executables ---------------------------------------------------

    def _executable(self, cfg, lanes, read_bucket, ref_bucket,
                    rescue_rounds):
        """The (spec, bucket, mesh)-keyed AOT executable for one batch
        shape.  rescue_rounds=None -> plain align step (one ladder rung);
        an int -> the whole on-device ladder."""
        key = (self.spec.key(), cfg, lanes, read_bucket, ref_bucket,
               rescue_rounds, self.mesh)

        def build():
            from ..serve.align_step import make_align_step
            step = make_align_step(cfg, read_bucket, self.mesh,
                                   rescue_rounds=rescue_rounds)
            avals = bucket_avals(cfg, lanes, read_bucket, ref_bucket,
                                 rescue_rounds or 0)
            return step.lower(*avals).compile()

        return self.cache.get(key, build)

    # ---- streaming -----------------------------------------------------

    def submit(self, read: np.ndarray, ref: np.ndarray) -> AlignFuture:
        """Queue one encoded (read, ref) pair; dispatches fire whenever a
        bucket queue reaches batch_lanes (earlier batches keep computing —
        double buffering)."""
        fut = AlignFuture(self, self._next_rid)
        self._next_rid += 1
        self._open[fut.rid] = fut
        self.stats["requests"] += 1
        bucket = self.bucket_for(len(read), len(ref))
        q = self._queues.setdefault(bucket, [])
        q.append((fut, read, ref))
        if len(q) >= self.spec.batch_lanes:
            self._dispatch(bucket, self._queues.pop(bucket))
        return fut

    def flush(self):
        """Dispatch every partially-filled bucket queue."""
        for bucket in list(self._queues):
            self._dispatch(bucket, self._queues.pop(bucket))

    def results(self) -> dict[int, dict]:
        """Flush, retire every in-flight dispatch, and return
        {rid: result dict} for every request not yet collected.  Collected
        rids are forgotten, so a long-lived session's memory stays bounded
        by what is in flight."""
        self.flush()
        while self._inflight:
            self._retire(self._inflight.popleft())
        done = {rid: fut._value for rid, fut in self._open.items()
                if fut.done()}
        for rid in done:
            del self._open[rid]
        return done

    def align(self, reads, refs) -> AlignResult:
        """One-shot batch: submit all pairs, drain, and assemble an
        AlignResult in input order — drop-in for GenASMAligner.align and
        bit-identical to it (tests/test_api.py)."""
        assert len(reads) == len(refs)
        futs = [self.submit(r, f) for r, f in zip(reads, refs)]
        self.flush()
        recs = [f.result() for f in futs]   # result() collects each rid
        B = len(recs)
        dist = np.array([r["dist"] for r in recs], np.int64)
        failed = np.array([not r["ok"] for r in recs], bool)
        k_used = np.array([r["k_used"] for r in recs], np.int32)
        rcon = np.array([r["read_consumed"] for r in recs], np.int32)
        fcon = np.array([r["ref_consumed"] for r in recs], np.int32)
        return AlignResult(dist, [r["cigar"] for r in recs],
                           [r["ops"] for r in recs], failed, k_used,
                           rcon, fcon)

    # ---- dispatch / retire ---------------------------------------------

    def _pad_batch(self, reads, refs, lanes, Lr, Lf):
        """Pad to `lanes` rows of (Lr, Lf) sentinels; ragged lane tails are
        REPEATS of the last real pair (exactly as alignable as its twin,
        so pads can't keep rescue gates open or skew stats — the engine
        trick, now session-wide)."""
        n = len(reads)
        reads = list(reads) + [reads[-1]] * (lanes - n)
        refs = list(refs) + [refs[-1]] * (lanes - n)
        rpad = np.full((lanes, Lr), SENTINEL_READ, np.uint8)
        fpad = np.full((lanes, Lf), SENTINEL_REF, np.uint8)
        rlen = np.zeros(lanes, np.int32)
        flen = np.zeros(lanes, np.int32)
        for i, (r, f) in enumerate(zip(reads, refs)):
            rpad[i, :len(r)] = r
            rlen[i] = len(r)
            fpad[i, :len(f)] = f
            flen[i] = len(f)
        return rpad, rlen, fpad, flen

    def _dispatch(self, bucket, items):
        """Pad one bucket batch on host, upload once, launch the executable
        (async — control returns while the device computes), and queue the
        dispatch for retirement.  Exceeding max_inflight retires the
        oldest first, which is what makes this double-buffered."""
        while len(self._inflight) >= self.spec.max_inflight:
            self._retire(self._inflight.popleft())
        t0 = time.time()
        futs = [it[0] for it in items]
        reads = [it[1] for it in items]
        refs = [it[2] for it in items]
        rb, fb = bucket
        lanes = bucket_lanes(len(items), self.cfg, self.mesh)
        device_mode = self.spec.rescue_mode == "device"
        rounds = self.spec.rescue_rounds if device_mode else None
        exe = self._executable(self.cfg, lanes, rb, fb, rescue_rounds=rounds)
        Lr, Lf = pad_geometry(self.cfg, rb, fb, rounds or 0)
        dev = transfer.to_device(self._pad_batch(reads, refs, lanes, Lr, Lf))
        out, _ = exe(*dev)
        self._inflight.append(_Dispatch(futs, reads, refs, out))
        self.stats["dispatches"] += 1
        self.stats["lanes"] += lanes
        self.stats["pad_lanes"] += lanes - len(items)
        self.stats["wall_s"] += time.time() - t0

    def _retire(self, d: _Dispatch):
        """Force one dispatch: download once, run compacted bucket-rescue
        rounds if needed, decode CIGARs, fulfill futures."""
        t0 = time.time()
        n = len(d.futures)
        keys = ("ops", "n_ops", "dist", "failed", "read_consumed",
                "ref_consumed") + (("k_used",) if "k_used" in d.out else ())
        host = transfer.to_host({k: d.out[k] for k in keys})
        failed = np.array(host["failed"][:n], bool)   # writable (rescue merge)
        dist = np.asarray(host["dist"])[:n].astype(np.int64)
        n_ops = np.asarray(host["n_ops"])[:n]
        ops_buf = np.asarray(host["ops"])[:n]
        rcon = np.asarray(host["read_consumed"])[:n].astype(np.int32)
        fcon = np.asarray(host["ref_consumed"])[:n].astype(np.int32)
        if "k_used" in host:
            k_used = np.asarray(host["k_used"])[:n].astype(np.int32)
        else:
            k_used = np.where(failed, 0, self.cfg.k).astype(np.int32)
        all_ops = [ops_buf[i, :n_ops[i]].copy() if not failed[i] else None
                   for i in range(n)]
        if self.spec.rescue_mode == "bucket" and failed.any():
            self._rescue_compacted(d, failed, dist, k_used, rcon, fcon,
                                   all_ops)
        dist = np.where(failed, 0, dist)
        for i, fut in enumerate(d.futures):
            ops = all_ops[i] if all_ops[i] is not None \
                else np.zeros(0, np.uint8)
            fut._value = {
                "ok": not failed[i], "dist": int(dist[i]),
                "cigar": ops_to_string(ops) if not failed[i] else "",
                "k_used": int(k_used[i]), "ops": ops,
                "read_consumed": int(0 if failed[i] else rcon[i]),
                "ref_consumed": int(0 if failed[i] else fcon[i]),
            }
        self.stats["wall_s"] += time.time() - t0

    def _rescue_compacted(self, d, failed, dist, k_used, rcon, fcon,
                          all_ops):
        """The ROADMAP rescue-efficiency item: instead of recomputing every
        lane's windows each k-doubling round (the on-device ladder) or
        re-tracing ragged subsets (the host loop), gather the still-failed
        lanes and compact them into the next-smaller length/lane bucket —
        solved lanes never recompute, shapes stay bucket-stable, and the
        rung executables live in the same CompileCache.  Bit-identical to
        rescue_mode='host' per lane (tests/test_rescue.py)."""
        todo = [i for i in range(len(d.futures)) if failed[i]]
        for cfg_r in rescue_schedule(self.cfg, self.spec.rescue_rounds)[1:]:
            if not todo:
                return
            reads = [d.reads[i] for i in todo]
            refs = [d.refs[i] for i in todo]
            rb = self.spec.read_bucket(max(len(r) for r in reads))
            fb = self.spec.ref_bucket(max(len(f) for f in refs))
            lanes = bucket_lanes(len(todo), cfg_r, self.mesh)
            exe = self._executable(cfg_r, lanes, rb, fb, rescue_rounds=None)
            Lr, Lf = pad_geometry(cfg_r, rb, fb, 0)
            dev = transfer.to_device(
                self._pad_batch(reads, refs, lanes, Lr, Lf))
            out, _ = exe(*dev)
            host = transfer.to_host(
                {k: out[k] for k in ("ops", "n_ops", "dist", "failed",
                                     "read_consumed", "ref_consumed")})
            self.stats["rescue_dispatches"] += 1
            self.stats["rescue_lanes"] += lanes
            ok = ~np.asarray(host["failed"])
            for loc, glob in enumerate(todo):
                if ok[loc]:
                    nops = int(host["n_ops"][loc])
                    all_ops[glob] = np.asarray(
                        host["ops"])[loc, :nops].copy()
                    dist[glob] = int(host["dist"][loc])
                    k_used[glob] = cfg_r.k
                    rcon[glob] = int(host["read_consumed"][loc])
                    fcon[glob] = int(host["ref_consumed"][loc])
                    failed[glob] = False
            todo = [g for g in todo if failed[g]]

    # ---- forcing -------------------------------------------------------

    def _force(self, fut: AlignFuture):
        """Resolve one future: retire in-flight dispatches oldest-first
        (they were launched first), dispatching its queue if still held."""
        for bucket, q in list(self._queues.items()):
            if any(it[0] is fut for it in q):
                self._dispatch(bucket, self._queues.pop(bucket))
                break
        while self._inflight and not fut.done():
            self._retire(self._inflight.popleft())

    def session_stats(self) -> dict:
        """Serving + compile-cache counters in one dict (benchmarks/CI)."""
        return dict(self.stats, compile_cache=self.cache.stats())
