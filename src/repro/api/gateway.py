"""repro.api.gateway — the concurrent multi-tenant front end with SLOs.

The paper's headline is throughput; a millions-of-users service lives by
TAIL LATENCY under concurrent, skewed load.  GenASM's window-independent
divide-and-conquer (the property Scrooge exploits for GPU scheduling)
means per-lane results are batch-composition independent, so a scheduler
is free to regroup, reorder and preempt requests at bucket granularity
without touching kernel code — exactly what this layer does on top of
:class:`repro.api.AlignSession`:

* **Tenants & priority lanes** — ``gateway.tenant(name, priority=...)``
  hands out submit handles.  Priority 0 is the latency lane: at every
  pump, dispatchable batches are ordered by (priority, oldest arrival),
  so a short-read latency bucket preempts a bulk long-read bucket that
  has been waiting longer — preemption at bucket granularity through the
  bucket separation the session already maintains.
* **Deadlines with an injectable clock** — every request may carry an
  absolute deadline (``deadline_s`` from submit time, by the gateway's
  ``clock``).  The deadline sweep expires QUEUED requests the moment
  ``now >= deadline`` (they fail fast with :class:`DeadlineExceeded` and
  their queue slot is freed — never dispatched, never wasting a lane);
  requests already dispatched complete normally and are scored against
  their deadline at COMPLETION time (``deadline_met``), which is the
  SLO-accounting a deadline-hit-rate benchmark needs.  Everything is
  driven by ``pump(now)``, so the whole scheduling surface is provable
  with a fake clock and scripted arrival traces — zero ``time.sleep`` in
  tier-1 (tests/test_gateway.py).
* **Cancellation that frees slots** — ``future.cancel()`` removes a
  queued request atomically (under the gateway lock, and under the
  session's submit lock for the mid-batch window), so the slot either
  cancels or dispatches, never both; a dispatched lane cannot be
  recalled — cancel returns False and the result simply arrives.
* **Load shedding (reject-fast)** — admission control sheds at submit
  time instead of queueing forever: a request of priority p is refused
  with :class:`ShedError` when the pairs in the system (gateway-queued +
  dispatched-but-unfinished — the PR-5 inflight signal, counted exactly)
  reach ``capacity * shed_frac[p]``, so bulk lanes shed earlier than the
  latency lane.  ``capacity=None`` derives the ceiling live from the
  session's occupancy-adaptive in-flight bound
  (``batch_lanes * (max_inflight + 1)``): when the PR-6 occupancy
  controller widens the pipeline, admission widens with it.

Thread model: ``submit``/``pump``/``cancel``/``close`` are safe from many
client threads (one re-entrant scheduling lock; completion callbacks from
the session's retire thread only ever take the separate stats lock, so
retire can never deadlock against a pumping client).  Results are
bit-identical to a serial AlignSession run of the same pairs — scheduling
reorders work in time, never in value (hammer suite in
tests/test_gateway.py, ≥8 client threads).

Lifecycle::

    session = plan(cfg, batch_lanes=8, executor="thread")
    gw = Gateway(session, policy=GatewayPolicy(capacity=64))
    latency = gw.tenant("short-reads", priority=0, deadline_s=0.5)
    bulk = gw.tenant("long-reads", priority=1)
    fut = latency.submit(read, ref)        # may raise ShedError
    ...
    fut.result(timeout=1.0)                # {ok, dist, cigar, ...}
    gw.close(); session.close()

See docs/api.md ("The multi-tenant gateway") for the full concurrency
contract.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

from ..obs import resolve_obs
from .session import AlignSession, RequestCancelled, SessionPoisonedError


class ShedError(RuntimeError):
    """Admission control refused this request: the system is at this
    priority's shed threshold.  Raised by submit() — reject-fast, the
    request never queued."""


class DeadlineExceeded(RuntimeError):
    """The request's deadline passed while it was still QUEUED: the sweep
    failed it fast and freed its slot (it was never dispatched)."""


class GatewayClosedError(RuntimeError):
    """The gateway refused the submit because close() already ran."""


@dataclasses.dataclass(frozen=True)
class GatewayPolicy:
    """The scheduling/shedding knobs, validated once at construction.

    capacity      — admission ceiling in PAIRS in the system (queued +
                    dispatched-but-unfinished).  None (default) derives it
                    live from the session: ``batch_lanes *
                    (max_inflight + 1)`` — wired to the occupancy-adaptive
                    in-flight signal, so a widened pipeline admits more.
    shed_frac     — per-priority fraction of capacity at which submits
                    shed (indexed by priority, last entry covers deeper
                    priorities).  The default sheds bulk (p>=2) at 50%,
                    standard (p=1) at 75%, and the latency lane (p=0)
                    only when the system is truly full.
    linger_s      — max age of the oldest queued request in a bucket
                    before a PARTIAL batch becomes dispatchable (the
                    latency-lane flush that keeps p99 bounded without
                    waiting for a full lane class).
    service_margin_s — dispatch a partial batch early when any queued
                    deadline is within this margin of now (a request that
                    would expire waiting for a full batch goes out now).
    """
    capacity: int | None = None
    shed_frac: tuple = (1.0, 0.75, 0.5)
    linger_s: float = 0.05
    service_margin_s: float = 0.0

    def __post_init__(self):
        assert self.capacity is None or self.capacity >= 1
        assert len(self.shed_frac) >= 1
        assert all(0.0 < f <= 1.0 for f in self.shed_frac)
        assert self.linger_s >= 0.0 and self.service_margin_s >= 0.0

    def frac_for(self, priority: int) -> float:
        return self.shed_frac[min(priority, len(self.shed_frac) - 1)]


class GatewayFuture:
    """Handle for one admitted request.  States: queued (in the gateway,
    cancellable/expirable) -> dispatched (owns an AlignFuture) -> done
    (value, error, cancelled or expired).  ``t_submit``/``t_dispatch``/
    ``t_done`` are gateway-clock timestamps; ``deadline_met`` is scored at
    completion time."""

    __slots__ = ("rid", "tenant", "priority", "bucket", "deadline",
                 "t_submit", "t_dispatch", "t_done", "_gateway", "_inner",
                 "_value", "_error", "_event", "_cancelled", "_finalized",
                 "_read", "_ref")

    def __init__(self, gateway: "Gateway", rid: int, tenant: str,
                 priority: int, bucket, deadline: float | None,
                 t_submit: float):
        self._gateway = gateway
        self.rid = rid
        self.tenant = tenant
        self.priority = priority
        self.bucket = bucket
        self.deadline = deadline
        self.t_submit = t_submit
        self.t_dispatch = None
        self.t_done = None
        self._inner = None
        self._value = None
        self._error = None
        self._event = threading.Event()
        self._cancelled = False
        self._finalized = False

    def done(self) -> bool:
        return self._event.is_set()

    def cancelled(self) -> bool:
        return self._cancelled

    @property
    def latency(self) -> float | None:
        """Submit-to-completion seconds (None until done)."""
        if self.t_done is None or self.t_submit is None:
            return None
        return self.t_done - self.t_submit

    @property
    def deadline_met(self) -> bool | None:
        """True when the request completed successfully within its
        deadline (no-deadline requests always meet); None until done."""
        if not self._event.is_set():
            return None
        if self._error is not None:
            return False
        return self.deadline is None or self.t_done <= self.deadline

    def result(self, timeout: float | None = None) -> dict:
        """Block until done and return the alignment record; raises the
        failure (DeadlineExceeded / RequestCancelled / ShedError never —
        sheds don't produce futures — or the dispatch's exception).  A
        still-queued request is force-dispatched first; ``timeout``
        bounds the wait (TimeoutError on expiry; the future stays
        collectable — timeout-then-fulfill is tested)."""
        if not self._event.is_set():
            self._gateway._force(self, timeout=timeout)
        if not self._event.is_set():
            raise TimeoutError(
                f"gateway result rid={self.rid} not ready within {timeout}s")
        if self._error is not None:
            raise self._error
        return self._value

    def cancel(self) -> bool:
        """Cancel if still queued (gateway queue, or the session queue
        during the mid-batch window): the slot is freed before any
        dispatch and result() raises RequestCancelled.  False once the
        pair is on a dispatched lane — a committed lane is never freed
        twice, the result simply arrives.  Idempotent."""
        return self._gateway._cancel(self)


class Tenant:
    """A named submit handle: carries the tenant's default priority and
    deadline; per-request overrides allowed.  Cheap — hold one per client
    thread or share, both are safe."""

    __slots__ = ("gateway", "name", "priority", "deadline_s")

    def __init__(self, gateway: "Gateway", name: str, priority: int = 1,
                 deadline_s: float | None = None):
        assert priority >= 0, priority
        self.gateway = gateway
        self.name = name
        self.priority = priority
        self.deadline_s = deadline_s

    def submit(self, read, ref, deadline_s: float | None = None,
               priority: int | None = None) -> GatewayFuture:
        """Admit one pair (or raise ShedError / GatewayClosedError)."""
        return self.gateway.submit(
            self, read, ref,
            deadline_s=self.deadline_s if deadline_s is None else deadline_s,
            priority=self.priority if priority is None else priority)


class Gateway:
    """The scheduling layer over one AlignSession (see module docstring).

    ``auto_pump=True`` (default) pumps inline on every submit, so full
    and urgent batches dispatch immediately; ``start_sweeper()``
    additionally runs a background pump loop for deadline expiry and
    linger flushes between submits (production).  Tests drive
    ``pump(now)`` manually with a fake clock — every scheduling decision
    is a pure function of (queues, now)."""

    #: legacy stats key -> registry metric name (see docs/observability.md)
    STAT_METRICS = {
        "submitted": "gateway_submitted_total",
        "shed": "gateway_shed_total",
        "expired": "gateway_expired_total",
        "cancelled": "gateway_cancelled_total",
        "dispatched": "gateway_dispatched_total",
        "completed": "gateway_completed_total",
        "failed": "gateway_failed_total",
        "deadline_hits": "gateway_deadline_hits_total",
        "deadline_misses": "gateway_deadline_misses_total",
        "pumps": "gateway_pumps_total",
        "partial_dispatches": "gateway_partial_dispatches_total",
    }
    #: per-tenant counter families, labelled ``tenant="<name>"``
    TENANT_KEYS = ("submitted", "shed", "expired", "cancelled",
                   "completed", "deadline_hits")

    def __init__(self, session: AlignSession,
                 policy: GatewayPolicy = GatewayPolicy(), clock=None,
                 auto_pump: bool = True, obs=None):
        self.session = session
        self.policy = policy
        self._clock = clock if clock is not None else time.monotonic
        self.auto_pump = auto_pump
        # the gateway shares the session's observability domain by
        # default — one registry/trace tells the whole admission ->
        # dispatch -> retire story; pass obs= to split it out
        self.obs = session.obs if obs is None else \
            resolve_obs(obs, clock=self._clock)
        self._m = {k: self.obs.counter(name)
                   for k, name in self.STAT_METRICS.items()}
        self._tm: dict[str, dict] = {}          # tenant -> key -> counter
        # live-load gauges mirror _n_queued/_n_outstanding; the plain
        # ints stay the functional source of truth so admission control
        # keeps working under obs='off' (gauges would read 0)
        self._g_queued = self.obs.gauge("gateway_queued")
        self._g_outstanding = self.obs.gauge("gateway_outstanding")
        self._h_latency = self.obs.histogram("gateway_latency_seconds")
        # _lock: scheduling state (queues, dispatch) — client threads only.
        # _stats_lock: counters + future finalisation — ALSO taken by the
        # session's retire thread (completion callbacks), so nothing may
        # block while holding it, or retire could deadlock a pumping
        # client stuck on dispatch backpressure.
        self._lock = threading.RLock()
        self._stats_lock = threading.Lock()
        self._queues: dict[tuple, list] = {}    # (priority, bucket) -> [gf]
        self._next_rid = 0
        self._closed = False
        self._n_queued = 0
        self._n_outstanding = 0                 # dispatched, not finalized
        self._sweeper: threading.Thread | None = None
        self._sweeper_stop: threading.Event | None = None
        #: (priority, bucket, n_real) per dispatch, newest last — the
        #: observable the deterministic preemption tests assert on
        self.dispatch_log: deque = deque(maxlen=1024)

    @property
    def stats(self) -> dict:
        """Scheduling counters as the legacy dict — a view over the obs
        registry (asserted equal to registry reads in tests/test_obs.py)."""
        return {k: m.value for k, m in self._m.items()}

    @property
    def tenant_stats(self) -> dict:
        """{tenant: {key: value}} — a view over the per-tenant labelled
        counters (``gateway_tenant_*_total{tenant=...}``)."""
        return {name: {k: c.value for k, c in tm.items()}
                for name, tm in self._tm.items()}

    def _tenant_metrics(self, name: str) -> dict:
        """The tenant's counter family, created on first touch (under the
        stats lock — callers hold it or are __init__/tenant())."""
        tm = self._tm.get(name)
        if tm is None:
            tm = self._tm[name] = {
                k: self.obs.counter(f"gateway_tenant_{k}_total",
                                    tenant=name)
                for k in self.TENANT_KEYS}
        return tm

    # ---- tenants -------------------------------------------------------

    def tenant(self, name: str, priority: int = 1,
               deadline_s: float | None = None) -> Tenant:
        with self._stats_lock:
            self._tenant_metrics(name)
        return Tenant(self, name, priority=priority, deadline_s=deadline_s)

    # ---- admission -----------------------------------------------------

    def capacity(self) -> int:
        """The live admission ceiling in pairs: the policy's, or derived
        from the session's occupancy signals (batch_lanes *
        (max_inflight + 1)) — the adaptive-inflight controller widening
        the pipeline widens admission with it."""
        if self.policy.capacity is not None:
            return self.policy.capacity
        return self.session.spec.batch_lanes * (
            self.session.load()["max_inflight"] + 1)

    def in_system(self) -> int:
        """Pairs occupying the gateway + session right now: queued here
        plus dispatched-but-unfinished (counted exactly via completion
        callbacks — this IS the inflight signal admission reads)."""
        with self._stats_lock:
            return self._n_queued + self._n_outstanding

    def submit(self, tenant: Tenant, read, ref,
               deadline_s: float | None = None,
               priority: int = 1) -> GatewayFuture:
        """Admit one request (reject-fast): sheds with ShedError when the
        system is at this priority's threshold, else queues it under
        (priority, bucket) and — with auto_pump — dispatches whatever
        became full/urgent.  Thread-safe."""
        now = self._clock()
        with self.obs.span("gateway.admit", tenant=tenant.name,
                           priority=priority):
            with self._lock:
                if self._closed:
                    raise GatewayClosedError("gateway is closed")
                n, cap = self.in_system(), self.capacity()
                if n >= cap * self.policy.frac_for(priority):
                    with self._stats_lock:
                        self._m["shed"].inc()
                        self._tenant_metrics(tenant.name)["shed"].inc()
                    raise ShedError(
                        f"priority-{priority} request shed: {n} pairs in "
                        f"system >= {self.policy.frac_for(priority):.0%} of "
                        f"capacity {cap}")
                bucket = self.session.bucket_for(len(read), len(ref))
                deadline = None if deadline_s is None else now + deadline_s
                gf = GatewayFuture(self, self._next_rid, tenant.name,
                                   priority, bucket, deadline, now)
                self._next_rid += 1
                gf._read, gf._ref = read, ref
                self._queues.setdefault((priority, bucket), []).append(gf)
                with self._stats_lock:
                    self._n_queued += 1
                    self._g_queued.add(1)
                    self._m["submitted"].inc()
                    self._tenant_metrics(tenant.name)["submitted"].inc()
            if self.auto_pump:
                self.pump(now)
        return gf

    # ---- the pump: sweep + priority-ordered dispatch -------------------

    def pump(self, now: float | None = None) -> int:
        """One scheduling step: expire queued deadlines, then dispatch
        every full or urgent batch in (priority, oldest-arrival) order —
        re-evaluated after each dispatch, so an urgent latency bucket
        that became dispatchable preempts the next bulk batch.  Returns
        the number of dispatches.  Deterministic given (queues, now):
        the fake-clock suite asserts exact decisions."""
        ndisp = 0
        with self._lock:
            if now is None:
                now = self._clock()
            self._m["pumps"].inc()
            self._sweep_deadlines(now)
            while True:
                key = self._next_dispatchable(now)
                if key is None:
                    break
                self._dispatch_from(key)
                ndisp += 1
        return ndisp

    def _sweep_deadlines(self, now: float) -> None:
        for key in list(self._queues):
            q = self._queues[key]
            keep = []
            for gf in q:
                if gf.deadline is not None and now >= gf.deadline:
                    self._finalize(gf, error=DeadlineExceeded(
                        f"rid={gf.rid} queued past its deadline "
                        f"({now - gf.deadline:.3f}s over)"), kind="expired")
                else:
                    keep.append(gf)
            if keep:
                self._queues[key] = keep
            else:
                del self._queues[key]

    def _next_dispatchable(self, now: float):
        """The (priority, bucket) queue to dispatch next: full queues and
        urgent ones (linger age or deadline margin), best (priority,
        oldest arrival) first.  None when nothing is dispatchable."""
        best = None
        for key, q in self._queues.items():
            if not q:
                continue
            full = len(q) >= self.session._current_lanes(key[1])
            urgent = (now - q[0].t_submit >= self.policy.linger_s) or any(
                gf.deadline is not None
                and gf.deadline - self.policy.service_margin_s <= now
                for gf in q)
            if not (full or urgent):
                continue
            rank = (key[0], q[0].t_submit)
            if best is None or rank < best[0]:
                best = (rank, key)
        return None if best is None else best[1]

    def _dispatch_from(self, key) -> None:
        """Move up to one lane class of requests from a gateway queue into
        the session (which fires the device dispatch when the bucket
        fills; partial batches are flushed explicitly).  Completion is
        observed via AlignFuture done-callbacks — they record the
        completion TIME under the stats lock and forget the session rid,
        keeping a long-lived gateway's memory bounded."""
        priority, bucket = key
        q = self._queues[key]
        lanes = self.session._current_lanes(bucket)
        batch, rest = q[:lanes], q[lanes:]
        if rest:
            self._queues[key] = rest
        else:
            del self._queues[key]
        with self._stats_lock:
            self._n_queued -= len(batch)
            self._n_outstanding += len(batch)
            self._g_queued.add(-len(batch))
            self._g_outstanding.add(len(batch))
            self._m["dispatched"].inc(len(batch))
            if len(batch) < lanes:
                self._m["partial_dispatches"].inc()
        self.dispatch_log.append((priority, bucket, len(batch)))
        t_disp = self._clock()
        err = None
        for i, gf in enumerate(batch):
            if err is not None:
                self._finalize(gf, error=err, kind="failed")
                continue
            try:
                af = self.session.submit(gf._read, gf._ref)
            except BaseException as e:   # poisoned/closed session
                err = e
                self._finalize(gf, error=e, kind="failed")
                continue
            gf.t_dispatch = t_disp
            gf._read = gf._ref = None          # the session owns them now
            gf._inner = af
            af.add_done_callback(
                lambda af, gf=gf: self._on_inner_done(gf, af))
        if err is None and len(batch) < lanes:
            self.session.flush()               # fire the partial batch

    # ---- completion / finalisation -------------------------------------

    def _on_inner_done(self, gf: GatewayFuture, af) -> None:
        """AlignFuture completion hook — runs on whichever thread retired
        the dispatch (the session's retire thread under
        executor='thread').  Takes ONLY the stats lock."""
        if af._error is not None:
            kind = "cancelled" if isinstance(af._error, RequestCancelled) \
                else "failed"
            self._finalize(gf, error=af._error, kind=kind,
                           outstanding=not isinstance(af._error,
                                                      RequestCancelled))
        else:
            self._finalize(gf, value=af._value, kind="completed")
        self.session._forget(af.rid)           # gateway owns collection

    def _finalize(self, gf: GatewayFuture, value=None, error=None,
                  kind: str = "completed", outstanding: bool | None = None):
        """Resolve a gateway future exactly once (idempotent under the
        stats lock) and keep the queued/outstanding counters exact.
        `kind`: completed | failed | expired | cancelled.  `outstanding`
        says which counter the request occupied (defaults by kind)."""
        if outstanding is None:
            outstanding = kind in ("completed", "failed")
        with self._stats_lock:
            if gf._finalized:
                return
            gf._finalized = True
            gf.t_done = self._clock()
            gf._value, gf._error = value, error
            ts = self._tenant_metrics(gf.tenant)
            if outstanding:
                self._n_outstanding -= 1
                self._g_outstanding.add(-1)
            else:
                self._n_queued -= 1
                self._g_queued.add(-1)
            if kind == "completed":
                self._m["completed"].inc()
                ts["completed"].inc()
                self._h_latency.observe(gf.t_done - gf.t_submit)
                if gf.deadline is None or gf.t_done <= gf.deadline:
                    self._m["deadline_hits"].inc()
                    ts["deadline_hits"].inc()
                else:
                    self._m["deadline_misses"].inc()
            elif kind == "expired":
                gf._cancelled = True
                self._m["expired"].inc()
                ts["expired"].inc()
            elif kind == "cancelled":
                gf._cancelled = True
                self._m["cancelled"].inc()
                ts["cancelled"].inc()
            else:
                self._m["failed"].inc()
        gf._event.set()

    # ---- forcing / cancellation ----------------------------------------

    def _force(self, gf: GatewayFuture, timeout: float | None = None):
        """Resolve one future: if still gateway-queued, dispatch its
        queue as a partial batch now (result() must not wait on traffic
        that may never come), then wait on the session future."""
        with self._lock:
            if gf._inner is None and not gf.done():
                key = (gf.priority, gf.bucket)
                q = self._queues.get(key)
                if q and gf in q:
                    self._dispatch_from(key)
        inner = gf._inner
        if inner is not None and not gf._event.is_set():
            try:
                inner.result(timeout=timeout)
            except TimeoutError:
                if not inner.done():
                    return                     # caller raises TimeoutError
            except BaseException:
                pass                           # the callback recorded it
            # the inner future resolved: its callback has run (callbacks
            # fire inside _fulfill/_fail before result() returns on this
            # or the retire thread) — but guard the cross-thread window
            self._on_inner_done(gf, inner)     # idempotent

    def _cancel(self, gf: GatewayFuture) -> bool:
        with self._lock:
            if gf.done():
                return gf._cancelled
            if gf._inner is None:
                key = (gf.priority, gf.bucket)
                q = self._queues.get(key)
                if q and gf in q:
                    q.remove(gf)
                    if not q:
                        del self._queues[key]
                    self._finalize(gf, error=RequestCancelled(
                        f"rid={gf.rid} cancelled while queued"),
                        kind="cancelled", outstanding=False)
                    return True
            inner = gf._inner
        if inner is None:
            return gf._cancelled               # finalized under our feet
        # mid-batch window: the pair may still sit in the SESSION queue
        # (partial batch before flush).  session._cancel is atomic under
        # the submit lock — it either frees the slot (True, our callback
        # fires with RequestCancelled) or the lane is committed (False).
        return inner.cancel()

    # ---- sweeper / shutdown --------------------------------------------

    def start_sweeper(self, interval_s: float = 0.005) -> None:
        """Run pump() on a background loop so deadline expiry and linger
        flushes fire between submits (production serving).  Idempotent;
        close() stops it.  Tests drive pump(now) manually instead."""
        if self._sweeper is not None and self._sweeper.is_alive():
            return
        self._sweeper_stop = threading.Event()

        def loop():
            while not self._sweeper_stop.wait(interval_s):
                try:
                    self.pump()
                except SessionPoisonedError:
                    return                     # futures already failed

        self._sweeper = threading.Thread(target=loop, name="gateway-sweep",
                                         daemon=True)
        self._sweeper.start()

    def flush_all(self) -> None:
        """Dispatch everything still queued, in (priority, oldest) order,
        without closing — the batch-boundary drain for callers that pace
        their own traffic.  Retirement still happens via result() /
        session.results()."""
        with self._lock:
            while self._queues:
                key = min(self._queues,
                          key=lambda k: (k[0], self._queues[k][0].t_submit))
                self._dispatch_from(key)

    def close(self, drain: bool = True) -> None:
        """Stop the sweeper and shut the gateway down.  drain=True
        (default) dispatches everything still queued (priority order) and
        retires every outstanding lane — futures resolve before close
        returns.  drain=False fails queued futures fast with
        RequestCancelled (dispatched lanes still complete via the
        session).  Idempotent; the underlying session is NOT closed (the
        caller owns it)."""
        if self._sweeper_stop is not None:
            self._sweeper_stop.set()
        if self._sweeper is not None:
            self._sweeper.join()
            self._sweeper = None
        with self._lock:
            self._closed = True
            if drain:
                self.flush_all()
            else:
                for q in list(self._queues.values()):
                    for gf in q:
                        self._finalize(gf, error=RequestCancelled(
                            "gateway closed without drain"),
                            kind="cancelled", outstanding=False)
                self._queues.clear()
        if drain:
            try:
                self.session.results()         # force-retire everything
            except SessionPoisonedError:
                pass                           # futures carry the errors

    def __enter__(self) -> "Gateway":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(drain=exc_type is None)

    # ---- stats ----------------------------------------------------------

    def gateway_stats(self) -> dict:
        """Counters + live load + per-tenant breakdown (benchmarks/CI)."""
        with self._stats_lock:
            out = self.stats                   # registry-backed property
            out["tenants"] = self.tenant_stats
            out["queued"] = self._n_queued
            out["outstanding"] = self._n_outstanding
        out["capacity"] = self.capacity()
        out["session_load"] = self.session.load()
        out["dispatch_log_tail"] = list(self.dispatch_log)[-16:]
        return out
