"""Logical activation-sharding constraints, mesh-shape agnostic.

Model code calls constrain(x, 'batch', None, 'model') with logical dims;
the helper resolves them against whatever mesh the enclosing jit runs
under ('batch' -> ('pod','data') when a pod axis exists), skips axes that
don't divide, and is a no-op outside a mesh context (CPU unit tests).

This module is also the single source of truth for how the ALIGNER's pair
(batch) axis maps onto a mesh: `pair_axes` / `n_pair_shards` name the data
axes, `pair_shardings` builds the NamedShardings every sharded align step
uses, `constrain_pairs` pins the (B, ...) batch arrays inside a jit, and
`pair_pad_multiple` is the batch-size quantum the serving engine must pad
ragged batches to so every device gets an equal, kernel-tile-aligned
shard (see serve.engine / kernels.ops)."""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def pair_axes(mesh) -> tuple:
    """Mesh axes the alignment pair axis shards over (data-parallel)."""
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def n_pair_shards(mesh) -> int:
    """How many equal shards the pair axis splits into on `mesh`."""
    n = 1
    for a in pair_axes(mesh):
        n *= mesh.shape[a]
    return n


def pair_shardings(mesh):
    """(batch-major (B, L), per-lane (B,), replicated) NamedShardings for
    the aligner's arrays — shared by every sharded align-step factory."""
    dp = pair_axes(mesh)
    return (NamedSharding(mesh, P(dp, None)),
            NamedSharding(mesh, P(dp)),
            NamedSharding(mesh, P()))


def constrain_pairs(mesh, reads, read_len, refs, ref_len):
    """Pin the aligner batch inputs to the pair axes inside a jit, so the
    jnp fills (and everything around the shard_mapped kernels) are GSPMD
    data-parallel rather than replicated.  No-op when mesh is None or the
    batch does not divide the pair shards."""
    if mesh is None:
        return reads, read_len, refs, ref_len
    n = n_pair_shards(mesh)
    if n == 1 or reads.shape[0] % n != 0:
        return reads, read_len, refs, ref_len
    bsh, vsh, _ = pair_shardings(mesh)
    wsc = jax.lax.with_sharding_constraint
    return (wsc(reads, bsh), wsc(read_len, vsh),
            wsc(refs, bsh), wsc(ref_len, vsh))


def pair_pad_multiple(cfg, mesh) -> int:
    """Batch-size quantum for sharded serving: lane_tile * n_devices for the
    Pallas backends (each device's shard must hold whole kernel tiles),
    n_devices for jnp.  1 when unsharded — single-device behaviour is
    unchanged."""
    n = n_pair_shards(mesh)
    if n == 1:
        return 1
    from ..core.config import PALLAS_BACKENDS
    tile = cfg.lane_tile if cfg.backend in PALLAS_BACKENDS else 1
    return n * tile


def quantise_lanes(n: int, cfg, mesh) -> int:
    """Round a lane count up to the batch quantum: the smallest multiple of
    `pair_pad_multiple(cfg, mesh)` >= n.  Single source of truth for how
    the serving engine AND the session front door (repro.api) quantise
    ragged batches so no device ever gets an unequal or tile-split shard."""
    q = pair_pad_multiple(cfg, mesh)
    return -(-max(n, 1) // q) * q


def bucket_lanes(n: int, cfg, mesh) -> int:
    """The session's static lane class for an n-request dispatch: the
    smallest quantised power-of-two class >= n (classes are
    ``quantise_lanes(2**j)``), so ragged dispatch sizes collapse onto a
    handful of compiled batch shapes instead of one executable per
    distinct n.  Idempotent — a value that already IS a class maps to
    itself, even when the pair quantum is not a power of two (otherwise a
    planned batch_lanes would inflate again at dispatch time)."""
    p2 = 1
    while quantise_lanes(p2, cfg, mesh) < n:
        p2 *= 2
    return quantise_lanes(p2, cfg, mesh)


def lane_classes(ceiling: int, cfg, mesh) -> tuple:
    """The negotiated lane-class ladder up to (and including)
    ``bucket_lanes(ceiling)``, ascending.  This is the single source of
    truth for which batch shapes adaptive batching (repro.api) may step
    between: every rung is a quantised class (equal, tile-aligned shards
    on a mesh), so shrinking a sparsely-filled bucket can never produce a
    shape the quantisation rules would reject."""
    top = bucket_lanes(max(ceiling, 1), cfg, mesh)
    out = []
    p2 = 1
    while True:
        c = quantise_lanes(p2, cfg, mesh)
        if not out or c > out[-1]:
            out.append(c)
        if c >= top:
            return tuple(out)
        p2 *= 2


def mesh_fingerprint(mesh) -> tuple:
    """Stable identity of a mesh for process-wide compile-cache keys: axis
    names, axis sizes and the flat device ids.  Two mesh objects spanning
    the same devices with the same axes fingerprint equal, so independent
    sessions over equal meshes share executables (repro.api)."""
    if mesh is None:
        return ("nomesh",)
    names = tuple(mesh.axis_names)
    sizes = tuple(int(mesh.shape[a]) for a in names)
    ids = tuple(int(d.id) for d in mesh.devices.flat)
    return (names, sizes, ids)


def _mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if m is None or not m.axis_names:
        return None
    return m


def constrain(x, *dims):
    mesh = _mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))

    def resolve(d, dim_size):
        if d == "batch":
            axes = tuple(a for a in ("pod", "data") if a in names)
        elif d is None:
            return None
        else:
            axes = (d,) if d in names else ()
        if not axes:
            return None
        n = 1
        for a in axes:
            n *= sizes[a]
        if dim_size % n != 0:
            return None
        return axes if len(axes) > 1 else axes[0]

    spec = P(*[resolve(d, s) for d, s in zip(dims, x.shape)])
    return jax.lax.with_sharding_constraint(x, spec)
