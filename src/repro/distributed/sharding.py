"""Logical activation-sharding constraints, mesh-shape agnostic.

Model code calls constrain(x, 'batch', None, 'model') with logical dims;
the helper resolves them against whatever mesh the enclosing jit runs
under ('batch' -> ('pod','data') when a pod axis exists), skips axes that
don't divide, and is a no-op outside a mesh context (CPU unit tests)."""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if m is None or not m.axis_names:
        return None
    return m


def constrain(x, *dims):
    mesh = _mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))

    def resolve(d, dim_size):
        if d == "batch":
            axes = tuple(a for a in ("pod", "data") if a in names)
        elif d is None:
            return None
        else:
            axes = (d,) if d in names else ()
        if not axes:
            return None
        n = 1
        for a in axes:
            n *= sizes[a]
        if dim_size % n != 0:
            return None
        return axes if len(axes) > 1 else axes[0]

    spec = P(*[resolve(d, s) for d, s in zip(dims, x.shape)])
    return jax.lax.with_sharding_constraint(x, spec)
