"""Distributed-optimization tricks: int8-compressed gradient ring
reduce-scatter + all-gather (bandwidth ~4x lower than fp32 all-reduce),
built from shard_map + ppermute.

Quantization: per-chunk absmax scaling to int8; the ring accumulates in
fp32 locally and re-quantizes per hop (error stays bounded by 1/127 per
hop; tests check end-to-end relative error).  Used by the train driver
when --grad-compress is set; the default path relies on GSPMD's implicit
fp32 all-reduce."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _axis_size(axis_name):
    # jax.lax.axis_size is newer-jax; psum(1) constant-folds to the same.
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def _quant(x):
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def ring_reduce_scatter_q8(x, axis_name: str):
    """x: (n_shards * chunk,) fp32 per device -> (chunk,) = fully-reduced
    chunk `me`.  The partial sum for chunk c starts at device (c+1)%n and
    rings to c, each hop quantized to int8 + one fp32 scale."""
    n = _axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    xs = x.reshape(n, -1)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def body(t, acc):
        q, s = _quant(acc)
        q = jax.lax.ppermute(q, axis_name, perm)
        s = jax.lax.ppermute(s, axis_name, perm)
        c = (me - 2 - t) % n          # chunk id of the partial just received
        mine = jax.lax.dynamic_index_in_dim(xs, c, 0, keepdims=False)
        return _dequant(q, s) + mine

    acc0 = jax.lax.dynamic_index_in_dim(xs, (me - 1) % n, 0, keepdims=False)
    return jax.lax.fori_loop(0, n - 1, body, acc0)


def compressed_allreduce(x, axis_name: str):
    """reduce-scatter (int8 ring) + int8 all-gather: psum replacement at
    ~1/4 the wire bytes."""
    n = _axis_size(axis_name)
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    flat = jnp.pad(flat, (0, pad))
    shard = ring_reduce_scatter_q8(flat, axis_name)
    q, s = _quant(shard)
    qg = jax.lax.all_gather(q, axis_name)            # (n, chunk)
    sg = jax.lax.all_gather(s, axis_name)            # (n, 1)
    full = _dequant(qg, sg).reshape(-1)
    return full[:x.size].reshape(x.shape)


def make_compressed_grad_sync(mesh, axis_name="data"):
    """shard_map wrapper syncing a grad pytree across the data axis with
    int8 ring collectives (grads enter replicated per data-shard)."""
    from jax.sharding import PartitionSpec as P

    def sync(grads):
        def inner(g):
            return jax.tree_util.tree_map(
                lambda a: compressed_allreduce(a, axis_name) /
                _axis_size(axis_name), g)
        from ..launch.mesh import shard_map
        return shard_map(inner, mesh=mesh, in_specs=P(), out_specs=P(),
                         check=False)(grads)

    return sync
