"""Parse optimized HLO text for collective traffic (roofline §collective).

cost_analysis() does not expose collective bytes, so we scan the compiled
module for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops and sum their tensor sizes (shapes in partitioned
HLO are per-device).  Wire-byte convention (documented in EXPERIMENTS.md):
all-reduce counts 2x (reduce-scatter + all-gather phases); others 1x; the
(n-1)/n ring factor is folded to 1.  Ops inside `while` bodies appear once —
the dry-run's two-point depth extrapolation recovers trip counts.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Returns {'total_wire_bytes', 'by_op': {op: bytes}, 'counts': {op: n}}."""
    by_op = defaultdict(int)
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        result_seg, op, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue  # counted at -start
        size = _shape_bytes(result_seg)
        wire = 2 * size if op == "all-reduce" else size
        by_op[op] += wire
        counts[op] += 1
    return {"total_wire_bytes": int(sum(by_op.values())),
            "by_op": dict(by_op), "counts": dict(counts)}
