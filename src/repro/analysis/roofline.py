"""Roofline term calculator (TPU v5e constants from the assignment)."""
from __future__ import annotations

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

# model-FLOPs conventions: 6·N·D train, 2·N·D inference (per generated token)
TRAIN_FACTOR, INFER_FACTOR = 6, 2


def roofline_terms(flops_global: float, bytes_global: float,
                   coll_bytes_per_dev: float, chips: int) -> dict:
    compute_t = flops_global / (chips * PEAK_FLOPS)
    memory_t = bytes_global / (chips * HBM_BW)
    coll_t = coll_bytes_per_dev / ICI_BW   # HLO shapes are already per-device
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom.replace("_s", "")
    terms["bound_s"] = terms[dom]
    return terms


def model_flops(n_active_params: float, tokens: float, train: bool) -> float:
    return (TRAIN_FACTOR if train else INFER_FACTOR) * n_active_params * tokens


def useful_fraction(model_fl: float, hlo_flops_global: float) -> float:
    return model_fl / hlo_flops_global if hlo_flops_global else 0.0


def count_params(params_tree) -> int:
    import jax
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params_tree))
