"""Checkpointing: atomic, manifest-driven, async-capable, elastic-restore.

Arrays are saved logically (full value) with their tree paths; restore
re-places them under *any* mesh via device_put with the target shardings —
this is what makes elastic rescale (N pods -> M pods) work.  On a real
multi-host pod each process would save its addressable shards
(process_index-suffixed files); the single-host container exercises the
same code path with one shard file.
"""
from __future__ import annotations

import json
import os
import pathlib
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def save_checkpoint(ckpt_dir, state, step: int, *, keep: int = 3,
                    async_save: bool = False):
    """Atomic: write to tmp dir, fsync, rename.  Returns the ckpt path (or
    the in-flight thread when async_save)."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = _flatten(state)
    # snapshot to host memory synchronously (cheap), write async if asked
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

    def _write():
        tmp = ckpt_dir / f".tmp_step_{step}"
        tmp.mkdir(exist_ok=True)
        np.savez(tmp / "shard_0.npz", **host)
        (tmp / "manifest.json").write_text(json.dumps(
            {"step": step, "keys": sorted(host), "n_shards": 1,
             "time": time.time()}))
        final = ckpt_dir / f"step_{step:08d}"
        if final.exists():
            import shutil; shutil.rmtree(final)
        os.replace(tmp, final)
        _gc(ckpt_dir, keep)

    if async_save:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return ckpt_dir / f"step_{step:08d}"


def _gc(ckpt_dir: pathlib.Path, keep: int):
    ckpts = sorted(p for p in ckpt_dir.iterdir() if p.name.startswith("step_"))
    for p in ckpts[:-keep]:
        import shutil; shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.iterdir()
                   if p.name.startswith("step_"))
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir, abstract_state, *, step: int | None = None,
                       shardings=None):
    """Restore into the structure of `abstract_state`; if `shardings` is
    given (possibly for a *different* mesh than the one saved under), arrays
    are re-placed accordingly — elastic restore."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    data = np.load(d / "shard_0.npz")
    flat_keys = _flatten(abstract_state)
    leaves, treedef = jax.tree_util.tree_flatten(abstract_state)
    keys_in_order = list(_flatten(abstract_state).keys())
    arrays = []
    sh_flat = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(keys_in_order))
    for key, sh in zip(keys_in_order, sh_flat):
        a = data[key]
        arrays.append(jax.device_put(a, sh) if sh is not None else a)
    return jax.tree_util.tree_unflatten(treedef, arrays), step
