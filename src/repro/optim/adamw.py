"""AdamW in pure JAX with fp32 optimizer state sharded like the params
(ZeRO-style: the param PartitionSpecs already split every matrix over both
the 'data' and 'model' axes, so m/v inherit full 2-axis sharding)."""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0., 1.)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    sq = jax.tree_util.tree_map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq, jnp.float32(0)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        p32 = p.astype(jnp.float32)
        new = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p32)
        return new.astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = jax.tree_util.tree_flatten(opt_state["m"])[0]
    flat_v = jax.tree_util.tree_flatten(opt_state["v"])[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gn, "lr": lr}
