"""Shared layers: norms, rotary embeddings (incl. M-RoPE), activations,
parameter-spec helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x, w, eps=1e-6):
    v = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (x.astype(jnp.float32) * jax.lax.rsqrt(v + eps)) * \
        (1.0 + w.astype(jnp.float32))
    return y.astype(x.dtype)


def cast_tree(params, dtype):
    """Cast float params to the compute dtype (mixed-precision forward)."""
    return jax.tree_util.tree_map(
        lambda a: a.astype(dtype)
        if hasattr(a, "dtype") and a.dtype in (jnp.float32, jnp.bfloat16)
        else a, params)


def act_fn(name):
    return {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True)}[name]


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float, sections=()):
    """x: (..., S, H, Dh); positions: (B, S) or (3, B, S) for M-RoPE.

    M-RoPE (Qwen2-VL): the Dh/2 rotary frequency slots are partitioned into
    `sections` (t, h, w) groups, each rotated by its own position stream.
    """
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta), jnp.float32)      # (Dh/2,)
    if positions.ndim == 3 and sections:
        secs = list(sections)
        assert sum(secs) == dh // 2
        parts = []
        start = 0
        for i, s in enumerate(secs):
            parts.append(positions[i][..., None] * freqs[start:start + s])
            start += s
        ang = jnp.concatenate(parts, axis=-1)                    # (B, S, Dh/2)
    else:
        if positions.ndim == 3:
            positions = positions[0]
        ang = positions[..., None] * freqs                       # (B, S, Dh/2)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)             # (B,S,1,Dh/2)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------- params ----

class ParamSpec:
    """Declarative parameter: shape, logical sharding, init scale."""

    def __init__(self, shape, spec, init="normal", scale=None):
        self.shape = tuple(int(s) for s in shape)
        self.spec = spec          # tuple of mesh-axis names or None per dim
        self.init = init          # 'normal' | 'zeros' | 'ones'
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        self.scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)


def init_param(rng, ps: ParamSpec, dtype):
    if ps.init == "zeros":
        return jnp.zeros(ps.shape, dtype)
    if ps.init == "ones":
        return jnp.ones(ps.shape, dtype)
    return (jax.random.normal(rng, ps.shape, jnp.float32) * ps.scale).astype(dtype)


def init_tree(rng, specs, dtype=jnp.float32):
    flat, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(rng, len(flat))
    vals = [init_param(k, ps, dtype) for k, ps in zip(keys, flat)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_tree(specs, dtype=jnp.float32):
    return jax.tree_util.tree_map(
        lambda ps: jax.ShapeDtypeStruct(ps.shape, dtype), specs,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def spec_tree(specs):
    """PartitionSpec pytree matching the param tree."""
    from jax.sharding import PartitionSpec as P
    return jax.tree_util.tree_map(
        lambda ps: P(*ps.spec), specs, is_leaf=lambda x: isinstance(x, ParamSpec))
