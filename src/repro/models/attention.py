"""Attention: GQA with RoPE/M-RoPE, query-chunked (bounded memory at 32k
prefill), sliding-window/global via a traced window size (so gemma2's
local/global alternation works under scan-over-layers without doubling
FLOPs), logit softcapping, and a decode path over a KV cache that may be
sequence-sharded across the 'model' mesh axis."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import apply_rope, softcap

NEG = -1e30
NO_WINDOW = 1 << 30


def attention(q, k, v, q_pos, k_pos, *, window, cap: float, scale: float,
              q_chunk: int = 1024):
    """q: (B, Sq, H, Dh); k/v: (B, Sk, KV, Dh); q_pos (Sq,), k_pos (Sk,).
    `window` may be a traced int32 scalar (NO_WINDOW disables it).
    Query-chunked exact softmax: peak memory O(q_chunk * Sk) per head."""
    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, Dh)
    window = jnp.asarray(window, jnp.int32)

    def chunk_fn(qc, qpos_c):
        s = jnp.einsum("bqkgd,bskd->bkgqs", qc, k) * scale
        s = softcap(s, cap)
        keep = (k_pos[None, :] <= qpos_c[:, None]) & \
               (k_pos[None, :] > qpos_c[:, None] - window)
        s = jnp.where(keep[None, None, None], s, NEG)
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
        return jnp.einsum("bkgqs,bskd->bqkgd", p, v)

    if Sq <= q_chunk:
        out = chunk_fn(qg, q_pos)
    else:
        n_chunks = -(-Sq // q_chunk)
        pad = n_chunks * q_chunk - Sq
        qg_p = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        qp_p = jnp.pad(q_pos, ((0, pad),))
        qg_c = qg_p.reshape(B, n_chunks, q_chunk, KV, G, Dh).swapaxes(0, 1)
        qp_c = qp_p.reshape(n_chunks, q_chunk)
        out = jax.lax.map(lambda a: chunk_fn(*a), (qg_c, qp_c))
        out = out.swapaxes(0, 1).reshape(B, n_chunks * q_chunk, KV, G, Dh)
        out = out[:, :Sq]
    return out.reshape(B, Sq, H, Dh)


def _window_for_layer(cfg, layer_is_global):
    """Effective sliding window as a traced scalar."""
    if cfg.local_global_every:
        return jnp.where(layer_is_global, NO_WINDOW,
                         cfg.sliding_window or NO_WINDOW)
    return jnp.int32(cfg.sliding_window or NO_WINDOW)


def attn_block(p, x, positions, pos_1d, cfg, layer_is_global=0,
               cache=None, cache_pos=None):
    """positions: (B,S) or (3,B,S) rotary positions; pos_1d: (S,) int32 mask
    positions (shared across batch).  cache: dict(k,v) of (B, Sc, KV, Dh) for
    decode (appends at cache_pos).  Returns (out, cache_out)."""
    B, S, D = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, H, Dh)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(B, S, KV, Dh)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(B, S, KV, Dh)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(H, Dh)
        k = k + p["bk"].reshape(KV, Dh)
        v = v + p["bv"].reshape(KV, Dh)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    scale = cfg.attention_multiplier or (1.0 / (Dh ** 0.5))
    window = _window_for_layer(cfg, layer_is_global)

    if cache is None:
        out = attention(q, k, v, pos_1d, pos_1d, window=window,
                        cap=cfg.attn_softcap, scale=scale)
        cache_out = {"k": k, "v": v}
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, cache_pos, 0, 0))
        Sc = ck.shape[1]
        k_pos = jnp.arange(Sc, dtype=jnp.int32)
        q_pos = cache_pos + jnp.arange(S, dtype=jnp.int32)
        out = attention(q, ck, cv, q_pos, k_pos, window=window,
                        cap=cfg.attn_softcap, scale=scale)
        cache_out = {"k": ck, "v": cv}

    y = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * Dh), p["wo"])
    return y, cache_out
