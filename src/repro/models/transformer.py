"""Unified decoder LM covering the dense / MoE / VLM / audio assigned
architectures (llama3.2, granite3, gemma2, qwen2.5, qwen3-moe, olmoe,
qwen2-vl, musicgen), plus the zamba2 hybrid and xlstm classes.

Layers run under lax.scan with stacked parameters (compile time ~O(1) in
depth) and optional remat; gemma2's local/global alternation rides through
the scan as a per-layer 0/1 input; zamba2's *shared* attention block keeps a
single (unstacked) parameter set applied every `shared_attn_every` layers.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .attention import attn_block
from .common import ParamSpec as PS
from .common import (abstract_tree, act_fn, cast_tree, init_tree, rms_norm,
                     softcap, spec_tree)
from .config import ModelConfig
from ..distributed.sharding import constrain
from .mamba2 import mamba2_block
from .moe import moe_ffn
from .xlstm import mlstm_block, slstm_block

DATA = ("pod", "data")  # batch shards over both pod and data axes


def _attn_specs(cfg, L):
    D, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = {
        "wq": PS((L, D, H * Dh), (None, "data", "model")),
        "wk": PS((L, D, KV * Dh), (None, "data", "model")),
        "wv": PS((L, D, KV * Dh), (None, "data", "model")),
        "wo": PS((L, H * Dh, D), (None, "model", "data")),
    }
    if cfg.qkv_bias:
        s["bq"] = PS((L, H * Dh), (None, "model"), init="zeros")
        s["bk"] = PS((L, KV * Dh), (None, "model"), init="zeros")
        s["bv"] = PS((L, KV * Dh), (None, "model"), init="zeros")
    return s


def _mlp_specs(cfg, L):
    D, F = cfg.d_model, cfg.d_ff
    return {
        "wg": PS((L, D, F), (None, "data", "model")),
        "wu": PS((L, D, F), (None, "data", "model")),
        "wd": PS((L, F, D), (None, "model", "data")),
    }


def _moe_specs(cfg, L):
    D, Fe, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": PS((L, D, E), (None, None, None)),
        "wg": PS((L, E, D, Fe), (None, "model", "data", None)),
        "wu": PS((L, E, D, Fe), (None, "model", "data", None)),
        "wd": PS((L, E, Fe, D), (None, "model", None, "data")),
    }


def mlp_ffn(p, x, cfg):
    act = act_fn(cfg.act)
    h = act(jnp.einsum("bsd,df->bsf", x, p["wg"])) * \
        jnp.einsum("bsd,df->bsf", x, p["wu"])
    return jnp.einsum("bsf,fd->bsd", h, p["wd"])


class TransformerLM:
    """Dense / MoE / VLM / audio decoder."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------ params --
    def param_specs(self):
        cfg = self.cfg
        L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab_padded
        layers = {"ln1": PS((L, D), (None, None), init="zeros"),
                  "ln2": PS((L, D), (None, None), init="zeros"),
                  "attn": _attn_specs(cfg, L)}
        if cfg.post_block_norm:
            layers["ln1b"] = PS((L, D), (None, None), init="zeros")
            layers["ln2b"] = PS((L, D), (None, None), init="zeros")
        layers["moe" if cfg.n_experts else "mlp"] = (
            _moe_specs(cfg, L) if cfg.n_experts else _mlp_specs(cfg, L))
        tree = {"embed": PS((V, D), ("model", "data"), scale=0.02),
                "layers": layers,
                "final_norm": PS((D,), (None,), init="zeros")}
        if cfg.n_codebooks:
            tree["head"] = PS((cfg.n_codebooks, D, V), (None, "data", "model"))
        elif not cfg.tie_embeddings:
            tree["head"] = PS((D, V), ("data", "model"))
        return tree

    def init(self, rng, dtype=jnp.float32):
        return init_tree(rng, self.param_specs(), dtype)

    def abstract_params(self, dtype=jnp.float32):
        return abstract_tree(self.param_specs(), dtype)

    def partition_specs(self):
        return spec_tree(self.param_specs())

    # ----------------------------------------------------------- forward --
    def _is_global(self):
        cfg = self.cfg
        if cfg.local_global_every:
            return (np.arange(cfg.n_layers) % 2 == 1).astype(np.int32)
        return np.zeros(cfg.n_layers, np.int32)

    def _embed(self, params, batch):
        cfg = self.cfg
        if "embeds" in batch:                       # stub modality frontends
            x = batch["embeds"]
        else:
            x = jnp.take(params["embed"], batch["tokens"], axis=0)
        x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
        if cfg.scale_embed:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        return constrain(x * cfg.embedding_multiplier, "batch", None, None)

    def _positions(self, batch, S, cache_pos=None):
        if "positions" in batch:
            return batch["positions"]
        if cache_pos is not None:
            return cache_pos + jnp.arange(S, dtype=jnp.int32)[None, :]
        return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                (batch_dim(batch), S))

    def _block(self, p, x, positions, pos_1d, is_global, cfg, cache, cache_pos):
        h = rms_norm(x, p["ln1"], cfg.rms_eps)
        a, cache_out = attn_block(p["attn"], h, positions, pos_1d, cfg,
                                  is_global, cache, cache_pos)
        if cfg.post_block_norm:
            a = rms_norm(a, p["ln1b"], cfg.rms_eps)
        x = x + a * cfg.residual_multiplier
        h = rms_norm(x, p["ln2"], cfg.rms_eps)
        aux = jnp.float32(0)
        if cfg.n_experts:
            f, aux = moe_ffn(p["moe"], h, cfg)
        else:
            f = mlp_ffn(p["mlp"], h, cfg)
        if cfg.post_block_norm:
            f = rms_norm(f, p["ln2b"], cfg.rms_eps)
        x = x + f * cfg.residual_multiplier
        return constrain(x, "batch", None, None), aux, cache_out

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.cfg.dtype == "bfloat16" else jnp.float32

    def forward(self, params, batch, mode="train", cache=None):
        """mode: train | prefill | decode.  Returns (logits, aux, new_cache)."""
        cfg = self.cfg
        params = cast_tree(params, self.compute_dtype)
        x = self._embed(params, batch)
        B, S, D = x.shape
        cache_pos = batch.get("cache_pos") if mode == "decode" else None
        positions = self._positions(batch, S, cache_pos)
        pos_1d = (positions[0] if positions.ndim == 2 else positions[0, 0])
        if positions.ndim == 2 and positions.shape[0] != 1:
            pos_1d = positions[0]
        is_global = jnp.asarray(self._is_global())

        lp = params["layers"]

        def body(carry, xs):
            x, aux = carry
            if mode == "decode":
                p, ig, layer_cache = xs
            else:
                p, ig = xs
                layer_cache = None
            x, aux_l, cache_out = self._block(
                p, x, positions, pos_1d, ig, cfg,
                layer_cache, cache_pos)
            ys = cache_out if mode != "train" else None
            return (x, aux + aux_l), ys

        if cfg.remat and mode == "train":
            body = jax.checkpoint(body)

        if mode == "decode":
            xs = (lp, is_global, cache["kv"])
        else:
            xs = (lp, is_global)
        if cfg.scan_layers:
            (x, aux), caches = jax.lax.scan(body, (x, jnp.float32(0)), xs)
        else:  # unrolled (per-layer costs visible to cost_analysis)
            carry, ys = (x, jnp.float32(0)), []
            for i in range(cfg.n_layers):
                xi = jax.tree_util.tree_map(lambda a: a[i], xs)
                carry, y = body(carry, xi)
                ys.append(y)
            (x, aux) = carry
            caches = (jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
                      if mode != "train" else None)

        x = rms_norm(x, params["final_norm"], cfg.rms_eps)
        if cfg.n_codebooks:
            logits = jnp.einsum("bsd,cdv->bscv", x, params["head"])
        elif cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
        else:
            logits = jnp.einsum("bsd,dv->bsv", x, params["head"])
        logits = softcap(logits / cfg.logits_scaling, cfg.final_softcap)
        logits = constrain(logits, "batch", *([None] * (logits.ndim - 3)),
                           None, "model")
        new_cache = None
        if mode in ("prefill", "decode"):
            new_cache = {"kv": caches}
        return logits, aux, new_cache

    # ------------------------------------------------------------- steps --
    def loss(self, params, batch):
        cfg = self.cfg
        logits, aux, _ = self.forward(params, batch, mode="train")
        labels = batch["labels"]
        lg = logits.astype(jnp.float32)
        if cfg.vocab_padded != cfg.vocab:
            pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
            lg = jnp.where(pad_mask, -1e30, lg)
        # one-hot cross-entropy: reductions over the vocab-sharded axis stay
        # sharded (take_along_axis would force an all-gather of the logits)
        lse = jax.scipy.special.logsumexp(lg, axis=-1)
        onehot = jax.nn.one_hot(labels, cfg.vocab_padded, dtype=lg.dtype)
        true_logit = jnp.einsum("...v,...v->...", lg, onehot)
        ce = lse - true_logit
        loss = jnp.mean(ce)
        return loss + cfg.router_aux_coef * aux / cfg.n_layers, {"ce": loss}

    def prefill(self, params, batch):
        logits, _, cache = self.forward(params, batch, mode="prefill")
        return logits[:, -1:], cache

    def decode_step(self, params, batch, cache):
        """batch: tokens (B,1) (or embeds), cache_pos scalar int32."""
        logits, _, cache = self.forward(params, batch, mode="decode",
                                        cache=cache)
        return logits, cache

    def init_cache(self, batch_size: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        L, KV, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        kv = {"k": jnp.zeros((L, batch_size, max_len, KV, Dh), dtype),
              "v": jnp.zeros((L, batch_size, max_len, KV, Dh), dtype)}
        return {"kv": kv}

    def abstract_cache(self, batch_size: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        L, KV, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        sds = jax.ShapeDtypeStruct
        kv = {"k": sds((L, batch_size, max_len, KV, Dh), dtype),
              "v": sds((L, batch_size, max_len, KV, Dh), dtype)}
        return {"kv": kv}


def batch_dim(batch):
    for k in ("tokens", "embeds"):
        if k in batch:
            return batch[k].shape[0]
    raise KeyError("batch has neither tokens nor embeds")
