"""Mixture-of-Experts FFN: top-k router + sort-based capacity dispatch.

Sharding-aware formulation (§Perf iteration 2 in EXPERIMENTS.md): routing,
sorting and gathers are computed *per batch row*, so with the batch sharded
over ('pod','data') every dispatch step is local to its data shard — no
global (tokens, d_model) scatter buffer (the naive global-flatten version
made GSPMD replicate a ~8.6 GB combine buffer per device and all-reduce
it).  Expert tiles (B, E, C, D) then shard E over 'model' (expert
parallelism); the combine is a per-expert-shard partial scatter that GSPMD
finishes with one activation-sized all-reduce over 'model'.

Capacity C = S*top_k/E * capacity_factor per row; overflowing assignments
drop (GShard-style), underfull slots point at token 0 with weight 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .common import act_fn


def router_topk(x, w_router, cfg):
    """x: (B, S, D) -> (weights (B,S,K), experts (B,S,K), aux scalar)."""
    logits = jnp.einsum("bsd,de->bse", x, w_router).astype(jnp.float32)
    logits = constrain(logits, "batch", None, None)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.norm_topk_prob:
        w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-9)
    E = cfg.n_experts
    me = jnp.mean(probs, axis=(0, 1))
    fe = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = E * jnp.sum(me * fe)
    return w.astype(x.dtype), idx.astype(jnp.int32), aux


def moe_ffn(p, x, cfg):
    """p: router (D,E), wg/wu (E, D, Fe), wd (E, Fe, D).  x: (B, S, D).
    Returns (y, aux_loss)."""
    B, S, D = x.shape
    K, E = cfg.top_k, cfg.n_experts
    C = int(S * K / E * cfg.capacity_factor) + 1
    w, idx, aux = router_topk(x, p["router"], cfg)

    # ---- per-row sort-based dispatch (local to the data shard) ----
    eid = idx.reshape(B, S * K)                          # (B, S*K)
    tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(S, dtype=jnp.int32), K), (B, S * K))
    wgt = w.reshape(B, S * K)
    order = jnp.argsort(eid, axis=-1)
    eid_s = jnp.take_along_axis(eid, order, axis=-1)
    tok_s = jnp.take_along_axis(tok, order, axis=-1)
    wgt_s = jnp.take_along_axis(wgt, order, axis=-1)
    starts = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(E, dtype=jnp.int32)))(
        eid_s)                                           # (B, E)
    rank = jnp.arange(S * K, dtype=jnp.int32)[None, :] - \
        jnp.take_along_axis(starts, eid_s, axis=-1)
    keep = rank < C
    slot = jnp.where(keep, eid_s * C + rank, E * C)      # OOB -> dropped

    b_idx = jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32)[:, None],
                             (B, S * K))
    tok_for = jnp.zeros((B, E * C), jnp.int32) \
        .at[b_idx, slot].set(tok_s, mode="drop")
    wgt_for = jnp.zeros((B, E * C), x.dtype) \
        .at[b_idx, slot].set(wgt_s, mode="drop")

    # ---- gather tokens into (B, E, C, D) expert tiles, E over 'model' ----
    xe = jax.vmap(lambda xr, tf: xr[tf])(x, tok_for)         # (B, E*C, D)
    xe = constrain(xe.reshape(B, E, C, D), "batch", "model", None, None)
    act = act_fn(cfg.act)
    h = act(jnp.einsum("becd,edf->becf", xe, p["wg"])) * \
        jnp.einsum("becd,edf->becf", xe, p["wu"])
    ye = jnp.einsum("becf,efd->becd", h, p["wd"])
    ye = constrain(ye, "batch", "model", None, None)
    ye = ye.reshape(B, E * C, D) * wgt_for[..., None]

    # ---- combine: per-expert-shard partial scatter + AR over 'model' ----
    # vmapped per-row scatter-add: explicit (B, E*C, 2) scatter indices hide
    # the batch alignment from GSPMD and force replication (§Perf iter 2b)
    y = jax.vmap(lambda tf, yr: jnp.zeros((S, D), x.dtype).at[tf].add(yr))(
        tok_for, ye)
    y = constrain(y, "batch", None, None)
    return y, aux.astype(jnp.float32)
