"""xLSTM LM: mLSTM blocks with an sLSTM block every `slstm_every` layers
(xLSTM[7:1]-style).  Heterogeneous blocks -> layers are unrolled (depth 12
for the assigned config; compile time is fine without scan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamSpec as PS
from .common import rms_norm
from .config import ModelConfig
from .transformer import TransformerLM
from ..distributed.sharding import constrain
from .xlstm import mlstm_block, slstm_block


class XLSTMLM(TransformerLM):
    def _kinds(self):
        cfg = self.cfg
        e = cfg.slstm_every
        return ["slstm" if (e and (i % e) == e - 1) else "mlstm"
                for i in range(cfg.n_layers)]

    def param_specs(self):
        cfg = self.cfg
        D, V = cfg.d_model, cfg.vocab_padded
        Di = 2 * D
        H = cfg.n_heads
        Dh_s = D // H
        layers = []
        for kind in self._kinds():
            ln = {"ln": PS((D,), (None,), init="zeros")}
            if kind == "mlstm":
                layers.append({**ln,
                    "w_up": PS((D, 2 * Di), ("data", "model")),
                    "conv_w": PS((4, Di), (None, "model"), scale=0.5),
                    "wq": PS((Di, Di), ("data", "model")),
                    "wk": PS((Di, Di), ("data", "model")),
                    "wv": PS((Di, Di), ("data", "model")),
                    "w_i": PS((Di, H), ("model", None)),
                    "w_f": PS((Di, H), ("model", None)),
                    "gn": PS((Di,), (None,), init="zeros"),
                    "w_down": PS((Di, D), ("model", "data")),
                })
            else:
                layers.append({**ln,
                    "w_gates": PS((D, 4 * D), ("data", "model")),
                    "r_gates": PS((H, Dh_s, 4 * Dh_s), (None, None, None)),
                    "gn": PS((D,), (None,), init="zeros"),
                    "w_down": PS((D, D), ("data", "model")),
                })
        tree = {"embed": PS((V, D), ("model", "data"), scale=0.02),
                "layers": tuple(layers),
                "final_norm": PS((D,), (None,), init="zeros"),
                "head": PS((D, V), ("data", "model"))}
        return tree

    def forward(self, params, batch, mode="train", cache=None):
        cfg = self.cfg
        from .common import cast_tree
        params = cast_tree(params, self.compute_dtype)
        x = self._embed(params, batch)
        kinds = self._kinds()
        new_states = []
        for i, (kind, p) in enumerate(zip(kinds, params["layers"])):
            st = cache["states"][i] if mode == "decode" else None
            h = rms_norm(x, p["ln"], cfg.rms_eps)
            fn = mlstm_block if kind == "mlstm" else slstm_block
            if cfg.remat and mode == "train":
                blk = jax.checkpoint(
                    lambda p_, h_, fn=fn: fn(p_, h_, cfg, None))
                out, st_new = blk(p, h)
            else:
                out, st_new = fn(p, h, cfg, st)
            x = constrain(x + out, "batch", None, None)
            new_states.append(st_new)
        x = rms_norm(x, params["final_norm"], cfg.rms_eps)
        logits = constrain(jnp.einsum("bsd,dv->bsv", x, params["head"]),
                           "batch", None, "model")
        new_cache = None
        if mode in ("prefill", "decode"):
            new_cache = {"states": tuple(new_states)}
        return logits, jnp.float32(0), new_cache

    def abstract_cache(self, batch_size: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        D = cfg.d_model
        Di, H = 2 * D, cfg.n_heads
        Dh, Dh_s = Di // H, D // H
        sds = jax.ShapeDtypeStruct
        states = []
        for kind in self._kinds():
            if kind == "mlstm":
                states.append((sds((batch_size, H, Dh, Dh + 1), dtype),
                               sds((batch_size, 3, Di), dtype)))
            else:
                f32 = jnp.float32
                states.append((sds((batch_size, H, Dh_s), f32),
                               sds((batch_size, H, Dh_s), f32),
                               sds((batch_size, H, Dh_s), f32),
                               sds((batch_size, H, Dh_s), dtype)))
        return {"states": tuple(states)}

    def init_cache(self, batch_size: int, max_len: int, dtype=jnp.bfloat16):
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.abstract_cache(batch_size, max_len, dtype))
