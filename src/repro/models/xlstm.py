"""xLSTM blocks: mLSTM (matrix memory, exponential gating) and sLSTM
(scalar memory, recurrent only).

mLSTM is computed as chunked gated linear attention: the normalizer state
n_t = f n_{t-1} + i k_t is carried exactly by appending a constant-one
channel to the value stream (so chunked == recurrent, asserted in tests);
stabilization is chunk-local in fp32 with input gates clipped (DESIGN.md
notes this simplification of the paper's running-max m_t).  sLSTM has no
parallel form and scans over time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import rms_norm
from .mamba2 import ssd_chunked


def mlstm_mixer(q, k, v, i_gate, f_gate, chunk: int = 256, state=None):
    """q,k,v: (B, L, H, Dh); i_gate/f_gate: (B, L, H) raw (pre-activation).
    Returns (h (B,L,H,Dh), final_state (B,H,Dh,Dh+1))."""
    B, L, H, Dh = q.shape
    a_log = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))        # log f_t
    ig = jnp.clip(i_gate.astype(jnp.float32), -10.0, 10.0)
    # fold exp input gate into k (chunk-local stabilization happens in fp32
    # through the ssd decay path); append ones channel to v for normalizer n
    k_eff = k * jnp.exp(ig)[..., None].astype(k.dtype)
    v_ext = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    # per-head B/C streams -> run ssd per head by folding H into batch
    scale = 1.0 / (Dh ** 0.5)
    xh = v_ext.transpose(0, 2, 1, 3).reshape(B * H, L, 1, Dh + 1)
    al = a_log.transpose(0, 2, 1).reshape(B * H, L, 1)
    Bm = k_eff.transpose(0, 2, 1, 3).reshape(B * H, L, Dh)
    Cm = (q * scale).transpose(0, 2, 1, 3).reshape(B * H, L, Dh)
    Lp = -(-L // chunk) * chunk
    if Lp != L:
        xh = jnp.pad(xh, ((0, 0), (0, Lp - L), (0, 0), (0, 0)))
        al = jnp.pad(al, ((0, 0), (0, Lp - L), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, Lp - L), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, Lp - L), (0, 0)))
    h0 = None
    if state is not None:
        h0 = state.reshape(B * H, 1, Dh, Dh + 1)
    y, hf = ssd_chunked(xh, al, Bm, Cm, min(chunk, Lp), h0=h0)
    y = y[:, :L, 0].reshape(B, H, L, Dh + 1).transpose(0, 2, 1, 3)
    num, den = y[..., :Dh], y[..., Dh:]
    h = num / jnp.maximum(jnp.abs(den), 1.0)
    return h, hf.reshape(B, H, Dh, Dh + 1)


def mlstm_block(p, x, cfg, state=None, chunk: int = 256):
    """p: ln, w_up (D, 2*Di), conv_w, wq/wk/wv (Di, Di), w_i/w_f (Di, H),
    gn, w_down (Di, D).  Di = 2*D, H = n_heads.
    state: (mixer_state (B,H,Dh,Dh+1), conv_state (B, 3, Di)) for decode."""
    B, L, D = x.shape
    Di = 2 * D
    H = cfg.n_heads
    Dh = Di // H
    u = jnp.einsum("bld,de->ble", x, p["w_up"])
    xu, zg = jnp.split(u, 2, axis=-1)                     # (B,L,Di) each
    dconv = 4
    mixer_state = conv_state = None
    if state is not None:
        mixer_state, conv_state = state
    if conv_state is None:
        hist = jnp.pad(xu, ((0, 0), (dconv - 1, 0), (0, 0)))
    else:
        hist = jnp.concatenate([conv_state, xu], axis=1)
    conv = sum(hist[:, i:i + L] * p["conv_w"][i] for i in range(dconv))
    conv = jax.nn.silu(conv)
    new_conv_state = hist[:, L:L + dconv - 1]
    q = jnp.einsum("ble,ef->blf", conv, p["wq"]).reshape(B, L, H, Dh)
    k = jnp.einsum("ble,ef->blf", conv, p["wk"]).reshape(B, L, H, Dh)
    v = jnp.einsum("ble,ef->blf", xu, p["wv"]).reshape(B, L, H, Dh)
    ig = jnp.einsum("ble,eh->blh", conv, p["w_i"])
    fg = jnp.einsum("ble,eh->blh", conv, p["w_f"]) + 3.0  # forget bias
    h, st = mlstm_mixer(q, k, v, ig, fg, chunk=chunk, state=mixer_state)
    h = rms_norm(h.reshape(B, L, Di), p["gn"], cfg.rms_eps)
    out = jnp.einsum("ble,ed->bld", h * jax.nn.silu(zg), p["w_down"])
    return out, (st, new_conv_state)


def slstm_block(p, x, cfg, state=None):
    """sLSTM: scalar-memory recurrent cell with exponential gating, H heads.
    p: w_gates (D, 4*D) (i,f,z,o pre-activations), r_gates (H, Dh, 4*Dh)
    recurrent, gn (D,), w_down (D, D).  state: (c, n, m, h_prev)."""
    B, L, D = x.shape
    H = cfg.n_heads
    Dh = D // H
    pre = jnp.einsum("bld,de->ble", x, p["w_gates"]).reshape(B, L, H, 4 * Dh)

    def step(carry, pre_t):
        c, n, m, h_prev = carry                            # (B,H,Dh) each
        rec = jnp.einsum("bhd,hde->bhe", h_prev, p["r_gates"])
        it, ft, zt, ot = jnp.split(pre_t + rec, 4, axis=-1)
        it = it.astype(jnp.float32); ft = ft.astype(jnp.float32)
        log_f = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(log_f + m, jnp.clip(it, -10., 10.))
        i_s = jnp.exp(jnp.clip(it, -10., 10.) - m_new)
        f_s = jnp.exp(log_f + m - m_new)
        c_new = f_s * c + i_s * jnp.tanh(zt.astype(jnp.float32))
        n_new = f_s * n + i_s
        h_t = jax.nn.sigmoid(ot.astype(jnp.float32)) * c_new / \
            jnp.maximum(jnp.abs(n_new), 1.0)
        h_t = h_t.astype(x.dtype)
        return (c_new, n_new, m_new, h_t), h_t

    if state is None:
        z = jnp.zeros((B, H, Dh), jnp.float32)
        state = (z, z, z, jnp.zeros((B, H, Dh), x.dtype))
    state, hs = jax.lax.scan(step, state, pre.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).reshape(B, L, D)
    h = rms_norm(h, p["gn"], cfg.rms_eps)
    out = jnp.einsum("bld,de->ble", h, p["w_down"])
    return out, state
