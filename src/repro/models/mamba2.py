"""Mamba-2 (SSD) block: chunked-parallel for training/prefill, recurrent for
decode — the sequence mixer of the zamba2 hybrid architecture.

Scalar-identity A per head (the SSD restriction).  The chunked algorithm is
the standard 4-part decomposition: intra-chunk (masked quadratic), chunk
states, inter-chunk recurrence (scan over chunks), state readout.
Equivalence with the naive per-step recurrence is asserted in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import act_fn, rms_norm


def ssd_chunked(xh, a_log, Bm, Cm, chunk: int, h0=None):
    """xh: (B, L, H, P) inputs (already dt-scaled); a_log: (B, L, H) log decay
    per step (<= 0); Bm/Cm: (B, L, N) shared across heads (n_groups = 1).
    Returns (y (B,L,H,P), final_state (B,H,N,P))."""
    Bsz, L, H, P = xh.shape
    N = Bm.shape[-1]
    nc = L // chunk
    assert nc * chunk == L
    xc = xh.reshape(Bsz, nc, chunk, H, P)
    ac = a_log.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)

    la = jnp.cumsum(ac, axis=2)                          # (B,nc,Q,H)
    # intra-chunk: scores_iq,jk = C_i.B_j * exp(la_i - la_j), j <= i
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)           # (B,nc,Q,Q)
    dec = la[:, :, :, None, :] - la[:, :, None, :, :]    # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    dec = jnp.where(mask[None, None, :, :, None], dec, -jnp.inf)
    att = cb[..., None] * jnp.exp(dec)                   # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att.astype(xh.dtype), xc)

    # chunk states: S_c = sum_j exp(la_end - la_j) B_j (x) x_j
    dec_end = jnp.exp(la[:, :, -1:, :] - la)             # (B,nc,Q,H)
    Sc = jnp.einsum("bcjn,bcjh,bcjhp->bchnp",
                    Bc, dec_end.astype(xh.dtype), xc)    # (B,nc,H,N,P)

    # inter-chunk scan
    a_tot = jnp.exp(la[:, :, -1, :]).astype(xh.dtype)    # (B,nc,H)
    def scan_fn(h, inp):
        s, at = inp                                       # (B,H,N,P), (B,H)
        h_new = h * at[..., None, None] + s
        return h_new, h
    init = h0 if h0 is not None else jnp.zeros((Bsz, H, N, P), xh.dtype)
    h_fin, h_prior = jax.lax.scan(scan_fn,
                                  init,
                                  (Sc.swapaxes(0, 1), a_tot.swapaxes(0, 1)))
    h_prior = h_prior.swapaxes(0, 1)                      # (B,nc,H,N,P)

    # inter contribution: y_i += C_i . (exp(la_i) * h_prior)
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp",
                         Cc, jnp.exp(la).astype(xh.dtype), h_prior)
    y = (y_intra + y_inter).reshape(Bsz, L, H, P)
    return y, h_fin


def mamba2_block(p, x, cfg, state=None, conv_state=None, chunk: int = 256):
    """Full Mamba2 mixer.  p keys: w_in, conv_w, dt_bias, A_log, D, norm_w,
    w_out.  x: (B, L, D).  If state/conv_state given -> single-step decode
    (L == 1).  Returns (y, (state, conv_state))."""
    B, L, D = x.shape
    d_in = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_head_dim
    H = d_in // P
    N = cfg.ssm_state
    dconv = cfg.ssm_conv
    zxbcdt = jnp.einsum("bld,de->ble", x, p["w_in"])
    z, xc, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)

    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)      # (B,L,d_in+2N)
    if state is None:
        pad = jnp.pad(conv_in, ((0, 0), (dconv - 1, 0), (0, 0)))
        conv = sum(pad[:, i:i + L] * p["conv_w"][i] for i in range(dconv))
        new_conv_state = pad[:, L:L + dconv - 1]   # last dconv-1 inputs
    else:
        hist = jnp.concatenate([conv_state, conv_in], axis=1)  # (B,dconv,•)
        conv = sum(hist[:, i:i + L] * p["conv_w"][i] for i in range(dconv))
        new_conv_state = hist[:, L:]
    conv = jax.nn.silu(conv)
    xc, Bm, Cm = jnp.split(conv, [d_in, d_in + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,L,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (H,)
    a_log = dt * A                                                 # (B,L,H)
    xh = xc.reshape(B, L, H, P) * dt[..., None].astype(x.dtype)

    xh_orig = xh
    if state is None:
        Lp = -(-L // chunk) * chunk
        if Lp != L:
            xh = jnp.pad(xh, ((0, 0), (0, Lp - L), (0, 0), (0, 0)))
            a_log = jnp.pad(a_log, ((0, 0), (0, Lp - L), (0, 0)))
            Bm = jnp.pad(Bm, ((0, 0), (0, Lp - L), (0, 0)))
            Cm = jnp.pad(Cm, ((0, 0), (0, Lp - L), (0, 0)))
        y, h_fin = ssd_chunked(xh, a_log, Bm, Cm, min(chunk, Lp), h0=state)
        y = y[:, :L]
    else:
        # recurrent step(s): h = a*h + B (x) x ; y = C . h
        def step(h, inp):
            xt, at, bt, ct = inp
            h = h * jnp.exp(at)[..., None, None].astype(xt.dtype) \
                + jnp.einsum("bn,bhp->bhnp", bt, xt)
            yt = jnp.einsum("bn,bhnp->bhp", ct, h)
            return h, yt
        h_fin, ys = jax.lax.scan(
            step, state,
            (xh.swapaxes(0, 1), a_log.swapaxes(0, 1),
             Bm.swapaxes(0, 1), Cm.swapaxes(0, 1)))
        y = ys.swapaxes(0, 1)                                       # (B,L,H,P)

    y = y + p["D"][None, None, :, None] * xh_orig
    y = y.reshape(B, L, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.rms_eps)
    out = jnp.einsum("ble,ed->bld", y, p["w_out"])
    return out, (h_fin, new_conv_state)
