"""Architecture registry: --arch <id> -> model + config + input specs."""
from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from .config import SUBQUADRATIC_FAMILIES, ModelConfig
from .transformer import TransformerLM
from .xlstm_lm import XLSTMLM
from .zamba2 import Zamba2LM

ARCH_IDS = (
    "qwen3-moe-235b-a22b", "olmoe-1b-7b", "llama3.2-1b", "granite-3-2b",
    "gemma2-2b", "qwen2.5-14b", "qwen2-vl-2b", "zamba2-2.7b",
    "musicgen-medium", "xlstm-125m",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}

# (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_model(arch_or_cfg):
    cfg = get_config(arch_or_cfg) if isinstance(arch_or_cfg, str) else arch_or_cfg
    cls = {"hybrid": Zamba2LM, "ssm": XLSTMLM}.get(cfg.family, TransformerLM)
    return cls(cfg)


def shape_applicable(cfg: ModelConfig, shape: str) -> bool:
    """long_500k needs sub-quadratic sequence mixing (DESIGN.md §4)."""
    if shape == "long_500k":
        return cfg.family in SUBQUADRATIC_FAMILIES
    return True


def tiny_config(cfg: ModelConfig, n_layers=2) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    repl = dict(
        n_layers=n_layers, d_model=64, n_heads=4, d_head=16,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128 if cfg.d_ff else 0, vocab=256,
        sliding_window=min(cfg.sliding_window, 8) if cfg.sliding_window else 0,
        remat=False,
    )
    if cfg.n_experts:
        repl.update(n_experts=4, top_k=2)
    if cfg.family in ("hybrid",):
        repl.update(ssm_state=16, ssm_head_dim=16, shared_attn_every=2,
                    n_kv_heads=4)
    if cfg.family == "ssm":
        repl.update(slstm_every=2, n_layers=max(n_layers, 2))
    if cfg.mrope_sections:
        repl.update(mrope_sections=(2, 3, 3))
    if cfg.n_codebooks:
        repl.update(n_codebooks=2)
    return dataclasses.replace(cfg, **repl)


def input_specs(cfg: ModelConfig, shape: str, *, tiny: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of the given shape
    cell (no device allocation) — consumed by launch/dryrun.py."""
    S, GB, kind = SHAPES[shape]
    if tiny:
        S, GB = 128, 8
    sds = jax.ShapeDtypeStruct
    i32 = jnp.int32
    model = get_model(cfg)
    if kind == "train":
        batch = {"tokens": sds((GB, S), i32), "labels": sds((GB, S), i32)}
        if cfg.family == "audio":
            batch = {"embeds": sds((GB, S, cfg.d_model), jnp.bfloat16),
                     "labels": sds((GB, S, cfg.n_codebooks), i32)}
        if cfg.family == "vlm":
            batch["positions"] = sds((3, GB, S), i32)
        return {"batch": batch}
    if kind == "prefill":
        batch = {"tokens": sds((GB, S), i32)}
        if cfg.family == "audio":
            batch = {"embeds": sds((GB, S, cfg.d_model), jnp.bfloat16)}
        if cfg.family == "vlm":
            batch["positions"] = sds((3, GB, S), i32)
        return {"batch": batch}
    # decode: one new token against a seq_len-sized state
    batch = {"tokens": sds((GB, 1), i32), "cache_pos": sds((), i32)}
    if cfg.family == "audio":
        batch = {"embeds": sds((GB, 1, cfg.d_model), jnp.bfloat16),
                 "cache_pos": sds((), i32)}
    if cfg.family == "vlm":
        batch["positions"] = sds((3, GB, 1), i32)
    cache = model.abstract_cache(GB, S)
    return {"batch": batch, "cache": cache}
