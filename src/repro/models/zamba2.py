"""Zamba2-style hybrid LM: Mamba2 backbone + one *shared* attention block
applied every `shared_attn_every` layers (Zamba2's parameter-sharing trick;
the shared block sees concat(hidden, original embedding) through a fusion
projection — simplified from the paper's per-invocation LoRA, see DESIGN)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .attention import attn_block
from .common import ParamSpec as PS
from .common import abstract_tree, init_tree, rms_norm, spec_tree
from .config import ModelConfig
from .mamba2 import mamba2_block
from .transformer import TransformerLM, _attn_specs, _mlp_specs, mlp_ffn
from ..distributed.sharding import constrain


class Zamba2LM(TransformerLM):
    def param_specs(self):
        cfg = self.cfg
        L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab_padded
        d_in = cfg.ssm_expand * D
        N, P = cfg.ssm_state, cfg.ssm_head_dim
        H = d_in // P
        conv_ch = d_in + 2 * N
        e_total = 2 * d_in + 2 * N + H
        layers = {
            "ln": PS((L, D), (None, None), init="zeros"),
            "w_in": PS((L, D, e_total), (None, "data", "model")),
            "conv_w": PS((L, cfg.ssm_conv, conv_ch), (None, None, "model"),
                         scale=0.5),
            "dt_bias": PS((L, H), (None, "model"), init="zeros"),
            "A_log": PS((L, H), (None, "model"), init="zeros"),
            "D": PS((L, H), (None, "model"), init="ones"),
            "norm_w": PS((L, d_in), (None, "model"), init="zeros"),
            "w_out": PS((L, d_in, D), (None, "model", "data")),
        }
        shared = {
            "fuse": PS((2 * D, D), ("data", "model")),
            "ln1": PS((D,), (None,), init="zeros"),
            "ln2": PS((D,), (None,), init="zeros"),
            "attn": _att_unstack(_attn_specs(cfg, 1)),
            "mlp": _att_unstack(_mlp_specs(cfg, 1)),
        }
        return {"embed": PS((V, D), ("model", "data"), scale=0.02),
                "layers": layers, "shared": shared,
                "final_norm": PS((D,), (None,), init="zeros"),
                "head": PS((D, V), ("data", "model"))}

    @property
    def n_apps(self):
        return self.cfg.n_layers // self.cfg.shared_attn_every

    def _shared_block(self, params, x, x0, positions, pos_1d, cfg,
                      cache, cache_pos):
        h = jnp.concatenate([x, x0], axis=-1)
        h = jnp.einsum("bsd,df->bsf", h, params["fuse"].astype(x.dtype))
        a, cache_out = attn_block(params["attn"],
                                  rms_norm(h, params["ln1"], cfg.rms_eps),
                                  positions, pos_1d, cfg, 0, cache, cache_pos)
        h = h + a
        h = h + mlp_ffn(params["mlp"],
                        rms_norm(h, params["ln2"], cfg.rms_eps), cfg)
        return x + h, cache_out

    def forward(self, params, batch, mode="train", cache=None):
        cfg = self.cfg
        from .common import cast_tree
        params = cast_tree(params, self.compute_dtype)
        x = self._embed(params, batch)
        B, S, D = x.shape
        x0 = x
        cache_pos = batch.get("cache_pos") if mode == "decode" else None
        positions = self._positions(batch, S, cache_pos)
        pos_1d = positions[0] if positions.ndim == 2 else positions[0, 0]
        every = cfg.shared_attn_every
        A = self.n_apps
        L = cfg.n_layers

        if mode == "decode":
            kv_all = cache["kv"]            # {'k': (A,B,Sc,KV,Dh), 'v': ...}
            Sc = kv_all["k"].shape[2]
        else:
            KV, Dh = cfg.n_kv_heads, cfg.head_dim
            Sc = S
            kv_all = {"k": jnp.zeros((A, B, S, KV, Dh), x.dtype),
                      "v": jnp.zeros((A, B, S, KV, Dh), x.dtype)}

        def body(carry, xs):
            x, kv_all = carry
            if mode == "decode":
                p, idx, ssm_st, conv_st = xs
            else:
                p, idx = xs
                ssm_st = conv_st = None
            h = rms_norm(x, p["ln"], cfg.rms_eps)
            m, (ssm_new, conv_new) = mamba2_block(p, h, cfg, ssm_st, conv_st)
            x = constrain(x + m, "batch", None, None)

            def apply_shared(args):
                x, kv_all = args
                a_idx = idx // every
                lc = jax.tree_util.tree_map(
                    lambda c: jax.lax.dynamic_index_in_dim(c, a_idx, 0,
                                                           keepdims=False),
                    kv_all)
                x, cache_out = self._shared_block(
                    params["shared"], x, x0, positions, pos_1d, cfg,
                    lc if mode == "decode" else None, cache_pos)
                if mode != "train":
                    kv_all = jax.tree_util.tree_map(
                        lambda c, n: jax.lax.dynamic_update_index_in_dim(
                            c, n.astype(c.dtype), a_idx, 0), kv_all, cache_out)
                return (x, kv_all)

            is_app = (idx % every) == (every - 1)
            x, kv_all = jax.lax.cond(is_app, apply_shared, lambda a: a,
                                     (x, kv_all))
            ys = (ssm_new, conv_new) if mode != "train" else None
            return (x, kv_all), ys

        if cfg.remat and mode == "train":
            body = jax.checkpoint(body)

        idxs = jnp.arange(L, dtype=jnp.int32)
        if mode == "decode":
            xs = (params["layers"], idxs, cache["ssm"], cache["conv"])
        else:
            xs = (params["layers"], idxs)
        if cfg.scan_layers:
            (x, kv_all), states = jax.lax.scan(body, (x, kv_all), xs)
        else:
            carry, ys = (x, kv_all), []
            for i in range(L):
                xi = jax.tree_util.tree_map(lambda a: a[i], xs)
                carry, y = body(carry, xi)
                ys.append(y)
            (x, kv_all) = carry
            states = (jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
                      if mode != "train" else None)

        x = rms_norm(x, params["final_norm"], cfg.rms_eps)
        logits = constrain(jnp.einsum("bsd,dv->bsv", x, params["head"]),
                           "batch", None, "model")
        new_cache = None
        if mode in ("prefill", "decode"):
            ssm, conv = states
            new_cache = {"kv": kv_all, "ssm": ssm, "conv": conv}
        return logits, jnp.float32(0), new_cache

    def init_cache(self, batch_size: int, max_len: int, dtype=jnp.bfloat16):
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.abstract_cache(batch_size, max_len, dtype))

    def abstract_cache(self, batch_size: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        d_in = cfg.ssm_expand * cfg.d_model
        N, P = cfg.ssm_state, cfg.ssm_head_dim
        H = d_in // P
        conv_ch = d_in + 2 * N
        L, A = cfg.n_layers, self.n_apps
        KV, Dh = cfg.n_kv_heads, cfg.head_dim
        sds = jax.ShapeDtypeStruct
        return {
            "kv": {"k": sds((A, batch_size, max_len, KV, Dh), dtype),
                   "v": sds((A, batch_size, max_len, KV, Dh), dtype)},
            "ssm": sds((L, batch_size, H, N, P), dtype),
            "conv": sds((L, batch_size, cfg.ssm_conv - 1, conv_ch), dtype),
        }


def _att_unstack(specs):
    """Drop the leading stacked-layer dim from a spec tree (shared block)."""
    return jax.tree_util.tree_map(
        lambda ps: PS(ps.shape[1:], ps.spec[1:], init=ps.init),
        specs, is_leaf=lambda x: isinstance(x, PS))
