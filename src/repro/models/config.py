"""Unified model configuration covering all ten assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0               # 0 -> d_model // n_heads

    # attention options
    qkv_bias: bool = False
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    sliding_window: int = 0       # 0 = none; >0 window size
    local_global_every: int = 0   # gemma2: layer i is global iff i % 2 == 1
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE half-dim split
    post_block_norm: bool = False # gemma2 sandwich norms
    scale_embed: bool = False     # gemma2 sqrt(d) embedding scale

    # granite depth-scaled multipliers
    embedding_multiplier: float = 1.0
    residual_multiplier: float = 1.0
    attention_multiplier: float = 0.0      # 0 -> 1/sqrt(d_head)
    logits_scaling: float = 1.0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    norm_topk_prob: bool = True
    router_aux_coef: float = 0.0

    # SSM / hybrid (zamba2, xlstm)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    shared_attn_every: int = 0    # zamba2: shared attention block cadence
    slstm_every: int = 0          # xlstm: every Nth block is sLSTM

    # audio (musicgen)
    n_codebooks: int = 0

    act: str = "silu"             # silu | gelu
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # training-time knobs (overridable per run)
    remat: bool = True
    scan_layers: bool = True

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to 256 so vocab-sharded params/logits divide the
        mesh axes (16/32-way); loss masks the padding columns."""
        return -(-self.vocab // 256) * 256

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def block_kind(self) -> str:
        if self.family in ("ssm",):
            return "xlstm"
        if self.family == "hybrid":
            return "mamba2"
        return "attn"

    def with_layers(self, n: int) -> "ModelConfig":
        return dataclasses.replace(self, n_layers=n)


# architecture families whose sequence mixing is sub-quadratic (long_500k runs)
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")
