"""Synthetic genome + PBSIM2-like long-read simulator + candidate chains.

The container is offline, so the paper's dataset (PBSIM2 reads from the
human genome, minimap2 chains) is mirrored statistically: a seeded random
genome, reads sampled with a PacBio CLR-like edit profile (default 10%
errors split ~40/35/25 sub/ins/del), and candidate locations = the true
locus (span from the simulator) plus optional decoy loci.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ReadSimConfig:
    read_len: int = 10_000
    error_rate: float = 0.10
    sub_frac: float = 0.40
    ins_frac: float = 0.35
    del_frac: float = 0.25
    seed: int = 0


def synth_genome(length: int, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).integers(0, 4, length).astype(np.uint8)


def mutate(ref: np.ndarray, cfg: ReadSimConfig, rng) -> tuple[np.ndarray, int]:
    """Emit a read by walking `ref` with the error profile.  Returns
    (read[:read_len], ref_span_consumed)."""
    p_err = cfg.error_rate
    tot = cfg.sub_frac + cfg.ins_frac + cfg.del_frac
    p_sub = p_err * cfg.sub_frac / tot
    p_ins = p_err * cfg.ins_frac / tot
    p_del = p_err * cfg.del_frac / tot
    L = cfg.read_len
    # vectorized draw with slack, then fix up lengths
    n = int(L * (1 + p_err) + 64)
    r = rng.random(n)
    out = []
    i = 0  # ref cursor
    for x in r:
        if len(out) >= L or i >= len(ref):
            break
        if x < p_del:
            i += 1
        elif x < p_del + p_ins:
            out.append(rng.integers(0, 4))
        elif x < p_del + p_ins + p_sub:
            c = ref[i]
            out.append((c + 1 + rng.integers(0, 3)) % 4)
            i += 1
        else:
            out.append(ref[i])
            i += 1
    read = np.array(out[:L], dtype=np.uint8)
    return read, i


@dataclasses.dataclass
class ReadSet:
    reads: list[np.ndarray]
    ref_segments: list[np.ndarray]   # true-locus candidate segments
    true_pos: np.ndarray
    spans: np.ndarray


def simulate_reads(genome: np.ndarray, n_reads: int,
                   cfg: ReadSimConfig = ReadSimConfig()) -> ReadSet:
    rng = np.random.default_rng(cfg.seed + 1)
    max_span = int(cfg.read_len * 1.3) + 64
    reads, segs, pos, spans = [], [], [], []
    for _ in range(n_reads):
        p = int(rng.integers(0, len(genome) - max_span))
        read, span = mutate(genome[p:p + max_span], cfg, rng)
        reads.append(read)
        segs.append(genome[p:p + span].copy())
        pos.append(p)
        spans.append(span)
    return ReadSet(reads, segs, np.array(pos), np.array(spans))


def candidate_chains(genome: np.ndarray, rs: ReadSet, decoys_per_read: int = 0,
                     seed: int = 7) -> list[tuple[int, np.ndarray]]:
    """minimap2 `-P`-like candidate list: for each read, the true-locus
    segment plus `decoys_per_read` random loci (which should fail to align).
    Returns list of (read_index, ref_segment)."""
    rng = np.random.default_rng(seed)
    out = []
    for i, seg in enumerate(rs.ref_segments):
        out.append((i, seg))
        for _ in range(decoys_per_read):
            p = int(rng.integers(0, len(genome) - len(seg)))
            out.append((i, genome[p:p + len(seg)].copy()))
    return out
