"""Synthetic genome + PBSIM2-like long-read simulator + candidate chains.

The container is offline, so the paper's dataset (PBSIM2 reads from the
human genome, minimap2 chains) is mirrored statistically: a seeded random
genome, reads sampled with a PacBio CLR-like edit profile (default 10%
errors split ~40/35/25 sub/ins/del), and candidate locations = the true
locus (span from the simulator) plus optional decoy loci.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ReadSimConfig:
    read_len: int = 10_000
    error_rate: float = 0.10
    sub_frac: float = 0.40
    ins_frac: float = 0.35
    del_frac: float = 0.25
    seed: int = 0


def synth_genome(length: int, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).integers(0, 4, length).astype(np.uint8)


def _event_probs(cfg: ReadSimConfig) -> tuple[float, float, float]:
    """(p_sub, p_ins, p_del) per emitted-position draw."""
    tot = cfg.sub_frac + cfg.ins_frac + cfg.del_frac
    return (cfg.error_rate * cfg.sub_frac / tot,
            cfg.error_rate * cfg.ins_frac / tot,
            cfg.error_rate * cfg.del_frac / tot)


def mutate(ref: np.ndarray, cfg: ReadSimConfig, rng) -> tuple[np.ndarray, int]:
    """Emit a read by walking `ref` with the error profile.  Returns
    (read[:read_len], ref_span_consumed)."""
    p_err = cfg.error_rate
    p_sub, p_ins, p_del = _event_probs(cfg)
    L = cfg.read_len
    # vectorized draw with slack, then fix up lengths.  A deletion draw
    # consumes no output, so only (1 - p_del) of draws emit: provision by
    # the expected deletion mass (+6 sigma), keeping the legacy formula
    # when it is the larger so low-deletion profiles keep their exact rng
    # stream.  Top-up draws below cover the residual tail risk.
    need = L / max(1e-9, 1.0 - p_del)
    n = int(max(L * (1 + p_err), need + 6.0 * (need * p_del) ** 0.5) + 64)
    chunk = rng.random(n)
    ci = 0
    out = []
    i = 0  # ref cursor
    while len(out) < L and i < len(ref):
        if ci == len(chunk):
            chunk = rng.random(
                max(64, int((L - len(out)) / max(1e-9, 1.0 - p_del)) + 32))
            ci = 0
        x = chunk[ci]
        ci += 1
        if x < p_del:
            i += 1
        elif x < p_del + p_ins:
            out.append(rng.integers(0, 4))
        elif x < p_del + p_ins + p_sub:
            c = ref[i]
            out.append((c + 1 + rng.integers(0, 3)) % 4)
            i += 1
        else:
            out.append(ref[i])
            i += 1
    read = np.array(out[:L], dtype=np.uint8)
    assert len(read) == L or i >= len(ref), \
        f"short read {len(read)} < {L} with ref remaining (draw shortfall)"
    return read, i


@dataclasses.dataclass
class ReadSet:
    reads: list[np.ndarray]
    ref_segments: list[np.ndarray]   # true-locus candidate segments
    true_pos: np.ndarray
    spans: np.ndarray


def simulate_reads(genome: np.ndarray, n_reads: int,
                   cfg: ReadSimConfig = ReadSimConfig()) -> ReadSet:
    rng = np.random.default_rng(cfg.seed + 1)
    # ref consumed per emitted base is (1 - p_ins) / (1 - p_del): deletions
    # eat ref without emitting.  Keep the legacy 1.3x when it is larger so
    # low-deletion profiles keep their exact sampling stream.
    _, p_ins, p_del = _event_probs(cfg)
    span_ratio = (1.0 - p_ins) / max(1e-9, 1.0 - p_del)
    max_span = int(cfg.read_len * max(1.3, 1.15 * span_ratio)) + 64
    reads, segs, pos, spans = [], [], [], []
    for _ in range(n_reads):
        p = int(rng.integers(0, len(genome) - max_span))
        read, span = mutate(genome[p:p + max_span], cfg, rng)
        reads.append(read)
        segs.append(genome[p:p + span].copy())
        pos.append(p)
        spans.append(span)
    return ReadSet(reads, segs, np.array(pos), np.array(spans))


def candidate_chains(genome: np.ndarray, rs: ReadSet, decoys_per_read: int = 0,
                     seed: int = 7) -> list[tuple[int, np.ndarray]]:
    """minimap2 `-P`-like candidate list: for each read, the true-locus
    segment plus `decoys_per_read` random loci (which should fail to align).
    Returns list of (read_index, ref_segment)."""
    rng = np.random.default_rng(seed)
    out = []
    for i, seg in enumerate(rs.ref_segments):
        out.append((i, seg))
        for _ in range(decoys_per_read):
            p = int(rng.integers(0, len(genome) - len(seg)))
            out.append((i, genome[p:p + len(seg)].copy()))
    return out


def plant_decoys(genome: np.ndarray, rs: ReadSet, decoys_per_read: int = 4,
                 chunk: int = 250, divergence: float = 0.03,
                 seed: int = 17) -> tuple[np.ndarray, np.ndarray]:
    """Plant partial-repeat decoy loci for END-TO-END mapper evaluation.

    ``candidate_chains`` hands an aligner fabricated decoy segments; a
    real mapper discovers its own candidates, so decoys must live IN the
    genome.  For each read, copy a ``chunk``-long piece from the interior
    of its true segment (lightly mutated by ``divergence``) to
    ``decoys_per_read`` random loci.  Seeding finds the shared chunk and
    chaining extrapolates a full candidate window around it — but the
    window's flanks are unrelated sequence, so the X-drop pre-filter
    (anchored at the window start) kills it, the way partial repeats
    behave in real mapping.  Decoy sites avoid every true locus and each
    other, so planting never corrupts ground truth.

    Returns (planted genome copy, (n_reads, decoys_per_read) decoy
    positions).
    """
    rng = np.random.default_rng(seed)
    g = genome.copy()
    occupied = [(int(p), int(p + s)) for p, s in zip(rs.true_pos, rs.spans)]
    pos = np.zeros((len(rs.reads), decoys_per_read), np.int64)
    for i, seg in enumerate(rs.ref_segments):
        # interior chunk: past any pre-filter prefix, clear of the tail
        lo = min(max(0, len(seg) - chunk), max(0, len(seg) // 2 - chunk // 2))
        src = seg[lo:lo + chunk].copy()
        for d in range(decoys_per_read):
            piece = src.copy()
            flip = rng.random(len(piece)) < divergence
            piece[flip] = (piece[flip] + 1 + rng.integers(
                0, 3, int(flip.sum()))) % 4
            for _ in range(1000):
                p = int(rng.integers(0, len(g) - len(piece)))
                if all(p + len(piece) <= a or p >= b for a, b in occupied):
                    break
            else:
                raise RuntimeError("no free decoy site found")
            g[p:p + len(piece)] = piece
            occupied.append((p, p + len(piece)))
            pos[i, d] = p
    return g, pos
