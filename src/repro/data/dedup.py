"""GenASM as an LM data-pipeline operator: alignment-based near-duplicate
filtering of training sequences (the paper's technique integrated as a
first-class framework feature — see DESIGN.md §4).

Token streams are reduced to the aligner's 4-symbol alphabet (2-bit hash
per token); near-duplicates then have small edit distance in the reduced
space (the reduction can only *lower* distance, so no true near-dup is
missed; unrelated pairs collide to ~expected-random distance ≈ 0.5/symbol,
far above threshold)."""
from __future__ import annotations

import numpy as np

from ..core.aligner import GenASMAligner
from ..core.config import AlignerConfig


def tokens_to_dna(tokens: np.ndarray) -> np.ndarray:
    """2-bit hash of each token id (splitmix-style mix, xor-folded)."""
    t = tokens.astype(np.uint64)
    h = t * np.uint64(0x9E3779B97F4A7C15)
    h ^= h >> np.uint64(31)
    h *= np.uint64(0xBF58476D1CE4E5B9)
    h ^= h >> np.uint64(29)
    return (h & np.uint64(3)).astype(np.uint8)


def near_duplicates(seqs: list[np.ndarray], *, max_rate: float = 0.15,
                    cfg: AlignerConfig | None = None) -> list[tuple[int, int, int]]:
    """All-pairs near-dup candidates among token sequences (for production,
    pre-bucket by MinHash; all-pairs keeps the demo self-contained).
    Returns (i, j, dist) pairs whose edit rate <= max_rate."""
    cfg = cfg or AlignerConfig(W=64, O=24, k=12)
    enc = [tokens_to_dna(s) for s in seqs]
    pairs = [(i, j) for i in range(len(seqs)) for j in range(i + 1, len(seqs))
             if 0.8 <= len(enc[i]) / max(1, len(enc[j])) <= 1.25]
    if not pairs:
        return []
    al = GenASMAligner(cfg, rescue_rounds=1)
    reads = [enc[i] for i, _ in pairs]
    refs = [enc[j] for _, j in pairs]
    res = al.align(reads, refs)
    out = []
    for (i, j), d, failed in zip(pairs, res.dist, res.failed):
        if not failed and d <= max_rate * max(len(enc[i]), len(enc[j])):
            out.append((i, j, int(d)))
    return out


def dedup_filter(seqs: list[np.ndarray], **kw) -> list[int]:
    """Indices to KEEP (first occurrence wins)."""
    dups = near_duplicates(seqs, **kw)
    drop = {j for _, j, _ in dups}
    return [i for i in range(len(seqs)) if i not in drop]
