"""Synthetic LM token pipeline: seeded Zipf-ish stream, packed batches,
background prefetch (host async), deterministic resume via a step cursor
(the cursor is part of training state conceptually; here it is the seed +
step so restore replays the same stream)."""
from __future__ import annotations

import queue
import threading

import numpy as np


class TokenStream:
    """Deterministic batch generator: batch i is a pure function of
    (seed, i) — replay after restart is exact."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 family: str = "dense", d_model: int = 0, n_codebooks: int = 0):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed = seed
        self.family = family
        self.d_model = d_model
        self.n_codebooks = n_codebooks

    def batch_at(self, i: int):
        rng = np.random.default_rng((self.seed << 20) ^ i)
        # Zipf-flavoured marginal over the vocab, repeated-ngram structure
        z = rng.zipf(1.3, size=(self.batch, self.seq + 1)).astype(np.int64)
        toks = (z % (self.vocab - 1)) + 1
        out = {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
        if self.family == "audio":
            emb = rng.standard_normal(
                (self.batch, self.seq, self.d_model)).astype(np.float32)
            lab = rng.integers(0, self.vocab,
                               (self.batch, self.seq, self.n_codebooks))
            out = {"embeds": emb, "labels": lab.astype(np.int32)}
        if self.family == "vlm":
            pos = np.broadcast_to(np.arange(self.seq, dtype=np.int32),
                                  (self.batch, self.seq))
            out["positions"] = np.stack([pos] * 3)
        return out

    def iterate(self, start: int = 0):
        i = start
        while True:
            yield self.batch_at(i)
            i += 1


class Prefetcher:
    """Host-side async prefetch (overlaps batch synthesis with device work)."""

    def __init__(self, it, depth: int = 2):
        self.q = queue.Queue(maxsize=depth)
        self.it = it
        self._stop = False
        self.t = threading.Thread(target=self._run, daemon=True)
        self.t.start()

    def _run(self):
        for item in self.it:
            if self._stop:
                return
            self.q.put(item)

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def stop(self):
        self._stop = True
