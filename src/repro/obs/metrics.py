"""Thread-safe, low-overhead metrics registry (counters, gauges,
histograms with fixed bucket edges).

The paper's claims are all *measurements* — per-stage stores, accesses,
windows (Scrooge argues the same way) — yet four generations of this
repo's instrumentation each grew their own counters, locking and export
path (``core.transfer``, ``CompileCache``, ``gateway_stats()``, the
mapper funnel).  This module is the one substrate they all ride now:

* A :class:`MetricsRegistry` hands out **named, labelled metric objects**
  memoised by (name, labels): asking twice returns the same object, so a
  hot path fetches its counters ONCE at init and pays only a locked
  ``+=`` per event afterwards (increments are locked because the exact
  1-upload/1-download and lowering-count test assertions must survive
  the session's retire thread racing the dispatch thread).
* ``registry.labeled(session="a")`` returns a **view** that stamps a
  constant label set onto every metric it vends — how several sessions
  share one registry (benchmarks, a future multi-process fingerprint)
  without colliding, while each still reads back only its own counters.
* :data:`NULL_REGISTRY` is the **disabled** registry: every request
  returns the one :data:`NULL_METRIC` singleton whose mutators do
  nothing — no allocation, no lock, no branch at the call site — so an
  obs-disabled serving path costs a method call per event and nothing
  else (tests/test_obs.py holds the submit path to zero obs-module
  allocations).

Reads (``.value``) are deliberately lock-free: a single attribute load
of a Python int/float is atomic under the GIL, and exporters tolerate
point-in-time skew between metrics.  Values are cumulative since
construction; ``reset()`` exists because the transfer-counter contract
(``transfer.reset()``) predates this module and is per-family, not
registry-wide.
"""
from __future__ import annotations

import bisect
import threading

#: Fixed default histogram edges (seconds): latency-shaped, 1ms..10s.
#: Fixed at construction — Prometheus-style cumulative buckets only make
#: sense when every observation falls into a stable edge set.
DEFAULT_EDGES = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                 0.5, 1.0, 2.5, 5.0, 10.0)


def qualified_name(name: str, labels: tuple) -> str:
    """``name{k="v",...}`` — the snapshot/export key for one metric."""
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic counter (ints or float seconds).  ``inc`` is exact under
    concurrent threads (locked read-modify-write); ``value`` is a single
    atomic read."""

    kind = "counter"
    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: tuple = ()):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n=1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self):
        return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0

    def snap(self):
        return self._value

    def __repr__(self):
        return f"Counter({qualified_name(self.name, self.labels)}=" \
               f"{self._value})"


class Gauge:
    """Up/down instantaneous value (queue depths, in-flight counts)."""

    kind = "gauge"
    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: tuple = ()):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._value = 0

    def add(self, n=1) -> None:
        with self._lock:
            self._value += n

    def set(self, v) -> None:
        with self._lock:
            self._value = v

    @property
    def value(self):
        return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0

    def snap(self):
        return self._value

    def __repr__(self):
        return f"Gauge({qualified_name(self.name, self.labels)}=" \
               f"{self._value})"


class Histogram:
    """Fixed-edge histogram (Prometheus-style cumulative buckets).

    ``edges`` are the upper bounds of the finite buckets; one implicit
    ``+Inf`` bucket catches the rest.  ``observe`` is O(log n_edges)
    under the lock."""

    kind = "histogram"
    __slots__ = ("name", "labels", "edges", "_lock", "_counts", "_sum",
                 "_count")

    def __init__(self, name: str, labels: tuple = (),
                 edges: tuple = DEFAULT_EDGES):
        assert tuple(edges) == tuple(sorted(edges)) and len(edges) >= 1, \
            edges
        self.name = name
        self.labels = labels
        self.edges = tuple(float(e) for e in edges)
        self._lock = threading.Lock()
        self._counts = [0] * (len(self.edges) + 1)   # [..., +Inf]
        self._sum = 0.0
        self._count = 0

    def observe(self, v) -> None:
        i = bisect.bisect_left(self.edges, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def reset(self) -> None:
        with self._lock:
            self._counts = [0] * (len(self.edges) + 1)
            self._sum = 0.0
            self._count = 0

    def snap(self) -> dict:
        """{"buckets": {edge: cumulative_count, "+Inf": total}, "sum",
        "count"} — cumulative, the Prometheus exposition shape."""
        with self._lock:
            counts = list(self._counts)
            s, c = self._sum, self._count
        cum, out = 0, {}
        for e, n in zip(self.edges, counts):
            cum += n
            out[repr(e)] = cum
        out["+Inf"] = cum + counts[-1]
        return {"buckets": out, "sum": s, "count": c}

    def __repr__(self):
        return f"Histogram({qualified_name(self.name, self.labels)} " \
               f"count={self._count} sum={self._sum:.6g})"


class _NullMetric:
    """The disabled metric: one process-wide singleton serving as counter,
    gauge AND histogram — every mutator is a no-op, every read is zero.
    Identity is the contract (``registry.counter(...) is NULL_METRIC``):
    a disabled hot path holds this object and pays one no-op method call
    per event, allocating nothing (tests/test_obs.py)."""

    kind = "null"
    __slots__ = ()
    value = 0
    count = 0
    sum = 0.0
    name = "<null>"
    labels = ()
    edges = ()

    def inc(self, n=1) -> None:
        pass

    def add(self, n=1) -> None:
        pass

    def set(self, v) -> None:
        pass

    def observe(self, v) -> None:
        pass

    def reset(self) -> None:
        pass

    def snap(self):
        return 0


NULL_METRIC = _NullMetric()


class MetricsRegistry:
    """Process- or session-scoped metric store.

    One registry per observability domain: the process-global default
    (``repro.obs.default_registry()``) carries the cross-cutting families
    (host<->device transfers, the shared compile cache); each
    :class:`~repro.api.AlignSession` gets its own injectable registry so
    N tenants never collide and a snapshot is one tenant's whole story.
    """

    enabled = True

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict = {}      # (name, labels) -> metric

    def _get(self, cls, name: str, labels: dict, **kw):
        key = (name, tuple(sorted(labels.items())))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = cls(name, key[1], **kw)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {qualified_name(name, key[1])} already "
                    f"registered as {m.kind}, requested {cls.kind}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, edges: tuple = DEFAULT_EDGES,
                  **labels) -> Histogram:
        h = self._get(Histogram, name, labels, edges=edges)
        if h.edges != tuple(float(e) for e in edges):
            raise ValueError(
                f"histogram {name} already registered with edges "
                f"{h.edges}, requested {edges} (edges are fixed)")
        return h

    def labeled(self, **labels) -> "LabeledRegistry":
        """A view stamping constant labels on every metric it vends —
        several components share one registry without name collisions."""
        return LabeledRegistry(self, labels)

    def metrics(self) -> list:
        with self._lock:
            return list(self._metrics.values())

    def snapshot(self) -> dict:
        """{qualified_name: value-or-histogram-dict} for every metric —
        the one structure exporters, benchmarks and the legacy-accessor
        equality tests read."""
        return {qualified_name(m.name, m.labels): m.snap()
                for m in self.metrics()}

    def reset(self) -> None:
        for m in self.metrics():
            m.reset()


class LabeledRegistry:
    """Constant-label view over a base registry (see
    :meth:`MetricsRegistry.labeled`).  Shares the base's storage; its own
    ``snapshot()`` is filtered to metrics carrying the view's labels."""

    enabled = True

    def __init__(self, base, labels: dict):
        self._base = base
        self._labels = dict(labels)

    def counter(self, name: str, **labels) -> Counter:
        return self._base.counter(name, **{**self._labels, **labels})

    def gauge(self, name: str, **labels) -> Gauge:
        return self._base.gauge(name, **{**self._labels, **labels})

    def histogram(self, name: str, edges: tuple = DEFAULT_EDGES,
                  **labels) -> Histogram:
        return self._base.histogram(name, edges=edges,
                                    **{**self._labels, **labels})

    def labeled(self, **labels) -> "LabeledRegistry":
        return LabeledRegistry(self._base, {**self._labels, **labels})

    def metrics(self) -> list:
        want = set(self._labels.items())
        return [m for m in self._base.metrics()
                if want <= set(m.labels)]

    def snapshot(self) -> dict:
        return {qualified_name(m.name, m.labels): m.snap()
                for m in self.metrics()}

    def reset(self) -> None:
        for m in self.metrics():
            m.reset()


class NullRegistry:
    """The disabled registry: vends :data:`NULL_METRIC` for everything.
    ``enabled`` is False so call sites that must skip even the no-op
    (e.g. building a label dict) can branch once at init."""

    enabled = False

    def counter(self, name: str, **labels):
        return NULL_METRIC

    def gauge(self, name: str, **labels):
        return NULL_METRIC

    def histogram(self, name: str, edges: tuple = DEFAULT_EDGES,
                  **labels):
        return NULL_METRIC

    def labeled(self, **labels) -> "NullRegistry":
        return self

    def metrics(self) -> list:
        return []

    def snapshot(self) -> dict:
        return {}

    def reset(self) -> None:
        pass


NULL_REGISTRY = NullRegistry()

_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-global registry: cross-cutting counter families
    (``transfer_*``, the shared ``compile_cache_*``) live here; sessions
    get their own (see repro.obs.Obs)."""
    return _DEFAULT
