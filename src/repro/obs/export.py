"""Exporters: Prometheus-style text, JSON-lines trace dump, and
perfetto-compatible (Chrome trace-event) JSON.

All three are pure functions of a registry/tracer snapshot — no I/O, no
global state — so tests assert exact output and callers pick their sink
(stdout for the example's ``--metrics-dump``, files for the nightly CI
artifacts, ``ui.perfetto.dev`` for the timeline).
"""
from __future__ import annotations

import json

from .metrics import qualified_name


def prometheus_text(registry) -> str:
    """The registry as Prometheus exposition text: one ``# TYPE`` comment
    per metric family, counters/gauges as single samples, histograms as
    cumulative ``_bucket{le=...}`` + ``_sum`` + ``_count`` series."""
    lines = []
    seen_types = set()
    metrics = sorted(registry.metrics(), key=lambda m: (m.name, m.labels))
    for m in metrics:
        if m.name not in seen_types:
            seen_types.add(m.name)
            lines.append(f"# TYPE {m.name} {m.kind}")
        if m.kind == "histogram":
            snap = m.snap()
            base = dict(m.labels)
            for le, cum in snap["buckets"].items():
                labels = tuple(sorted({**base, "le": le}.items()))
                lines.append(
                    f"{qualified_name(m.name + '_bucket', labels)} {cum}")
            lines.append(
                f"{qualified_name(m.name + '_sum', m.labels)} "
                f"{snap['sum']}")
            lines.append(
                f"{qualified_name(m.name + '_count', m.labels)} "
                f"{snap['count']}")
        else:
            lines.append(f"{qualified_name(m.name, m.labels)} {m.snap()}")
    return "\n".join(lines) + ("\n" if lines else "")


def trace_jsonl(tracer) -> str:
    """Completed spans as JSON lines (one span per line, oldest first) —
    the grep/jq-friendly dump."""
    return "".join(json.dumps(r, sort_keys=True) + "\n"
                   for r in tracer.records())


def perfetto_trace(tracer, pid: int = 0) -> dict:
    """Spans as Chrome trace-event JSON (the format perfetto /
    chrome://tracing load directly): complete ("X") events with
    microsecond timestamps, one track per thread, thread names as "M"
    metadata events.  Clock origin is the tracer's clock (monotonic or
    fake) — relative placement is what the timeline shows."""
    records = tracer.records()
    tids: dict[str, int] = {}
    events = []
    for r in records:
        tid = tids.setdefault(r["thread"], len(tids) + 1)
        args = dict(r["attrs"])
        args["sid"] = r["sid"]
        if r["parent"] is not None:
            args["parent_sid"] = r["parent"]
        events.append({
            "name": r["name"], "cat": "repro.obs", "ph": "X",
            "ts": r["t0"] * 1e6, "dur": max(0.0, (r["t1"] - r["t0"]) * 1e6),
            "pid": pid, "tid": tid, "args": args,
        })
    meta = [{"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
             "args": {"name": tname}} for tname, tid in tids.items()]
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def write_artifacts(obs, directory, prefix: str = "obs") -> dict:
    """Write the three exports for one Obs bundle into ``directory``:
    ``<prefix>_metrics.prom``, ``<prefix>_trace.jsonl``,
    ``<prefix>_trace.json`` (perfetto).  Returns {kind: path} — the
    nightly CI job uploads these as artifacts."""
    import os

    os.makedirs(directory, exist_ok=True)
    paths = {}

    p = os.path.join(directory, f"{prefix}_metrics.prom")
    with open(p, "w") as fh:
        fh.write(prometheus_text(obs.registry))
    paths["prometheus"] = p

    p = os.path.join(directory, f"{prefix}_trace.jsonl")
    with open(p, "w") as fh:
        fh.write(trace_jsonl(obs.tracer))
    paths["jsonl"] = p

    p = os.path.join(directory, f"{prefix}_trace.json")
    with open(p, "w") as fh:
        json.dump(perfetto_trace(obs.tracer), fh, indent=1)
    paths["perfetto"] = p
    return paths
