"""Structured span tracing on an injectable clock.

A span is one timed, named, attributed interval; nesting is tracked per
thread (a span opened while another is live on the same thread records
it as its parent), so the serving stack's hierarchy —

    gateway.admit -> session.dispatch -> device.execute
    retire.decode -> rescue.rung[k]
    mapper.map_batch -> index.lookup / chain / prefilter / align

— falls out of the ``with tracer.span(...)`` blocks already wrapping
those stages, across the dispatch AND retire threads (each thread keeps
its own stack; a retire-side span is a root, not a fake child of
whatever the dispatch thread happens to be doing).

Determinism is the same discipline the gateway scheduler is held to: the
clock is injectable, so a FakeClock yields byte-stable span timestamps
and the tier-1 trace tests assert EXACT span trees with zero
``time.sleep`` (tests/test_obs.py).  Completed spans land in a bounded
deque (``maxlen``) — a long-lived session's trace memory is bounded, old
spans fall off the back.

:data:`NULL_TRACER` is the disabled tracer: ``span()`` returns the one
reusable :data:`NULL_SPAN` singleton (no record, no clock read, no
allocation beyond the call itself).
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque


class Span:
    """One open interval; a context manager.  Records itself into the
    tracer's deque on ``__exit__`` (only completed spans are recorded)."""

    __slots__ = ("name", "attrs", "sid", "parent", "thread", "t0", "t1",
                 "_tracer")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.sid = None
        self.parent = None
        self.thread = None
        self.t0 = None
        self.t1 = None

    def __enter__(self) -> "Span":
        tr = self._tracer
        self.sid = next(tr._ids)
        stack = tr._stack()
        self.parent = stack[-1].sid if stack else None
        self.thread = threading.current_thread().name
        stack.append(self)
        self.t0 = tr._clock()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        tr = self._tracer
        self.t1 = tr._clock()
        stack = tr._stack()
        # tolerate exception-path unwinding out of order
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:
            stack.remove(self)
        if exc_type is not None:
            self.attrs = {**self.attrs, "error": exc_type.__name__}
        tr._record(self)
        return False


class Tracer:
    """Span collector: injectable clock, per-thread nesting stacks, one
    bounded deque of completed spans."""

    enabled = True

    def __init__(self, clock=None, maxlen: int = 8192):
        self._clock = clock if clock is not None else time.monotonic
        self._records: deque = deque(maxlen=maxlen)
        self._ids = itertools.count()
        self._tls = threading.local()
        self._lock = threading.Lock()

    def _stack(self) -> list:
        s = getattr(self._tls, "stack", None)
        if s is None:
            s = self._tls.stack = []
        return s

    def span(self, name: str, **attrs) -> Span:
        """Open a span: ``with tracer.span("session.dispatch", lanes=8):``
        Attrs must be JSON-serializable scalars (exporters dump them)."""
        return Span(self, name, attrs)

    def _record(self, span: Span) -> None:
        with self._lock:
            self._records.append(span)

    def records(self) -> list[dict]:
        """Completed spans, oldest first, as plain dicts:
        {name, sid, parent, thread, t0, t1, attrs}."""
        with self._lock:
            spans = list(self._records)
        return [{"name": s.name, "sid": s.sid, "parent": s.parent,
                 "thread": s.thread, "t0": s.t0, "t1": s.t1,
                 "attrs": dict(s.attrs)} for s in spans]

    def reset(self) -> None:
        with self._lock:
            self._records.clear()


class _NullSpan:
    """Reusable no-op span: stateless, so one singleton serves every
    disabled ``with`` block on every thread concurrently."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled tracer: no clock reads, no records, no per-span
    allocation (``span()`` hands back the singleton)."""

    enabled = False

    def span(self, name: str, **attrs) -> _NullSpan:
        return NULL_SPAN

    def records(self) -> list:
        return []

    def reset(self) -> None:
        pass


NULL_TRACER = NullTracer()
