"""repro.obs — the unified observability subsystem.

One substrate for every measurement the repo makes (the paper argues
from per-stage accounting; so do we):

* :mod:`repro.obs.metrics` — thread-safe counters/gauges/histograms in a
  :class:`MetricsRegistry`; a process-global default registry for
  cross-cutting families (``transfer_*``, shared ``compile_cache_*``)
  plus injectable per-session registries.
* :mod:`repro.obs.trace` — structured span tracing on an injectable
  clock (``gateway.admit → session.dispatch → device.execute``,
  ``retire.decode → rescue.rung``, and the mapper funnel
  ``index.lookup → chain → prefilter → align``).
* :mod:`repro.obs.export` — Prometheus text, JSON-lines, perfetto
  trace-event JSON.

The :class:`Obs` bundle is what components take: a registry + a tracer
that share an enabled/disabled fate.  ``plan(..., obs='off')`` resolves
to :data:`OBS_OFF`, whose metrics are the :data:`NULL_METRIC` singleton
and whose spans are the :data:`NULL_SPAN` singleton — the hot path then
costs a no-op method call per event and nothing else (identity and
zero-allocation are asserted in tests/test_obs.py).  The trade is
explicit: ``obs='off'`` gives up ALL telemetry for that session
(``session.stats`` reads zeros) in exchange for zero overhead.
"""
from __future__ import annotations

from .export import (perfetto_trace, prometheus_text, trace_jsonl,
                     write_artifacts)
from .metrics import (DEFAULT_EDGES, Counter, Gauge, Histogram,
                      LabeledRegistry, MetricsRegistry, NULL_METRIC,
                      NULL_REGISTRY, NullRegistry, default_registry,
                      qualified_name)
from .trace import NULL_SPAN, NULL_TRACER, NullTracer, Span, Tracer

__all__ = [
    "Obs", "OBS_OFF", "resolve_obs",
    "MetricsRegistry", "LabeledRegistry", "NullRegistry", "NULL_REGISTRY",
    "Counter", "Gauge", "Histogram", "NULL_METRIC", "DEFAULT_EDGES",
    "Tracer", "NullTracer", "Span", "NULL_SPAN", "NULL_TRACER",
    "prometheus_text", "trace_jsonl", "perfetto_trace", "write_artifacts",
    "default_registry", "qualified_name",
]


class Obs:
    """One observability domain: a metrics registry + a span tracer.

    Components hold an ``Obs`` and ask it for metrics/spans; callers
    choose the scope by choosing which ``Obs`` to inject (a private one
    per session by default, one shared bundle across a benchmark run,
    or :data:`OBS_OFF`)."""

    __slots__ = ("registry", "tracer")

    def __init__(self, registry, tracer):
        self.registry = registry
        self.tracer = tracer

    @property
    def enabled(self) -> bool:
        return self.registry.enabled

    @staticmethod
    def private(clock=None, maxlen: int = 8192) -> "Obs":
        """A fresh enabled bundle (own registry, own tracer on ``clock``)."""
        return Obs(MetricsRegistry(), Tracer(clock=clock, maxlen=maxlen))

    # -- convenience passthroughs ------------------------------------
    def counter(self, name: str, **labels):
        return self.registry.counter(name, **labels)

    def gauge(self, name: str, **labels):
        return self.registry.gauge(name, **labels)

    def histogram(self, name: str, edges=DEFAULT_EDGES, **labels):
        return self.registry.histogram(name, edges=edges, **labels)

    def span(self, name: str, **attrs):
        return self.tracer.span(name, **attrs)

    def labeled(self, **labels) -> "Obs":
        """Same tracer, a constant-label view of the registry."""
        return Obs(self.registry.labeled(**labels), self.tracer)

    def snapshot(self) -> dict:
        return self.registry.snapshot()

    def prometheus(self) -> str:
        return prometheus_text(self.registry)

    def perfetto(self) -> dict:
        return perfetto_trace(self.tracer)

    def jsonl(self) -> str:
        return trace_jsonl(self.tracer)

    def reset(self) -> None:
        self.registry.reset()
        self.tracer.reset()


#: The disabled bundle — every metric is NULL_METRIC, every span is
#: NULL_SPAN.  Shared and stateless, so one instance serves the process.
OBS_OFF = Obs(NULL_REGISTRY, NULL_TRACER)


def resolve_obs(obs, clock=None) -> Obs:
    """Normalise the ``obs=`` argument components accept:

    * ``None`` → a fresh private enabled bundle (tracer on ``clock``);
    * ``'off'`` / ``False`` → :data:`OBS_OFF`;
    * an :class:`Obs` → itself (caller-scoped sharing).
    """
    if obs is None:
        return Obs.private(clock=clock)
    if obs is False or obs == "off":
        return OBS_OFF
    if isinstance(obs, Obs):
        return obs
    raise TypeError(f"obs must be None, 'off', or an Obs bundle; got "
                    f"{obs!r}")
