"""Data pipeline: simulator statistics, chains, GenASM-based dedup."""
import numpy as np
import pytest

from repro.core.oracle import levenshtein
from repro.data.dedup import dedup_filter, near_duplicates, tokens_to_dna
from repro.data.genome import (ReadSimConfig, candidate_chains, mutate,
                               simulate_reads, synth_genome)


def test_simulator_error_rate_matches_config():
    g = synth_genome(120_000, seed=1)
    cfg = ReadSimConfig(read_len=2000, error_rate=0.10, seed=2)
    rs = simulate_reads(g, 4, cfg)
    rates = []
    for r, seg in zip(rs.reads, rs.ref_segments):
        ed = levenshtein(r[:500], seg[:500 + 40])
        # global distance of prefixes overestimates slightly (tail gaps)
        rates.append(ed / 500)
    assert 0.05 < np.mean(rates) < 0.22


def test_chains_contain_true_locus_and_decoys():
    g = synth_genome(50_000, seed=3)
    rs = simulate_reads(g, 3, ReadSimConfig(read_len=300, seed=4))
    chains = candidate_chains(g, rs, decoys_per_read=2)
    assert len(chains) == 9
    # true locus segments match the simulator's
    assert all(np.array_equal(chains[3 * i][1], rs.ref_segments[i])
               for i in range(3))


def test_tokens_to_dna_alphabet():
    t = np.arange(1000)
    d = tokens_to_dna(t)
    assert d.min() >= 0 and d.max() <= 3
    # hash should spread
    assert len({tuple(d[i:i + 4]) for i in range(0, 996, 4)}) > 100


@pytest.mark.slow
def test_dedup_finds_near_duplicates():
    rng = np.random.default_rng(5)
    base = rng.integers(0, 30_000, 400)
    near = base.copy()
    near[::50] = rng.integers(0, 30_000, len(near[::50]))  # ~2% token edits
    other = rng.integers(0, 30_000, 400)
    seqs = [base, near, other]
    dups = near_duplicates(seqs, max_rate=0.15)
    pairs = {(i, j) for i, j, _ in dups}
    assert (0, 1) in pairs
    assert (0, 2) not in pairs and (1, 2) not in pairs
    keep = dedup_filter(seqs, max_rate=0.15)
    assert keep == [0, 2]
