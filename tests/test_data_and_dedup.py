"""Data pipeline: simulator statistics, chains, GenASM-based dedup."""
import numpy as np
import pytest

from repro.core.oracle import levenshtein
from repro.data.dedup import dedup_filter, near_duplicates, tokens_to_dna
from repro.data.genome import (ReadSimConfig, candidate_chains, mutate,
                               plant_decoys, simulate_reads, synth_genome)


def test_simulator_error_rate_matches_config():
    g = synth_genome(120_000, seed=1)
    cfg = ReadSimConfig(read_len=2000, error_rate=0.10, seed=2)
    rs = simulate_reads(g, 4, cfg)
    rates = []
    for r, seg in zip(rs.reads, rs.ref_segments):
        ed = levenshtein(r[:500], seg[:500 + 40])
        # global distance of prefixes overestimates slightly (tail gaps)
        rates.append(ed / 500)
    assert 0.05 < np.mean(rates) < 0.22


def test_chains_contain_true_locus_and_decoys():
    g = synth_genome(50_000, seed=3)
    rs = simulate_reads(g, 3, ReadSimConfig(read_len=300, seed=4))
    chains = candidate_chains(g, rs, decoys_per_read=2)
    assert len(chains) == 9
    # true locus segments match the simulator's
    assert all(np.array_equal(chains[3 * i][1], rs.ref_segments[i])
               for i in range(3))


def test_mutate_full_length_under_del_heavy_profile():
    """Regression: the draw provision `L * (1 + p_err) + 64` ignored that
    deletions consume a draw but emit nothing, so del-heavy/high-error
    profiles returned reads silently shorter than cfg.read_len.  With
    enough reference, every read must come back exactly read_len."""
    cfg = ReadSimConfig(read_len=10_000, error_rate=0.3, sub_frac=0.1,
                        ins_frac=0.1, del_frac=0.8, seed=5)
    rng = np.random.default_rng(9)
    ref = synth_genome(40_000, seed=6)
    for _ in range(5):
        read, span = mutate(ref, cfg, rng)
        assert len(read) == cfg.read_len
        assert span <= len(ref)
    # simulate_reads must provision its ref slice by the same mass
    g = synth_genome(120_000, seed=7)
    rs = simulate_reads(g, 6, cfg)
    assert all(len(r) == cfg.read_len for r in rs.reads)
    # ...and untouched low-deletion profiles keep their exact rng stream
    # (bit-compatibility contract with committed BENCH baselines)
    rs0 = simulate_reads(synth_genome(100_000, seed=1), 2,
                         ReadSimConfig(read_len=1000, seed=2))
    assert list(rs0.true_pos) == [80043, 20654]


def test_plant_decoys_preserves_ground_truth():
    """Planted decoy chunks must never overwrite a true locus, and each
    decoy must actually carry the read's interior sequence."""
    g = synth_genome(80_000, seed=8)
    rs = simulate_reads(g, 4, ReadSimConfig(read_len=600, seed=9))
    g2, dpos = plant_decoys(g, rs, decoys_per_read=3, chunk=200,
                            divergence=0.0)
    assert dpos.shape == (4, 3)
    for p, s, seg in zip(rs.true_pos, rs.spans, rs.ref_segments):
        assert np.array_equal(g2[p:p + s], seg)      # truth untouched
    for i, seg in enumerate(rs.ref_segments):
        for d in range(3):
            piece = g2[dpos[i, d]:dpos[i, d] + 200]
            # zero divergence: the chunk is a verbatim interior copy
            hit = [np.array_equal(piece, seg[o:o + 200])
                   for o in range(len(seg) - 200 + 1)]
            assert any(hit)


def test_tokens_to_dna_alphabet():
    t = np.arange(1000)
    d = tokens_to_dna(t)
    assert d.min() >= 0 and d.max() <= 3
    # hash should spread
    assert len({tuple(d[i:i + 4]) for i in range(0, 996, 4)}) > 100


@pytest.mark.slow
def test_dedup_finds_near_duplicates():
    rng = np.random.default_rng(5)
    base = rng.integers(0, 30_000, 400)
    near = base.copy()
    near[::50] = rng.integers(0, 30_000, len(near[::50]))  # ~2% token edits
    other = rng.integers(0, 30_000, 400)
    seqs = [base, near, other]
    dups = near_duplicates(seqs, max_rate=0.15)
    pairs = {(i, j) for i, j, _ in dups}
    assert (0, 1) in pairs
    assert (0, 2) not in pairs and (1, 2) not in pairs
    keep = dedup_filter(seqs, max_rate=0.15)
    assert keep == [0, 2]
