"""Forced-multi-device parity: the sharded fused Pallas hot path must be
BIT-identical to the single-device run.

`XLA_FLAGS=--xla_force_host_platform_device_count=8` must be set before
jax import (and must not leak into the other single-device tests), so
every test here re-execs a subprocess, same as tests/test_distributed.py.

What tier-1 proves (one subprocess, the differential corpus profiles):
  * GenASMAligner(mesh=...) with backend='pallas_fused' + on-device
    k-doubling rescue == the mesh=None run on every output (ops, dist,
    k_used, failed, cigars, read/ref consumption) — including a ragged
    batch (B=30 is not a multiple of lane_tile * n_devices, so the kernel
    dispatch pads globally and shards evenly) and a rescue ladder where
    only SOME shards hold failed lanes (the round gate is a global any);
  * the sharded ladder still costs exactly 1 upload + 1 download;
  * the Scrooge-style banded tail store (tail_store='band', forced at
    the no-strict-win fallback boundary) is bit-identical on the mesh;
  * the collapsed make_align_step factory: sharded summaries == eager
    single-device summaries, and per-lane outputs actually land sharded
    over all 8 devices;
  * serve.AlignmentEngine(mesh=...): ragged request streams are padded to
    pair_pad_multiple = lane_tile * n_devices (equal, tile-aligned shards)
    and padding lanes never reach results or summary stats;
  * repro.api session with executor='thread' on the mesh: the background
    retire executor (host decode + compacted bucket-rescue rungs running
    on the retire thread against mesh-sharded executables) stays
    bit-identical to the single-device baseline, and shuts down cleanly.

A second tier-1 subprocess proves the same contract for the Triton
lowering: backend='pallas_gpu' (interpret mode on these forced-host
devices) sharded over the 8-device mesh == unsharded, bit for bit, with
the GPU pad quantum (lane_tile * n_devices via PALLAS_BACKENDS) applied.

The nightly (@slow) sweep extends the same parity to the jnp and split
pallas backends, the host rescue mode, a 2-D ('data','model') mesh and
the plain (no-rescue) factory.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# shared by both subprocess scripts: corpus + cfg + mesh + base aligner run
PRELUDE = """
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core.aligner import GenASMAligner
    from repro.core.config import AlignerConfig
    from repro.core import transfer
    from repro.launch.mesh import make_test_mesh
    from tests.test_differential import make_corpus

    def assert_bit_identical(a, b, label):
        assert list(a.dist) == list(b.dist), label
        assert list(a.failed) == list(b.failed), label
        assert list(a.k_used) == list(b.k_used), label
        assert list(a.read_consumed) == list(b.read_consumed), label
        assert list(a.ref_consumed) == list(b.ref_consumed), label
        assert a.cigars == b.cigars, label
        for i, (x, y) in enumerate(zip(a.ops, b.ops)):
            np.testing.assert_array_equal(x, y, err_msg=f"{label} lane {i}")
"""


def run_py(code: str, n_dev: int = 8, timeout=480):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=REPO)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_sharded_fused_rescue_bit_identical_and_engine_padding():
    out = run_py(PRELUDE + """
    cfg = AlignerConfig(W=16, O=6, k=4, lane_tile=4)
    mesh = make_test_mesh((8,), ('data',))
    n_shards = 8
    reads, refs, profs = make_corpus(seed=20260727, n_per_profile=6)
    B = len(reads)
    assert B == 30 and B % (cfg.lane_tile * n_shards) != 0   # ragged batch

    # ---- single-device baseline vs sharded run: bit-identical ----
    base = GenASMAligner(cfg, rescue_rounds=1,
                         backend='pallas_fused').align(reads, refs)
    transfer.reset()
    shard = GenASMAligner(cfg, rescue_rounds=1, backend='pallas_fused',
                          mesh=mesh).align(reads, refs)
    ts = transfer.stats()
    assert (ts.h2d_calls, ts.d2h_calls) == (1, 1), ts   # no per-round trips
    assert_bit_identical(shard, base, 'sharded pallas_fused')

    # the corpus must really exercise the rescue ladder, with failed lanes
    # in only SOME shards (the kernel pads B=30 -> 32, 4 lanes per shard)
    assert (base.k_used[~base.failed] > cfg.k).any()
    failed_shards = {i // 4 for i in range(B) if base.failed[i]}
    assert failed_shards and len(failed_shards) < n_shards
    print('PARITY OK', int(base.failed.sum()),
          int((base.k_used > cfg.k).sum()))

    # ---- banded tail store on the mesh: same contract ----
    # at this geometry the band is no strict win (nwb == nw), so 'auto'
    # picks the full store — force 'band' so the Scrooge-style tail body
    # itself runs under the 8-device shard_map, at the fallback boundary
    import dataclasses
    cfg_band = dataclasses.replace(cfg, tail_store='band')
    assert not cfg.tail_band_supported and cfg_band.tail_banded
    band = GenASMAligner(cfg_band, rescue_rounds=1, backend='pallas_fused',
                         mesh=mesh).align(reads, refs)
    assert_bit_identical(band, base, 'sharded banded tail')
    print('BAND OK')

    # ---- engine: ragged 13-request stream on the mesh ----
    from repro.serve.engine import AlignmentEngine, AlignRequest
    eng = AlignmentEngine(cfg, batch_size=13, rescue_rounds=1,
                          backend='pallas_fused', mesh=mesh)
    assert eng.pad_multiple == cfg.lane_tile * n_shards == 32
    assert eng.batch_size == 32        # quantised up at construction
    seen = []
    orig = eng.aligner.align
    eng.aligner.align = lambda r, f: (seen.append(len(r)), orig(r, f))[1]
    for i in range(13):
        eng.submit(AlignRequest(rid=i, read=reads[i], ref=refs[i]))
    stats = eng.serve_until_empty()
    assert seen == [32]                               # equal 4-lane shards
    assert stats['batches'] == 1 and stats['padded_lanes'] == 19
    assert stats['aligned'] + stats['failed'] == 13   # pads never counted
    assert set(eng.results) == set(range(13))
    for i in range(13):
        assert eng.results[i]['ok'] == (not base.failed[i])
        if not base.failed[i]:
            assert eng.results[i]['dist'] == int(base.dist[i])
            assert eng.results[i]['cigar'] == base.cigars[i]
    print('ENGINE OK', stats['aligned'], stats['failed'])

    # ---- session front door: background retire executor on the mesh ----
    # the threaded executor must stay bit-identical with every mesh-
    # sharded executable AND with compacted bucket rescue retiring on the
    # background thread (lane classes quantise to lane_tile * 8 = 32)
    from repro.api import plan
    with plan(cfg, backend='pallas_fused', rescue_rounds=1,
              rescue_mode='bucket', batch_lanes=16, executor='thread',
              mesh=mesh) as ses:
        assert ses.spec.batch_lanes == 32          # mesh lane quantum
        futs = [ses.submit(r, f) for r, f in zip(reads, refs)]
        ses.flush()
        recs = [f.result() for f in futs]
    for i in range(B):
        assert recs[i]['ok'] == (not base.failed[i]), i
        if recs[i]['ok']:
            assert recs[i]['dist'] == int(base.dist[i]), i
            assert recs[i]['cigar'] == base.cigars[i], i
            assert recs[i]['k_used'] == int(base.k_used[i]), i
    assert ses.stats['rescue_dispatches'] >= 1     # rungs ran on the thread
    assert ses._retire_thread is None              # clean shutdown
    print('SESSION-THREAD OK', ses.stats['dispatches'],
          ses.stats['rescue_dispatches'])

    # ---- collapsed factory: sharded summaries == single-device ----
    from repro.core.windowing import (SENTINEL_READ, SENTINEL_REF,
                                      rescue_schedule, self_tail_width)
    from repro.serve.align_step import align_step, make_align_step
    from jax.sharding import NamedSharding, PartitionSpec as P
    b13 = [(reads[i], refs[i]) for i in range(13)]
    b32 = b13 + [b13[-1]] * 19                 # the engine's padded batch
    L = max(len(r) for r, _ in b32)
    wt = self_tail_width(rescue_schedule(cfg, 1)[-1])
    Lf = max(len(f) for _, f in b32) + cfg.W + wt + 1
    rp = np.full((32, L + cfg.W + 1), SENTINEL_READ, np.uint8)
    fp = np.full((32, Lf), SENTINEL_REF, np.uint8)
    rl = np.zeros(32, np.int32); fl = np.zeros(32, np.int32)
    for i, (r, f) in enumerate(b32):
        rp[i, :len(r)] = r; rl[i] = len(r)
        fp[i, :len(f)] = f; fl[i] = len(f)
    ref_out, ref_sum = align_step(jnp.array(rp), jnp.array(rl),
                                  jnp.array(fp), jnp.array(fl), cfg=cfg,
                                  max_read_len=L, rescue_rounds=1)
    stepf = make_align_step(cfg, L, mesh, rescue_rounds=1)
    bsh = NamedSharding(mesh, P(('data',), None))
    vsh = NamedSharding(mesh, P(('data',)))
    args = (jax.device_put(jnp.array(rp), bsh), jax.device_put(jnp.array(rl), vsh),
            jax.device_put(jnp.array(fp), bsh), jax.device_put(jnp.array(fl), vsh))
    out, summ = stepf(*args)
    assert len(out['dist'].sharding.device_set) == 8   # really distributed
    for key in ('ops', 'n_ops', 'dist', 'failed', 'k_used',
                'read_consumed', 'ref_consumed', 'rounds_run'):
        np.testing.assert_array_equal(np.asarray(out[key]),
                                      np.asarray(ref_out[key]), err_msg=key)
    for key in ('n_failed', 'n_rescued', 'total_edits', 'total_ops',
                'rounds_run'):
        assert int(summ[key]) == int(ref_sum[key]), key
    print('FACTORY OK', int(summ['n_failed']), int(summ['total_edits']))
    """)
    assert "PARITY OK" in out and "ENGINE OK" in out and "FACTORY OK" in out
    assert "SESSION-THREAD OK" in out and "BAND OK" in out


def test_sharded_gpu_backend_bit_identical():
    """backend='pallas_gpu' (the Triton lowering, interpret mode on these
    forced-host devices) sharded over the 8-device mesh == unsharded, bit
    for bit, on the ragged differential corpus — including the GPU pad
    quantum: pair_pad_multiple = lane_tile * n_devices applies to
    pallas_gpu exactly as to the TPU backends (PALLAS_BACKENDS)."""
    out = run_py(PRELUDE + """
    from repro.distributed.sharding import pair_pad_multiple

    cfg = AlignerConfig(W=16, O=6, k=4, lane_tile=4, backend='pallas_gpu')
    mesh = make_test_mesh((8,), ('data',))
    reads, refs, profs = make_corpus(seed=20260727, n_per_profile=6)
    assert len(reads) == 30                              # ragged vs 4*8
    assert pair_pad_multiple(cfg, mesh) == 32            # GPU pad quantum

    base = GenASMAligner(cfg, rescue_rounds=1).align(reads, refs)
    transfer.reset()
    shard = GenASMAligner(cfg, rescue_rounds=1, mesh=mesh).align(reads, refs)
    ts = transfer.stats()
    assert (ts.h2d_calls, ts.d2h_calls) == (1, 1), ts    # no per-round trips
    assert_bit_identical(shard, base, 'sharded pallas_gpu')
    assert (base.k_used[~base.failed] > cfg.k).any()     # rescue exercised
    print('GPU PARITY OK', int(base.failed.sum()))
    """)
    assert "GPU PARITY OK" in out


@pytest.mark.slow
def test_sharded_parity_all_backends_and_meshes():
    """Nightly sweep: jnp + split-pallas backends, host rescue mode, the
    plain (no-rescue) factory and a 2-D mesh whose 'model' axis the pair
    sharding must ignore — all bit-identical to single-device."""
    out = run_py(PRELUDE + """
    from repro.core.windowing import (SENTINEL_READ, SENTINEL_REF,
                                      self_tail_width)
    from repro.serve.align_step import align_step, make_align_step
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = AlignerConfig(W=16, O=6, k=4, lane_tile=4)
    mesh = make_test_mesh((8,), ('data',))
    reads, refs, profs = make_corpus(seed=77, n_per_profile=8, read_len=48)
    B = len(reads)
    assert B == 40 and B % 8 == 0   # jnp GSPMD constraint path engages

    for backend in ('jnp', 'pallas'):
        base = GenASMAligner(cfg, rescue_rounds=2,
                             backend=backend).align(reads, refs)
        shard = GenASMAligner(cfg, rescue_rounds=2, backend=backend,
                              mesh=mesh).align(reads, refs)
        assert_bit_identical(shard, base, backend)
        print('OK backend', backend)

    # legacy host rescue loop, sharded per round
    base_h = GenASMAligner(cfg, rescue_rounds=1,
                           rescue_mode='host').align(reads, refs)
    shard_h = GenASMAligner(cfg, rescue_rounds=1, rescue_mode='host',
                            mesh=mesh).align(reads, refs)
    assert_bit_identical(shard_h, base_h, 'host rescue')
    print('OK host rescue')

    # 2-D mesh: pair axis shards over 'data' (4), 'model' axis ignored
    mesh2 = make_test_mesh((4, 2), ('data', 'model'))
    base_f = GenASMAligner(cfg, rescue_rounds=1,
                           backend='pallas_fused').align(reads, refs)
    shard_f = GenASMAligner(cfg, rescue_rounds=1, backend='pallas_fused',
                            mesh=mesh2).align(reads, refs)
    assert_bit_identical(shard_f, base_f, '2d mesh pallas_fused')
    print('OK 2d mesh')

    # plain factory (rescue_rounds=None): summaries + lanes match eager
    L = max(len(r) for r in reads)
    wt = self_tail_width(cfg)
    rp = np.full((B, L + cfg.W + 1), SENTINEL_READ, np.uint8)
    fp = np.full((B, max(len(f) for f in refs) + cfg.W + wt + 1),
                 SENTINEL_REF, np.uint8)
    rl = np.zeros(B, np.int32); fl = np.zeros(B, np.int32)
    for i, (r, f) in enumerate(zip(reads, refs)):
        rp[i, :len(r)] = r; rl[i] = len(r)
        fp[i, :len(f)] = f; fl[i] = len(f)
    ref_out, ref_sum = align_step(jnp.array(rp), jnp.array(rl),
                                  jnp.array(fp), jnp.array(fl), cfg=cfg,
                                  max_read_len=L)
    stepf = make_align_step(cfg, L, mesh)
    bsh = NamedSharding(mesh, P(('data',), None))
    vsh = NamedSharding(mesh, P(('data',)))
    out, summ = stepf(jax.device_put(jnp.array(rp), bsh),
                      jax.device_put(jnp.array(rl), vsh),
                      jax.device_put(jnp.array(fp), bsh),
                      jax.device_put(jnp.array(fl), vsh))
    for key in ('ops', 'n_ops', 'dist', 'failed'):
        np.testing.assert_array_equal(np.asarray(out[key]),
                                      np.asarray(ref_out[key]), err_msg=key)
    for key in ('n_failed', 'total_edits', 'total_ops'):
        assert int(summ[key]) == int(ref_sum[key]), key
    print('OK plain factory')
    """, timeout=560)
    for tag in ("OK backend jnp", "OK backend pallas", "OK host rescue",
                "OK 2d mesh", "OK plain factory"):
        assert tag in out
