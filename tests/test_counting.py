"""The paper's quantitative claims: analytic footprint/access counters,
validated against an instrumented (empirically counted) implementation."""
import numpy as np
import pytest

from repro.core.config import AlignerConfig
from repro.core.counting import (baseline_counts, improved_counts,
                                 reduction_report, sene_only_counts)


def empirical_baseline_writes(cfg):
    """Count words an instrumented unimproved GenASM-TB would write:
    4 edge vectors x NW words per (column, level)."""
    writes = 0
    for j in range(cfg.W):
        for d in range(cfg.k + 1):
            writes += 4 * cfg.nw
    return writes


def empirical_improved_writes(cfg, levels_run):
    writes = 0
    for j in range(cfg.ncols_band):
        for d in range(levels_run):
            writes += cfg.nwb
    return writes


def test_counter_formulas_match_empirical():
    for W, O, k in ((64, 24, 12), (64, 24, 16), (128, 48, 15)):
        cfg = AlignerConfig(W=W, O=O, k=k)
        assert baseline_counts(cfg, 10).dc_writes == \
            empirical_baseline_writes(cfg)
        for lv in (3, 7, k + 1):
            assert improved_counts(cfg, 10, lv).dc_writes == \
                empirical_improved_writes(cfg, lv)


def test_paper_magnitude_claims():
    """Paper: 24x footprint, 12x fewer accesses.  With the default config
    (W=64 O=24 k=12, 32-bit words) and the measured average of ~7 levels
    per window the reductions land in the paper's regime."""
    cfg = AlignerConfig(W=64, O=24, k=12)
    rep = reduction_report(cfg, avg_levels=7.0)
    assert rep["footprint_reduction_touched"] > 15.0
    assert rep["access_reduction"] > 8.0
    # SENE alone is exactly 4x on writes
    base = baseline_counts(cfg, 40)
    sene = sene_only_counts(cfg, 40)
    assert base.dc_writes / sene.dc_writes == 4.0
    # improved working set fits on chip for a 512-problem tile
    assert rep["vmem_bytes_per_problem"] * 512 < 16 * 2**20


def test_reductions_monotone_in_k():
    """Larger k (more levels) -> ET saves more; DENT band grows with k."""
    r_small = reduction_report(AlignerConfig(W=64, O=24, k=8), avg_levels=5.0)
    r_big = reduction_report(AlignerConfig(W=64, O=24, k=24), avg_levels=5.0)
    assert r_big["footprint_reduction_touched"] > \
        r_small["footprint_reduction_touched"] * 0.9
