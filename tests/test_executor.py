"""The serving executor (repro.api): retire thread, shared cache, adaptive
batching, poisoning.

Claims enforced:
  * the background retire executor (executor='thread') is bit-identical to
    the synchronous executor on the differential corpus — jnp + compacted
    bucket rescue here, pallas_fused (incl. rescue rungs retired on the
    thread) below, and the forced-8-device mesh leg rides the subprocess
    suite in tests/test_multidevice.py.  The executor reorders work in
    time, never in value;
  * the retire queue is bounded at spec.max_inflight (backpressure) and
    shutdown is clean: close() drains, joins the thread, is idempotent,
    and a closed session refuses submits;
  * exceptions are never lost: a raising retire/dispatch poisons the
    session — the owning dispatch's futures carry the original exception,
    every other outstanding future fails with SessionPoisonedError instead
    of waiting forever (the PR-5 bugfix for mid-stream dispatch failures),
    and later submits refuse;
  * the process-shared CompileCache: same-spec sessions lower each bucket
    exactly once total, different specs never cross-contaminate, and
    per-session counters reconcile with the process store's;
  * occupancy-adaptive lane classes shrink on sparse traffic, dispatch
    without waiting for the static ceiling, grow back under pressure —
    and change padding only (results bit-identical to the static twin).
"""
import threading
import time

import numpy as np
import pytest

from repro.api import (CompileCache, SessionPoisonedError, plan,
                       shared_compile_cache)
from repro.core.aligner import AlignResult
from tests.test_differential import CFG as DCFG, ROUNDS


def _assert_results_equal(a: AlignResult, b: AlignResult):
    np.testing.assert_array_equal(a.failed, b.failed)
    np.testing.assert_array_equal(a.dist, b.dist)
    np.testing.assert_array_equal(a.k_used, b.k_used)
    np.testing.assert_array_equal(a.read_consumed, b.read_consumed)
    np.testing.assert_array_equal(a.ref_consumed, b.ref_consumed)
    assert a.cigars == b.cigars
    for x, y in zip(a.ops, b.ops):
        np.testing.assert_array_equal(x, y)


def _exact_pairs(rng, n, length):
    reads = [rng.integers(0, 4, length).astype(np.uint8) for _ in range(n)]
    return reads, [r.copy() for r in reads]


# --------------------------------------------------------------------------
# bit-identity: threaded retire vs synchronous executor
# --------------------------------------------------------------------------

def test_threaded_retire_bit_identical_to_sync_differential(corpus,
                                                            diff_aligned):
    """THE executor parity claim, on the differential corpus with small
    dispatches (batch_lanes=8 splits the 30 pairs into several concurrent
    dispatches) and compacted bucket rescue running ON the retire thread.
    Same submission order => same dispatch grouping, so the threaded
    session must also be a pure cache hit on the sync session's
    executables (cross-session sharing under concurrency)."""
    reads, refs, _ = corpus
    base = diff_aligned("jnp")
    kw = dict(rescue_rounds=ROUNDS, rescue_mode="bucket", batch_lanes=8)
    sync = plan(DCFG, **kw)
    res_sync = sync.align(reads, refs)
    with plan(DCFG, executor="thread", **kw) as thr:
        futs = [thr.submit(r, f) for r, f in zip(reads, refs)]
        thr.flush()
        # collect out of order: late futures first
        recs = [f.result() for f in reversed(futs)][::-1]
        st = thr.session_stats()
    res_thr = AlignResult.from_records(recs)
    _assert_results_equal(res_sync, base)    # sync session == legacy door
    _assert_results_equal(res_thr, res_sync)  # threaded == sync, bit for bit
    assert st["dispatches"] >= 3             # genuinely streamed
    assert st["retire_wall_s"] > 0           # decode really ran off-thread
    # the threaded session lowered NOTHING: every executable (incl. the
    # rescue-rung lane classes) came from the process-shared store
    cs = thr.cache.stats()
    assert cs["lowerings"] == 0 and cs["shared_hits"] > 0
    assert thr._retire_thread is None        # context manager closed it


def test_threaded_retire_bit_identical_pallas_fused_rescue():
    """Same parity for the fused Pallas backend, with a decoy pair that
    keeps the k-doubling ladder alive so compacted rescue rounds
    (dispatch + download + merge) execute on the retire thread."""
    from tests.test_rescue import CFG as RCFG, _mk_corpus
    reads, refs = _mk_corpus(seed=5, n=4)    # err gradient + decoy
    store = CompileCache()                   # hermetic sharing for the test
    kw = dict(backend="pallas_fused", rescue_rounds=1, rescue_mode="bucket",
              batch_lanes=4, cache=store)
    sync = plan(RCFG, **kw)
    res_sync = sync.align(reads, refs)
    with plan(RCFG, executor="thread", **kw) as thr:
        futs = [thr.submit(r, f) for r, f in zip(reads, refs)]
        thr.flush()
        recs = [f.result() for f in futs]
    res_thr = AlignResult.from_records(recs)
    _assert_results_equal(res_thr, res_sync)
    assert res_sync.failed[-1]               # the decoy kept rescue running
    assert thr.stats["rescue_dispatches"] >= 1   # ... on the retire thread
    assert thr.cache.lowerings == 0          # all rungs shared from sync
    assert store.lowerings == sync.cache.lowerings


# --------------------------------------------------------------------------
# bounded queue, clean shutdown
# --------------------------------------------------------------------------

def test_retire_queue_bounded_and_clean_shutdown(rng):
    reads, refs = _exact_pairs(rng, 8, 24)
    s = plan(DCFG, rescue_rounds=0, batch_lanes=2, max_inflight=2,
             executor="thread")
    futs = [s.submit(r, f) for r, f in zip(reads, refs)]
    # the retire queue IS the backpressure: bounded at max_inflight
    assert s._retire_q is not None and s._retire_q.maxsize == 2
    t = s._retire_thread
    assert t is not None and t.is_alive() and t.daemon
    s.close()                                # drains, then joins the thread
    assert not t.is_alive() and s._retire_thread is None
    assert all(f.done() for f in futs)
    assert all(f.result()["dist"] == 0 for f in futs)   # exact matches
    with pytest.raises(RuntimeError):
        s.submit(reads[0], refs[0])          # closed sessions refuse
    s.close()                                # idempotent
    assert threading.active_count() >= 1     # no leaked retire threads wait


def test_retire_thread_exception_propagates_and_poisons(rng):
    """Exceptions from the retire thread land in the owning futures (the
    original exception), fail every other outstanding future with
    SessionPoisonedError, and refuse later submits — never lost, never a
    hang."""
    (r24a, r24b), (f24a, f24b) = _exact_pairs(rng, 2, 24)
    (r100,), (f100,) = _exact_pairs(rng, 1, 100)
    s = plan(DCFG, rescue_rounds=0, batch_lanes=2, executor="thread")
    boom = RuntimeError("decode exploded")

    def _boom(d):
        raise boom

    s._retire = _boom
    fa = s.submit(r24a, f24a)
    fq = s.submit(r100, f100)          # different bucket: stays queued
    fb = s.submit(r24b, f24b)          # fills the 24-bucket -> dispatch
    with pytest.raises(RuntimeError, match="decode exploded"):
        fa.result()                    # owning future: the original error
    with pytest.raises(RuntimeError, match="decode exploded"):
        fb.result()
    with pytest.raises(SessionPoisonedError):
        fq.result()                    # innocent bystander: poisoned, not hung
    with pytest.raises(SessionPoisonedError):
        s.submit(r24a, f24a)
    with pytest.raises(SessionPoisonedError):
        s.results()
    s.close(drain=False)               # clean shutdown even when poisoned
    assert s._retire_thread is None


def test_close_without_drain_fails_queued_futures_sync(rng):
    """close(drain=False) abandons queued work on BOTH executors: the
    futures fail fast instead of waiting (or erroring obscurely) forever."""
    (r,), (f,) = _exact_pairs(rng, 1, 24)
    s = plan(DCFG, rescue_rounds=0, batch_lanes=4, cache="private")
    fut = s.submit(r, f)               # queued, never dispatched
    s.close(drain=False)
    assert fut.done()
    with pytest.raises(SessionPoisonedError):
        fut.result()
    assert s.cache.lowerings == 0      # nothing was built for abandoned work


def test_sync_dispatch_failure_poisons_outstanding_futures(rng):
    """The PR-5 bugfix: a dispatch raising mid-stream used to leave futures
    of OTHER buckets waiting forever; now they fail fast with
    SessionPoisonedError while the failing batch carries the original
    exception."""
    (r24,), (f24,) = _exact_pairs(rng, 1, 24)
    (r100a, r100b), (f100a, f100b) = _exact_pairs(rng, 2, 100)
    s = plan(DCFG, rescue_rounds=0, batch_lanes=2, cache="private")
    f_other = s.submit(r24, f24)       # 24-bucket: queued, never dispatched

    def _boom(*a, **k):
        raise ValueError("lowering failed")

    s._executable = _boom
    g1 = s.submit(r100a, f100a)
    with pytest.raises(ValueError, match="lowering failed"):
        s.submit(r100b, f100b)         # fills the 100-bucket -> dispatch
    with pytest.raises(ValueError):
        g1.result()                    # owning batch: original exception
    with pytest.raises(SessionPoisonedError):
        f_other.result()               # used to wait forever; now fails fast
    with pytest.raises(SessionPoisonedError):
        s.submit(r24, f24)
    assert s.cache.lowerings == 0      # nothing was ever built


# --------------------------------------------------------------------------
# process-shared CompileCache
# --------------------------------------------------------------------------

def test_same_spec_sessions_lower_each_bucket_once_total(rng):
    """Multi-tenant serving: N sessions of one spec lower each (bucket,
    lane class) exactly once per store; different specs never
    cross-contaminate; per-session counters reconcile with the store."""
    reads24, refs24 = _exact_pairs(rng, 2, 24)     # bucket (32, 32)
    reads40, refs40 = _exact_pairs(rng, 2, 40)     # bucket (64, 64)
    reads = reads24 + reads40
    refs = refs24 + refs40
    store = CompileCache()
    kw = dict(rescue_rounds=0, batch_lanes=2, cache=store)
    a = plan(DCFG, **kw)
    assert not a.align(reads, refs).failed.any()
    sa = a.cache.stats()
    assert sa["misses"] == sa["lowerings"] == sa["executables"] == 2
    assert sa["hits"] == sa["shared_hits"] == 0
    b = plan(DCFG, **kw)                           # same spec, same store
    assert not b.align(reads, refs).failed.any()
    sb = b.cache.stats()
    # the tenancy claim: B lowered NOTHING — both buckets were shared
    assert sb["lowerings"] == sb["misses"] == 0
    assert sb["hits"] == sb["shared_hits"] == sb["executables"] == 2
    ss = store.stats()
    assert ss["lowerings"] == ss["executables"] == 2
    # counters reconcile: per-session sums == process store
    assert sa["hits"] + sb["hits"] == ss["hits"]
    assert sa["misses"] + sb["misses"] == ss["misses"]
    assert sa["lowerings"] + sb["lowerings"] == ss["lowerings"]
    # a DIFFERENT spec on the same store: new keys, no contamination
    c = plan(DCFG, k=6, **kw)
    assert not c.align(reads24, refs24).failed.any()
    assert c.cache.stats()["lowerings"] == 1       # its own executable
    assert not (c.cache._seen & a.cache._seen)     # disjoint key spaces
    assert store.stats()["executables"] == 3
    # steady state: a second pass anywhere lowers nothing more
    a.align(reads, refs)
    assert store.stats()["lowerings"] == 3


def test_compile_cache_builds_per_key_without_head_of_line_blocking():
    """The store lock only reserves keys: a slow lowering on one key must
    not stall fetches of unrelated keys (multi-tenant cold starts), while
    a racer on the SAME key waits and then hits — one build total.  Failed
    builds release the key for retry."""
    store = CompileCache()
    started, release = threading.Event(), threading.Event()
    out = {}

    def slow_build():
        started.set()
        assert release.wait(10)
        return "slow-exe"

    t1 = threading.Thread(
        target=lambda: out.setdefault("slow", store.fetch("k1", slow_build)))
    t1.start()
    assert started.wait(10)
    # k1 is mid-build: an unrelated key fetches immediately (no global lock)
    assert store.fetch("k2", lambda: "fast-exe") == ("fast-exe", True)
    # a same-key racer parks until the build lands, then shares it
    t2 = threading.Thread(
        target=lambda: out.setdefault("race", store.fetch("k1",
                                                          lambda: "never")))
    t2.start()
    time.sleep(0.05)
    assert "race" not in out           # really waiting on k1
    release.set()
    t1.join(10), t2.join(10)
    assert out["slow"] == ("slow-exe", True)
    assert out["race"] == ("slow-exe", False)   # shared, not rebuilt
    assert store.lowerings == 2 and len(store) == 2

    def bad():
        raise RuntimeError("lowering exploded")

    with pytest.raises(RuntimeError):
        store.fetch("k3", bad)
    assert store.fetch("k3", lambda: "ok-now") == ("ok-now", True)


def test_default_cache_is_process_shared():
    s1 = plan(DCFG, rescue_rounds=0, batch_lanes=2)
    s2 = plan(DCFG, rescue_rounds=0, batch_lanes=2)
    assert s1.cache.store is s2.cache.store is shared_compile_cache()
    assert plan(DCFG, cache="private").cache.store \
        is not shared_compile_cache()
    # equal specs key equal (content-hashed), unequal specs don't
    assert s1.spec.key() == s2.spec.key()
    assert plan(DCFG, k=6).spec.key() != s1.spec.key()


# --------------------------------------------------------------------------
# occupancy-adaptive lane classes
# --------------------------------------------------------------------------

def test_adaptive_lanes_shrink_regrow_and_stay_bit_identical(rng):
    """Sparse traffic steps the lane class down the quantised ladder (so a
    half-empty bucket stops padding to batch_lanes), a saturated bucket
    steps back up to the ceiling — and none of it changes values, only
    padding (results == the static twin's on the same stream)."""
    from tests.conftest import mutate_seq
    refs = [rng.integers(0, 4, 26).astype(np.uint8) for _ in range(26)]
    reads = [mutate_seq(f, 2, rng) for f in refs]   # nontrivial CIGARs
    kw = dict(rescue_rounds=1, batch_lanes=8)
    ada = plan(DCFG, adaptive_lanes=True, occupancy_window=2, **kw)
    sta = plan(DCFG, **kw)
    bucket = ada.bucket_for(26, 26)
    assert ada._current_lanes(bucket) == 8
    futs = []
    # phase 1 — sparse: 4 flushed pairs; the window shows fill 2 twice per
    # class, stepping 8 -> 4 -> 2
    for j in range(4):
        futs += [ada.submit(reads[2 * j + i], refs[2 * j + i])
                 for i in range(2)]
        ada.flush()
    assert ada._current_lanes(bucket) == 2
    assert ada.stats["lane_class_steps"] == 2
    # phase 2 — at the shrunk class, a pair dispatches WITHOUT flush()
    d0 = ada.stats["dispatches"]
    futs += [ada.submit(reads[8 + i], refs[8 + i]) for i in range(2)]
    assert ada.stats["dispatches"] == d0 + 1       # fired at class 2
    # phase 3 — sustained pressure saturates each class and grows back
    futs += [ada.submit(reads[10 + i], refs[10 + i]) for i in range(16)]
    ada.flush()
    assert ada._current_lanes(bucket) == 8         # back at the ceiling
    assert ada.stats["lane_class_steps"] >= 4
    recs = [f.result() for f in futs]
    occ = ada.session_stats()["occupancy"]
    assert occ[str(bucket)]["lane_class"] == 8
    # the static twin sees the same stream (flushes at the same points)
    sfuts = []
    for j in range(4):
        sfuts += [sta.submit(reads[2 * j + i], refs[2 * j + i])
                  for i in range(2)]
        sta.flush()
    sfuts += [sta.submit(reads[8 + i], refs[8 + i]) for i in range(2)]
    sfuts += [sta.submit(reads[10 + i], refs[10 + i]) for i in range(16)]
    sta.flush()
    srecs = [f.result() for f in sfuts]
    _assert_results_equal(AlignResult.from_records(recs),
                          AlignResult.from_records(srecs))
    assert sta.stats["lane_class_steps"] == 0      # static stayed static


# --------------------------------------------------------------------------
# occupancy-adaptive in-flight window
# --------------------------------------------------------------------------

def test_adaptive_inflight_widens_narrows_and_stays_bit_identical(rng):
    """The in-flight window follows the same sliding occupancy signal as
    lane classes, session-wide: saturated dispatches widen max_inflight by
    one per full window up to inflight_ceiling; all-partial (flush-driven)
    windows narrow it toward 1 — and like lane classes it is purely a
    scheduling choice (results == the static twin's on the same stream)."""
    from tests.conftest import mutate_seq
    refs = [rng.integers(0, 4, 26).astype(np.uint8) for _ in range(22)]
    reads = [mutate_seq(f, 2, rng) for f in refs]
    kw = dict(rescue_rounds=1, batch_lanes=2)
    ada = plan(DCFG, adaptive_inflight=True, inflight_ceiling=3,
               max_inflight=1, occupancy_window=2, **kw)
    sta = plan(DCFG, max_inflight=1, **kw)
    assert ada._max_inflight == 1
    futs = []
    # phase 1 — saturation: 8 pairs = 4 full dispatches at batch_lanes=2;
    # each full window of 2 widens by one: 1 -> 2 -> 3 (the ceiling)
    futs += [ada.submit(reads[i], refs[i]) for i in range(8)]
    assert ada._max_inflight == 3
    assert ada.stats["inflight_steps"] == 2
    # phase 2 — more pressure cannot exceed the ceiling
    futs += [ada.submit(reads[8 + i], refs[8 + i]) for i in range(4)]
    assert ada._max_inflight == 3
    # phase 3 — sparse: flush-driven singles narrow back toward 1
    for j in range(4):
        futs.append(ada.submit(reads[12 + j], refs[12 + j]))
        ada.flush()
    assert ada._max_inflight == 1
    assert ada.stats["inflight_steps"] == 4
    st = ada.session_stats()
    assert st["inflight"]["max_inflight"] == 1
    assert st["inflight"]["ceiling"] == 3
    recs = [f.result() for f in futs]
    # the static twin sees the same stream (flushes at the same points)
    sfuts = [sta.submit(reads[i], refs[i]) for i in range(12)]
    for j in range(4):
        sfuts.append(sta.submit(reads[12 + j], refs[12 + j]))
        sta.flush()
    sta.flush()
    srecs = [f.result() for f in sfuts]
    _assert_results_equal(AlignResult.from_records(recs),
                          AlignResult.from_records(srecs))
    assert sta.stats["inflight_steps"] == 0        # static stayed static
    assert "inflight" not in sta.session_stats()


def test_adaptive_inflight_threaded_queue_at_ceiling_and_clean(rng):
    """Threaded executor under an adaptive in-flight window: the retire
    queue is allocated at the CEILING (widening never reallocates), the
    current bound governs backpressure, results match the sync twin, and
    shutdown stays clean."""
    reads, refs = _exact_pairs(rng, 8, 24)
    kw = dict(rescue_rounds=0, batch_lanes=2, max_inflight=1,
              adaptive_inflight=True, inflight_ceiling=4,
              occupancy_window=2)
    with plan(DCFG, executor="thread", **kw) as s:
        futs = [s.submit(r, f) for r, f in zip(reads, refs)]
        s.flush()
        assert s._retire_q.maxsize == 4            # ceiling, not max_inflight
        recs = [f.result() for f in futs]
        assert s._max_inflight > 1                 # saturation widened it
    assert s._retire_thread is None                # close joined the thread
    assert all(r["dist"] == 0 for r in recs)       # exact matches


def test_adaptive_inflight_preserves_poison_semantics(rng):
    """Poison-on-exception is unchanged under adaptive sizing: a raising
    retire fails its own futures with the original exception, bystanders
    with SessionPoisonedError, and later submits refuse."""
    (r24a, r24b), (f24a, f24b) = _exact_pairs(rng, 2, 24)
    (r100,), (f100,) = _exact_pairs(rng, 1, 100)
    s = plan(DCFG, rescue_rounds=0, batch_lanes=2, executor="thread",
             adaptive_inflight=True, inflight_ceiling=4)
    boom = RuntimeError("decode exploded")

    def _boom(d):
        raise boom

    s._retire = _boom
    fa = s.submit(r24a, f24a)
    fq = s.submit(r100, f100)          # different bucket: stays queued
    fb = s.submit(r24b, f24b)          # fills the 24-bucket -> dispatch
    with pytest.raises(RuntimeError, match="decode exploded"):
        fa.result()
    with pytest.raises(RuntimeError, match="decode exploded"):
        fb.result()
    with pytest.raises(SessionPoisonedError):
        fq.result()
    with pytest.raises(SessionPoisonedError):
        s.submit(r24a, f24a)
    s.close(drain=False)
    assert s._retire_thread is None


# --------------------------------------------------------------------------
# AlignFuture.result(timeout=) + cancel() (the PR-8 gateway primitives)
# --------------------------------------------------------------------------

def test_result_timeout_then_fulfill(rng):
    """result(timeout=) bounds the WAIT, not the future: a timed-out
    future stays collectable and fulfills normally once the (gated)
    retire thread gets to it.  The gate is an Event, not a sleep."""
    reads, refs = _exact_pairs(rng, 2, 24)
    s = plan(DCFG, rescue_rounds=0, batch_lanes=2, executor="thread",
             cache="private")
    gate = threading.Event()
    orig = s._retire

    def gated(d):
        gate.wait(30)
        orig(d)

    s._retire = gated
    futs = [s.submit(r, f) for r, f in zip(reads, refs)]  # full -> dispatch
    with pytest.raises(TimeoutError, match="not ready"):
        futs[0].result(timeout=0.05)
    assert not futs[0].done()                  # still pending, not failed
    gate.set()
    assert futs[0].result(timeout=30)["dist"] == 0   # timeout-then-fulfill
    assert futs[1].result()["dist"] == 0
    s.close()


def test_cancel_queued_frees_slot_before_dispatch(rng):
    """cancel() on a still-queued future removes its slot atomically: the
    future fails with RequestCancelled, the rid is forgotten, and the
    bucket dispatches WITHOUT the cancelled lane."""
    from repro.api import RequestCancelled
    (ra, rb), (fa, fb) = _exact_pairs(rng, 2, 24)
    s = plan(DCFG, rescue_rounds=0, batch_lanes=2, cache="private")
    fut = s.submit(ra, fa)                     # queued: bucket not full
    assert fut.cancel() is True
    assert fut.cancelled() and fut.done()
    with pytest.raises(RequestCancelled):
        fut.result()
    assert fut.cancel() is True                # idempotent on repeats
    assert s.stats["cancelled"] == 1
    f2 = s.submit(rb, fb)
    s.flush()
    assert f2.result()["dist"] == 0
    assert s.stats["dispatches"] == 1          # only the survivor's batch
    s.close()


def test_cancel_after_dispatch_never_frees_a_lane_twice(rng):
    """Once the slot is on a dispatched lane, cancel() is False and stays
    False: the lane is committed exactly once and the result arrives
    normally (sync and threaded executors)."""
    for executor in ("sync", "thread"):
        rng2 = np.random.default_rng(7)
        reads, refs = _exact_pairs(rng2, 2, 24)
        s = plan(DCFG, rescue_rounds=0, batch_lanes=2, executor=executor,
                 cache="private")
        futs = [s.submit(r, f) for r, f in zip(reads, refs)]  # dispatched
        assert futs[0].cancel() is False       # committed: not cancellable
        assert not futs[0].cancelled()
        assert futs[0].result(timeout=30)["dist"] == 0
        assert futs[0].cancel() is False       # done-and-uncancelled stays
        assert s.stats["cancelled"] == 0
        assert s.stats["dispatches"] == 1      # the lane ran exactly once
        s.close()


def test_multi_client_submit_hammer_bit_identical_to_serial(rng):
    """8 client threads hammer ONE threaded session concurrently (mixed
    buckets, submit + per-thread flush + result) — every per-request
    record must be bit-identical to a serial single-thread run of the
    same pairs.  Per-lane results are batch-composition independent
    (PR-3 invariance), so ANY interleaving must yield the same values."""
    per_thread = []
    for t in range(8):
        trng = np.random.default_rng(500 + t)
        pairs = []
        for _ in range(6):
            n = int(trng.integers(16, 120))
            ref = trng.integers(0, 4, n).astype(np.uint8)
            read = ref.copy()
            read[::9] = (read[::9] + 1) % 4    # a few subs: rescue-free
            pairs.append((read, ref))
        per_thread.append(pairs)

    # shared cache on purpose: hermeticity is irrelevant to a value
    # claim, and the serial twin's lowerings feed the threaded run
    base = plan(DCFG, rescue_rounds=ROUNDS, rescue_mode="bucket",
                batch_lanes=4)
    serial = [[base.submit(r, f) for r, f in pairs] for pairs in per_thread]
    base.flush()
    want = [[sf.result() for sf in row] for row in serial]
    base.close()

    s = plan(DCFG, rescue_rounds=ROUNDS, rescue_mode="bucket",
             batch_lanes=4, executor="thread")
    got = [None] * 8
    errs = []

    def client(i):
        try:
            futs = [s.submit(r, f) for r, f in per_thread[i]]
            s.flush()
            got[i] = [ft.result(timeout=60) for ft in futs]
        except BaseException as e:             # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs, errs
    for i in range(8):
        _assert_results_equal(AlignResult.from_records(want[i]),
                              AlignResult.from_records(got[i]))
    s.close()


def test_close_while_outstanding_race(rng):
    """close() racing concurrent submits: every submit either lands (and
    close's drain fulfills it) or refuses with 'closed' — no future is
    ever left hanging and the retire thread always joins."""
    reads, refs = _exact_pairs(rng, 16, 24)
    s = plan(DCFG, rescue_rounds=0, batch_lanes=2, executor="thread",
             cache="private")
    start = threading.Barrier(3)
    landed, refused, errs = [], [], []

    def submitter(lo):
        start.wait()
        for i in range(lo, lo + 8):
            try:
                landed.append(s.submit(reads[i], refs[i]))
            except RuntimeError as e:
                if "closed" not in str(e):     # pragma: no cover
                    errs.append(e)
                refused.append(i)
                return

    t1 = threading.Thread(target=submitter, args=(0,))
    t2 = threading.Thread(target=submitter, args=(8,))
    t1.start(); t2.start()
    start.wait()                               # maximise the overlap
    s.close(drain=True)
    t1.join(); t2.join()
    assert not errs, errs
    for fut in landed:                         # landed => drained by close
        assert fut.done()
        assert fut.result(timeout=5)["dist"] == 0
    assert s._retire_thread is None
