"""Traceback: the paper's equivalence claims — edges4 (unimproved), 'and'
(SENE) and 'band' (SENE+DENT) produce identical, valid, optimal CIGARs."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import AlignerConfig
from repro.core.genasm import dc_dmajor, dc_jmajor
from repro.core.oracle import levenshtein, validate_cigar
from repro.core.cigar import ops_to_string
from repro.core.traceback import traceback
from tests.conftest import mutate_seq


def make_batch(rng, W, k, B):
    pats, txts, eds = [], [], []
    for _ in range(B):
        p = rng.integers(0, 4, W).astype(np.uint8)
        t = mutate_seq(p, int(rng.integers(0, k + 2)), rng, extend_to=W)
        pats.append(p); txts.append(t); eds.append(levenshtein(p, t))
    return np.stack(pats), np.stack(txts), eds


@pytest.mark.parametrize("W,k", [
    (32, 9), pytest.param(64, 12, marks=pytest.mark.slow)])
def test_three_modes_identical_cigars(W, k, rng):
    """Full traceback for the full-storage modes ('edges4' vs SENE 'and')
    must be optimal + identical; 'band' (DENT) stores only the columns the
    *committed* walk can reach, so it is compared on the committed prefix
    (its operating contract in the windowed pipeline)."""
    B = 16
    pats, txts, eds = make_batch(rng, W, k, B)
    pat, txt = jnp.array(pats), jnp.array(txts)
    wl = jnp.full((B,), W, jnp.int32)
    MAXO, MAXS = 2 * W + k, 2 * W + k + 4
    stride = W - W // 3
    full, committed = {}, {}
    for mode in ("edges4", "and", "band"):
        cfg = AlignerConfig(W=W, O=W // 3, k=k, store=mode)
        if mode == "band":
            res = dc_dmajor(pat, txt, cfg=cfg)
        else:
            res = dc_jmajor(pat, txt, wl, wl, k=k, n=W, nw=cfg.nw, store=mode)
        if mode != "band":
            tb = traceback(res.store, pat, txt, wl, wl, res.dist,
                           jnp.int32(10**6), cfg=cfg, mode=mode,
                           max_ops=MAXO, max_steps=MAXS)
            assert bool(np.array(tb["ok"]).all()), f"{mode}: invariant"
            full[mode] = []
            for b in range(B):
                if eds[b] <= k:
                    assert int(res.dist[b]) == eds[b]
                    ops = np.array(tb["ops"])[b][:int(tb["n_ops"][b])]
                    # ops are front-first over REVERSED windows
                    validate_cigar(pats[b][::-1], txts[b][::-1], ops,
                                   expected_dist=eds[b])
                    full[mode].append(ops_to_string(ops))
                else:
                    full[mode].append(None)
        tbc = traceback(res.store, pat, txt, wl, wl, res.dist,
                        jnp.int32(stride), cfg=cfg, mode=mode,
                        max_ops=MAXO, max_steps=MAXS)
        assert bool(np.array(tbc["ok"]).all()), f"{mode}: commit invariant"
        committed[mode] = [
            ops_to_string(np.array(tbc["ops"])[b][:int(tbc["n_ops"][b])])
            if eds[b] <= k else None for b in range(B)]
    assert full["edges4"] == full["and"]
    assert committed["edges4"] == committed["and"] == committed["band"]


def test_committed_traceback_stops_at_stride(rng):
    W, k = 64, 12
    cfg = AlignerConfig(W=W, O=24, k=k)
    B = 8
    pats, txts, eds = make_batch(rng, W, k, B)
    pat, txt = jnp.array(pats), jnp.array(txts)
    wl = jnp.full((B,), W, jnp.int32)
    res = dc_dmajor(pat, txt, cfg=cfg)
    tb = traceback(res.store, pat, txt, wl, wl, res.dist,
                   jnp.int32(cfg.stride), cfg=cfg, mode="band",
                   max_ops=W + k, max_steps=W + k + 4)
    solved = np.array(res.dist) <= k
    rd = np.array(tb["read_adv"])[solved]
    rf = np.array(tb["ref_adv"])[solved]
    assert (rd == cfg.stride).all()          # read advances exactly W-O
    assert (np.abs(rf - rd) <= k).all()      # ref drift bounded by k
    # committed cost consistency: cost <= window distance
    assert (np.array(tb["cost"])[solved] <= np.array(res.dist)[solved]).all()
