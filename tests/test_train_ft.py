"""Fault tolerance: checkpoint roundtrip, supervised restart, determinism
of the data stream, watchdog."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (latest_step, restore_checkpoint,
                                   save_checkpoint)
from repro.data.tokens import Prefetcher, TokenStream
from repro.models.registry import get_config, get_model, tiny_config
from repro.optim.adamw import AdamWConfig
from repro.runtime.ft import FailureInjector, Watchdog, supervise
from repro.train.step import abstract_state, init_state, make_train_step


@pytest.fixture
def setup(tmp_path):
    cfg = tiny_config(get_config("llama3.2-1b"))
    model = get_model(cfg)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3, total_steps=50,
                                                      warmup_steps=2)))
    state = init_state(model, jax.random.PRNGKey(0))
    stream = TokenStream(cfg.vocab, 4, 64, seed=0)
    return cfg, model, step, state, stream, tmp_path


def test_checkpoint_roundtrip(setup):
    cfg, model, step, state, stream, tmp = setup
    save_checkpoint(tmp / "ck", state, 7, keep=2)
    assert latest_step(tmp / "ck") == 7
    restored, s = restore_checkpoint(tmp / "ck", abstract_state(model))
    assert s == 7
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_n(setup):
    cfg, model, step, state, stream, tmp = setup
    for s in (10, 20, 30, 40):
        save_checkpoint(tmp / "ck", state, s, keep=2)
    steps = sorted(p.name for p in (tmp / "ck").iterdir())
    assert steps == ["step_00000030", "step_00000040"]


@pytest.mark.slow
def test_supervised_restart_reaches_target(setup):
    cfg, model, step, state, stream, tmp = setup
    inj = FailureInjector(fail_at=[7, 13])
    final, log, restarts = supervise(
        step, state, stream, steps=20, ckpt_dir=tmp / "ck",
        ckpt_every=5, abstract_state=abstract_state(model), injector=inj,
        log_every=5)
    assert restarts == 2
    assert int(final["opt"]["step"]) >= 20
    events = [r for r in log if "event" in r]
    assert len(events) == 2


@pytest.mark.slow
def test_restart_resumes_identical_state(setup):
    """Train 10 straight vs train-with-crash-at-7: same final state (data
    stream is a pure function of step, checkpoints at every step)."""
    cfg, model, step, state, stream, tmp = setup
    s_a, _, _ = supervise(step, state, stream, steps=10,
                          ckpt_dir=tmp / "a", ckpt_every=1,
                          abstract_state=abstract_state(model))
    inj = FailureInjector(fail_at=[7])
    s_b, _, r = supervise(step, state, stream, steps=10,
                          ckpt_dir=tmp / "b", ckpt_every=1,
                          abstract_state=abstract_state(model), injector=inj)
    assert r == 1
    # NOTE: supervise replays from the checkpointed step with the same
    # deterministic stream -> identical trajectories
    la = jax.tree_util.tree_leaves(s_a["params"])
    lb = jax.tree_util.tree_leaves(s_b["params"])
    for a, b in zip(la, lb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_watchdog_flags_straggler():
    wd = Watchdog(factor=3.0)
    for i in range(20):
        wd.record(i, 0.1)
    assert wd.record(20, 1.0)
    assert wd.stragglers


def test_token_stream_deterministic_and_prefetch():
    s = TokenStream(1000, 2, 16, seed=5)
    a = s.batch_at(3)
    b = s.batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    pf = Prefetcher(s.iterate(), depth=2)
    first = next(pf)
    np.testing.assert_array_equal(first["tokens"], s.batch_at(0)["tokens"])
    pf.stop()


@pytest.mark.slow
def test_grad_accum_matches_full_batch(setup):
    """mean-of-microbatch-grads == full-batch grad (CE of means).  Grads
    are compared directly: Adam's sqrt(v) normalization amplifies bf16
    noise on near-zero entries to +-lr, which would mask the property."""
    cfg, model, _, state, stream, tmp = setup
    batch = stream.batch_at(0)

    def full_grad(params):
        return jax.grad(lambda p: model.loss(p, batch)[0])(params)

    def accum_grad(params, n=2):
        mbs = jax.tree_util.tree_map(
            lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)
        def micro(acc, mb):
            g = jax.grad(lambda p: model.loss(p, mb)[0])(params)
            return jax.tree_util.tree_map(jnp.add, acc, g), None
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        acc, _ = jax.lax.scan(micro, zeros, mbs)
        return jax.tree_util.tree_map(lambda g: g / n, acc)

    g1 = jax.jit(full_grad)(state["params"])
    g2 = jax.jit(accum_grad)(state["params"])
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, dtype=np.float32),
                                   np.asarray(b, dtype=np.float32),
                                   rtol=5e-2, atol=5e-4)
