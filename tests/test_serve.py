"""Serving layer: alignment engine, greedy LM generation, optimizer math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.genome import ReadSimConfig, simulate_reads, synth_genome
from repro.models.registry import get_config, get_model, tiny_config
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state, schedule
from repro.serve.engine import AlignmentEngine, AlignRequest
from repro.serve.kvcache import greedy_generate


def test_alignment_engine_end_to_end():
    g = synth_genome(40_000, seed=5)
    rs = simulate_reads(g, 6, ReadSimConfig(read_len=120, error_rate=0.06,
                                            seed=6))
    # the engine is a shim over repro.api.AlignSession: both 4-request
    # batches land in ONE (length bucket, lane class) -> exactly one AOT
    # compile; rounds=0 keeps the ladder out (rescue is tested separately)
    from repro.core.config import AlignerConfig
    # cache='private': this test counts exact lowerings, so it must not
    # see executables other suites put in the process-shared store
    eng = AlignmentEngine(AlignerConfig(W=32, O=12, k=8), batch_size=4,
                          rescue_rounds=0, cache="private")
    assert eng.aligner.cache.stats()["lowerings"] == 0
    for i, (r, s) in enumerate(zip(rs.reads, rs.ref_segments)):
        eng.submit(AlignRequest(rid=i, read=r, ref=s))
    stats = eng.serve_until_empty()
    assert stats["batches"] == 2          # 4+2
    assert stats["aligned"] == 6
    assert all(eng.results[i]["ok"] for i in range(6))
    assert all(eng.results[i]["cigar"] for i in range(6))
    # compile stability through the shim: the ragged 2-request tail was
    # padded into the same 4-lane bucket as the full batch
    cs = eng.aligner.cache.stats()
    assert cs["lowerings"] == 1 and cs["hits"] == 1


@pytest.mark.slow
def test_engine_ragged_batch_padding_regression():
    """Non-multiple-of-batch-size request stream: the ragged final batch is
    padded to batch_size with REPEATS of a real pair (stable jit shapes),
    and padding lanes must neither consume extra rescue rounds (a garbage
    pad lane would fail every round and keep the on-device `any(failed)`
    round gate open) nor pollute stats['failed'] / per-request results.
    (@slow: its own W=16 ladder compile; the tier-1 representative is the
    stronger 8-forced-device version in tests/test_multidevice.py, which
    additionally checks the pair_pad_multiple quantisation.)"""
    from repro.core.config import AlignerConfig

    g = synth_genome(30_000, seed=15)
    rs = simulate_reads(g, 6, ReadSimConfig(read_len=64, error_rate=0.05,
                                            seed=16))
    eng = AlignmentEngine(AlignerConfig(W=16, O=6, k=4), batch_size=4,
                          rescue_rounds=1)
    seen_sizes = []
    orig_align = eng.aligner.align

    def spy(reads, refs):
        seen_sizes.append(len(reads))
        return orig_align(reads, refs)

    eng.aligner.align = spy
    for i, (r, s) in enumerate(zip(rs.reads, rs.ref_segments)):
        eng.submit(AlignRequest(rid=i, read=r, ref=s))
    stats = eng.serve_until_empty()
    assert seen_sizes == [4, 4]            # ragged tail padded, stable shape
    assert stats["batches"] == 2
    assert stats["padded_lanes"] == 2
    assert stats["aligned"] + stats["failed"] == 6   # pads never counted
    assert stats["failed"] == 0
    assert set(eng.results) == set(range(6))
    assert all(eng.results[i]["ok"] for i in range(6))


@pytest.mark.slow
def test_greedy_generate_shapes_and_determinism():
    cfg = tiny_config(get_config("llama3.2-1b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    out1 = greedy_generate(model, params, toks, n_new=5, max_len=16)
    out2 = greedy_generate(model, params, toks, n_new=5, max_len=16)
    assert out1.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert int(out1.max()) < cfg.vocab


def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=10.0,
                      warmup_steps=1, total_steps=200)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    for _ in range(150):
        g = {"w": 2 * params["w"]}            # grad of ||w||^2
        params, opt, m = adamw_update(params, g, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5
    assert float(m["grad_norm"]) >= 0


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert float(schedule(cfg, jnp.int32(100))) == pytest.approx(0.1, rel=1e-3)
    assert float(schedule(cfg, jnp.int32(55))) < 1.0
