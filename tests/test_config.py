"""AlignerConfig validation: every bad knob raises ValueError, not a bare
assert.

The contract (this PR's satellite): ``__post_init__`` names the offending
knob AND the valid choices in the message, so a misconfigured AlignSession
/ Gateway / MapperConfig front door fails with an actionable error instead
of a stack-trace-only AssertionError — and so callers can catch ValueError
uniformly (assert statements vanish under ``python -O``)."""
import pytest

from repro.core.config import (BACKENDS, PALLAS_BACKENDS, STORES,
                               TAIL_STORES, AlignerConfig)


def _err(**kw):
    base = dict(W=16, O=6, k=4)
    base.update(kw)
    with pytest.raises(ValueError) as ei:
        AlignerConfig(**base)
    return str(ei.value)


def test_overlap_bounds_name_the_knobs():
    for bad_O in (0, 16, 20, -3):
        msg = _err(O=bad_O)
        assert "O" in msg and "W" in msg and str(bad_O) in msg


def test_k_bounds_name_the_knobs():
    for bad_k in (0, 16, 99, -1):
        msg = _err(k=bad_k)
        assert "k" in msg and "W" in msg and str(bad_k) in msg


def test_lane_tile_must_be_positive():
    for bad in (0, -8):
        msg = _err(lane_tile=bad)
        assert "lane_tile" in msg and str(bad) in msg


def test_enum_knobs_name_knob_and_choices():
    """Each enum knob's message carries the knob name, the bad value, and
    every valid choice — copy-pasteable without opening the source."""
    cases = [("store", STORES), ("tail_store", TAIL_STORES),
             ("backend", BACKENDS)]
    for knob, choices in cases:
        msg = _err(**{knob: "warp_speed"})
        assert knob in msg and "warp_speed" in msg
        for choice in choices:
            assert choice in msg, f"{knob} error must list {choice!r}"


def test_pallas_backends_require_band_store():
    """The Pallas kernels implement the banded DP only; pairing any of them
    with a non-band store must say so, naming both knobs."""
    for backend in PALLAS_BACKENDS:
        for store in ("edges4", "and"):
            msg = _err(backend=backend, store=store)
            assert backend in msg and store in msg and "band" in msg


def test_valid_configs_construct():
    """The happy paths stay open — including the new pallas_gpu backend and
    jnp with every store mode."""
    for backend in BACKENDS:
        cfg = AlignerConfig(W=16, O=6, k=4, backend=backend)
        assert cfg.backend == backend
    for store in STORES:
        assert AlignerConfig(W=16, O=6, k=4, store=store).store == store
    for ts in TAIL_STORES:
        c = AlignerConfig(W=64, O=24, k=12, backend="pallas_gpu",
                          tail_store=ts)
        assert c.tail_store == ts


def test_valueerror_not_assertionerror():
    """Regression pin: the old bare asserts raised AssertionError; callers
    that catch ValueError must keep working."""
    try:
        AlignerConfig(W=16, O=6, k=4, backend="nope")
    except ValueError:
        pass
    else:  # pragma: no cover
        pytest.fail("invalid backend must raise ValueError")
