"""CIGAR packing roundtrip + RLE string."""
import jax.numpy as jnp
import numpy as np
from tests._hyp import given, settings, st

from repro.core.cigar import ops_to_string, pack_ops, unpack_ops
from repro.core.traceback import OP_NONE


@given(st.lists(st.integers(0, 3), min_size=0, max_size=70))
@settings(max_examples=40, deadline=None)
def test_pack_unpack_roundtrip(ops):
    L = 80
    row = np.full(L, OP_NONE, np.uint8)
    row[:len(ops)] = ops
    packed = pack_ops(jnp.array(row[None]))
    out = unpack_ops(np.asarray(packed), np.array([len(ops)]))[0]
    np.testing.assert_array_equal(out, np.array(ops, np.uint8))


def test_rle_string():
    assert ops_to_string(np.array([0, 0, 0, 1, 3, 3, 2])) == "3=1X2D1I"
    assert ops_to_string(np.array([], np.uint8)) == ""
