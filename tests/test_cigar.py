"""CIGAR packing roundtrip + RLE string + seeded per-backend invariants.

The invariant suite (no hypothesis, seeded corpus shared with
tests/test_differential.py via the session fixtures in conftest): for
every backend, the op array of each solved lane must decode to a CIGAR
whose consumed read/ref lengths equal the reported
read_consumed/ref_consumed and whose edit count equals dist."""
import re

import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.core.cigar import ops_to_string, pack_ops, unpack_ops
from repro.core.oracle import OP_DEL, OP_INS, OP_MATCH, OP_SUBST
from repro.core.traceback import OP_NONE


@given(st.lists(st.integers(0, 3), min_size=0, max_size=70))
@settings(max_examples=40, deadline=None)
def test_pack_unpack_roundtrip(ops):
    L = 80
    row = np.full(L, OP_NONE, np.uint8)
    row[:len(ops)] = ops
    packed = pack_ops(jnp.array(row[None]))
    out = unpack_ops(np.asarray(packed), np.array([len(ops)]))[0]
    np.testing.assert_array_equal(out, np.array(ops, np.uint8))


def test_rle_string():
    assert ops_to_string(np.array([0, 0, 0, 1, 3, 3, 2])) == "3=1X2D1I"
    assert ops_to_string(np.array([], np.uint8)) == ""


# ---- seeded per-backend CIGAR invariants (differential corpus) ----

_CIGAR_RE = re.compile(r"(\d+)([=XID])")
_READ_CONSUMES = {"=", "X", "I"}
_REF_CONSUMES = {"=", "X", "D"}


def _cigar_counts(cigar: str):
    counts = {"=": 0, "X": 0, "I": 0, "D": 0}
    spans = _CIGAR_RE.findall(cigar)
    assert "".join(f"{n}{c}" for n, c in spans) == cigar, cigar
    for n, c in spans:
        counts[c] += int(n)
    return counts


@pytest.mark.parametrize("backend", [
    "jnp",
    "pallas_fused",
    pytest.param("pallas", marks=pytest.mark.slow),
])
def test_cigar_consumption_invariants_per_backend(corpus, diff_aligned,
                                                  backend):
    """Solved lanes: the ops decode to a CIGAR that (a) fully consumes the
    read (read_consumed == len(read)), (b) consumes exactly ref_consumed
    reference chars (never more than the ref holds), and (c) carries
    exactly `dist` edits.  Failed lanes report empty CIGARs and zeroed
    consumption."""
    reads, refs, profs = corpus
    res = diff_aligned(backend)
    n_solved = 0
    for i in range(len(reads)):
        if res.failed[i]:
            assert res.cigars[i] == "" and res.ops[i].size == 0
            assert res.read_consumed[i] == 0 and res.ref_consumed[i] == 0
            continue
        ops = res.ops[i]
        n_eq = int((ops == OP_MATCH).sum())
        n_x = int((ops == OP_SUBST).sum())
        n_i = int((ops == OP_INS).sum())
        n_d = int((ops == OP_DEL).sum())
        assert n_eq + n_x + n_i + n_d == len(ops), profs[i]   # no strays
        assert n_eq + n_x + n_i == res.read_consumed[i] == len(reads[i])
        assert n_eq + n_x + n_d == res.ref_consumed[i] <= len(refs[i])
        assert n_x + n_i + n_d == res.dist[i], (i, profs[i])
        # and the RLE string agrees with the raw op array
        counts = _cigar_counts(res.cigars[i])
        assert counts == {"=": n_eq, "X": n_x, "I": n_i, "D": n_d}
        n_solved += 1
    assert n_solved > 0


def test_cigar_invariants_backends_agree(diff_aligned):
    """The invariant inputs themselves (consumption vectors) are part of
    the backend equivalence contract."""
    a, b = diff_aligned("jnp"), diff_aligned("pallas_fused")
    assert list(a.read_consumed) == list(b.read_consumed)
    assert list(a.ref_consumed) == list(b.ref_consumed)
