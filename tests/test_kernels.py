"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps in interpret mode,
plus the VMEM-fit claim."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import AlignerConfig
from repro.core.oracle import levenshtein
from repro.kernels.genasm_dc import vmem_bytes, vmem_bytes_tail
from repro.kernels.ops import genasm_dc_op
from repro.kernels.ref import genasm_dc_ref
from tests.conftest import mutate_seq


def batch(rng, W, k, B):
    pats, txts = [], []
    for _ in range(B):
        p = rng.integers(0, 4, W).astype(np.uint8)
        txts.append(mutate_seq(p, int(rng.integers(0, k + 2)), rng,
                               extend_to=W))
        pats.append(p)
    return jnp.array(np.stack(pats)), jnp.array(np.stack(txts))


@pytest.mark.parametrize("W,k,tile", [
    (16, 3, 4), (32, 7, 8), (32, 15, 8),
    pytest.param(64, 12, 8, marks=pytest.mark.slow),
    pytest.param(96, 9, 4, marks=pytest.mark.slow)])
def test_kernel_matches_ref_sweep(W, k, tile, rng):
    cfg = AlignerConfig(W=W, O=max(1, W // 3), k=k)
    B = tile
    pat, txt = batch(rng, W, k, B)
    d_ref, band_ref, lvl_ref = genasm_dc_ref(pat, txt, cfg=cfg)
    d_k, band_k, lvl_k = genasm_dc_op(pat, txt, cfg=cfg, tile=tile,
                                      interpret=True)
    assert (np.array(d_ref) == np.array(d_k)).all()
    assert int(lvl_ref) == int(lvl_k)
    L = int(lvl_ref)
    br = np.array(band_ref)                      # (K1, ncb, nwb, B)
    bk = np.array(band_k).transpose(0, 1, 3, 2)  # (K1, ncb, B, nwb) ->
    assert (br[:L] == bk[:L]).all()


def test_kernel_distances_match_oracle(rng):
    cfg = AlignerConfig(W=32, O=12, k=9)
    pat, txt = batch(rng, 32, 9, 8)
    d_k, _, _ = genasm_dc_op(pat, txt, cfg=cfg, tile=8, interpret=True)
    for b in range(8):
        ed = levenshtein(np.array(pat[b]), np.array(txt[b]))
        assert int(d_k[b]) == (ed if ed <= 9 else 10)


def test_kernel_batch_padding(rng):
    """non-multiple-of-tile batches are padded and trimmed."""
    cfg = AlignerConfig(W=32, O=12, k=7)
    pat, txt = batch(rng, 32, 7, 5)
    d_k, band, _ = genasm_dc_op(pat, txt, cfg=cfg, tile=4, interpret=True)
    assert d_k.shape == (5,)
    assert band.shape[2] == 5


def test_pad_sentinels_out_of_alphabet(rng):
    """The shared pad sentinels: any pattern code >= N_SYMBOLS never matches,
    any text code >= N_SYMBOLS maps to the all-ones PM row — so distances
    depend only on the true-length prefix, for jnp and kernel paths alike."""
    from repro.core.bitops import N_SYMBOLS, SENTINEL_PAT, SENTINEL_TEXT
    from repro.core.genasm import dc_jmajor

    assert SENTINEL_PAT != SENTINEL_TEXT
    assert SENTINEL_PAT >= N_SYMBOLS and SENTINEL_TEXT >= N_SYMBOLS
    W, k = 32, 7
    m, n = 11, 13
    p = rng.integers(0, N_SYMBOLS, m).astype(np.int32)
    t = mutate_seq(p.astype(np.uint8), 3, rng)[:n].astype(np.int32)
    want = levenshtein(p, t)
    want = want if want <= k else k + 1
    for pat_pad, txt_pad in ((SENTINEL_PAT, SENTINEL_TEXT),
                             (SENTINEL_TEXT + 1, N_SYMBOLS)):
        pat = np.full((1, W), pat_pad, np.int32)
        txt = np.full((1, W), txt_pad, np.int32)
        pat[0, :m] = p
        txt[0, :len(t)] = t
        res = dc_jmajor(jnp.array(pat), jnp.array(txt), jnp.array([m]),
                        jnp.array([len(t)]), k=k, n=W, nw=1, store="and")
        assert int(res.dist[0]) == want, (pat_pad, txt_pad)


def test_vmem_fit():
    """The paper's claim: the compressed working set fits on-chip."""
    import dataclasses
    for W, k, tile in ((64, 12, 512), (64, 16, 512), (128, 15, 256)):
        cfg = AlignerConfig(W=W, O=W // 3 + 1, k=k)
        assert vmem_bytes(cfg, tile) < 16 * 2**20, (W, k, tile)
        # the rectangular tail must fit even with the full-store fallback
        # at half the main-window tile; the banded store (the default
        # wherever the band proof is a strict win) only shrinks it
        full = dataclasses.replace(cfg, tail_store="full")
        assert vmem_bytes_tail(full, tile // 2) < 16 * 2**20, (W, k, tile)
        assert vmem_bytes_tail(cfg, tile // 2) \
            <= vmem_bytes_tail(full, tile // 2), (W, k, tile)
    # and the UNimproved table would not: 4 vectors x all columns x levels
    cfg = AlignerConfig(W=64, O=24, k=16)
    baseline_bytes = 64 * (cfg.k + 1) * 4 * cfg.nw * 4 * 512
    assert baseline_bytes > 16 * 2**20
