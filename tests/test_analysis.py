"""HLO collective parser + roofline math + dryrun pspec helpers."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.hlo import collective_bytes
from repro.analysis.roofline import model_flops, roofline_terms

HLO_FIXTURE = """
ENTRY %main {
  %p0 = bf16[8,128]{1,0} parameter(0)
  %ar = bf16[8,128]{1,0} all-reduce(bf16[8,128]{1,0} %p0), replica_groups={}
  %ag = f32[64,128]{1,0} all-gather(f32[4,128]{1,0} %x), dimensions={0}
  %rs = f32[4,128]{1,0} reduce-scatter(f32[64,128]{1,0} %y), dimensions={0}
  %a2a = (s8[16]{0}, s8[16]{0}) all-to-all(s8[16]{0} %a, s8[16]{0} %b)
  %cp = u32[512]{0} collective-permute(u32[512]{0} %z)
  %cps = u32[512]{0} collective-permute-start(u32[512]{0} %z)
  %cpd = u32[512]{0} collective-permute-done(u32[512]{0} %cps)
}
"""


def test_collective_parser_counts_and_bytes():
    r = collective_bytes(HLO_FIXTURE)
    c = r["counts"]
    assert c["all-reduce"] == 1 and c["all-gather"] == 1
    assert c["reduce-scatter"] == 1 and c["all-to-all"] == 1
    assert c["collective-permute"] == 2           # cp + cp-start (done skipped)
    by = r["by_op"]
    assert by["all-reduce"] == 2 * 8 * 128 * 2    # 2x wire for AR
    assert by["all-gather"] == 64 * 128 * 4
    assert by["reduce-scatter"] == 4 * 128 * 4
    assert by["all-to-all"] == 32                 # tuple of two s8[16]


def test_roofline_dominant_term():
    t = roofline_terms(flops_global=197e12 * 256, bytes_global=1.0,
                       coll_bytes_per_dev=1.0, chips=256)
    assert t["dominant"] == "compute" and abs(t["compute_s"] - 1.0) < 1e-9
    t = roofline_terms(1.0, 819e9 * 256 * 2.0, 1.0, 256)
    assert t["dominant"] == "memory" and abs(t["memory_s"] - 2.0) < 1e-9
    assert model_flops(1e9, 1e6, True) == 6e15


def test_fit_pspec_drops_nondivisible_axes():
    import subprocess, sys, os, textwrap
    # fit_pspec needs a mesh; run against tiny virtual mesh in-process is
    # fine (1 device -> every axis size 1 divides).  Use dryrun helper shape
    # logic directly with a fake mesh object.
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    from repro.launch.dryrun import fit_pspec
    assert fit_pspec((32, 100), ("data", "model"), FakeMesh()) == P("data", None)
    # axis absent from the mesh ('pod') or non-divisible (dim 1) -> dropped
    assert fit_pspec((1, 64), (("pod", "data"), "model"), FakeMesh()) == \
        P(None, "model")
    assert fit_pspec((256, 4096, 128), (None, "data", "model"), FakeMesh()) \
        == P(None, "data", "model")
