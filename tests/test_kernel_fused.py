"""Fused GenASM-DC+TB Pallas kernel: bit-identical to the jnp 'band' path,
CIGAR-valid vs the classic DP oracle, consistent with all three jnp store
modes on the committed prefix, and correct through windowing + rescue."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import AlignerConfig
from repro.core.genasm import dc_dmajor, dc_jmajor
from repro.core.oracle import levenshtein, validate_cigar
from repro.core.cigar import ops_to_string
from repro.core.traceback import OP_NONE, traceback
from repro.kernels.ops import GPU_PLATFORMS, genasm_tb_fused_op
from tests.conftest import mutate_seq


def batch(rng, W, k, B):
    pats, txts, eds = [], [], []
    for _ in range(B):
        p = rng.integers(0, 4, W).astype(np.uint8)
        t = mutate_seq(p, int(rng.integers(0, k + 2)), rng, extend_to=W)
        pats.append(p); txts.append(t); eds.append(levenshtein(p, t))
    return np.stack(pats), np.stack(txts), eds


def jnp_band_tb(pat, txt, cfg, commit_limit, max_ops, max_steps):
    B = pat.shape[0]
    wl = jnp.full((B,), cfg.W, jnp.int32)
    res = dc_dmajor(pat, txt, cfg=cfg)
    tb = traceback(res.store, pat, txt, wl, wl, res.dist,
                   jnp.int32(commit_limit), cfg=cfg, mode="band",
                   max_ops=max_ops, max_steps=max_steps)
    return res, tb


@pytest.mark.parametrize("W,k,tile,B", [
    (16, 3, 4, 4),
    (32, 15, 8, 8),    # nwb = 2: two-word band windows
    (32, 11, 4, 5),    # B not a multiple of tile
])
def test_fused_bit_identical_to_jnp_band(W, k, tile, B, rng):
    """The acceptance sweep: fused ops/dist == jnp band path, bit for bit."""
    cfg = AlignerConfig(W=W, O=max(1, W // 3), k=k)
    stride = cfg.stride
    max_ops, max_steps = cfg.tb_max_ops, cfg.tb_max_steps
    pats, txts, _ = batch(rng, W, k, B)
    pat, txt = jnp.array(pats), jnp.array(txts)
    res, tb = jnp_band_tb(pat, txt, cfg, stride, max_ops, max_steps)
    fz = genasm_tb_fused_op(pat, txt, cfg=cfg, commit_limit=stride,
                            max_ops=max_ops, max_steps=max_steps, tile=tile)
    assert (np.array(fz["dist"]) == np.array(res.dist)).all()
    assert int(fz["levels"]) == int(res.levels_run)
    assert bool(np.array(fz["ok"]).all())
    for key in ("ops", "n_ops", "read_adv", "ref_adv", "cost", "d_final"):
        np.testing.assert_array_equal(np.array(fz[key]), np.array(tb[key]),
                                      err_msg=key)


def test_fused_cigars_optimal_vs_oracle(rng):
    """With a full-coverage band (ncb == W+1) and no commit limit the fused
    walk is a complete traceback; its CIGARs must be valid and optimal."""
    W, k, B = 16, 5, 8
    cfg = AlignerConfig(W=W, O=2, k=k)       # stride+k+margin > W+1
    assert cfg.ncols_band == W + 1
    max_ops, max_steps = 2 * W + k, 2 * W + k + 4
    pats, txts, eds = batch(rng, W, k, B)
    fz = genasm_tb_fused_op(jnp.array(pats), jnp.array(txts), cfg=cfg,
                            commit_limit=10**6, max_ops=max_ops,
                            max_steps=max_steps, tile=4)
    assert bool(np.array(fz["ok"]).all())
    n_solved = 0
    for b in range(B):
        if eds[b] <= k:
            assert int(fz["dist"][b]) == eds[b]
            ops = np.array(fz["ops"])[b][:int(fz["n_ops"][b])]
            assert not (ops == OP_NONE).any()
            # ops are front-first over REVERSED windows
            validate_cigar(pats[b][::-1], txts[b][::-1], ops,
                           expected_dist=eds[b])
            n_solved += 1
    assert n_solved > 0


def test_fused_matches_all_jnp_store_modes_committed(rng):
    """Committed-prefix ops agree across edges4/and/band jnp modes and the
    fused kernel (the paper's equivalence claim, extended on-chip)."""
    W, k, B = 32, 9, 8
    stride = W - W // 3
    max_ops, max_steps = stride + k + 2, stride + k + 4
    pats, txts, eds = batch(rng, W, k, B)
    pat, txt = jnp.array(pats), jnp.array(txts)
    wl = jnp.full((B,), W, jnp.int32)
    committed = {}
    for mode in ("edges4", "and", "band"):
        cfg = AlignerConfig(W=W, O=W // 3, k=k, store=mode)
        if mode == "band":
            res = dc_dmajor(pat, txt, cfg=cfg)
        else:
            res = dc_jmajor(pat, txt, wl, wl, k=k, n=W, nw=cfg.nw, store=mode)
        tb = traceback(res.store, pat, txt, wl, wl, res.dist,
                       jnp.int32(stride), cfg=cfg, mode=mode,
                       max_ops=max_ops, max_steps=max_steps)
        committed[mode] = [
            ops_to_string(np.array(tb["ops"])[b][:int(tb["n_ops"][b])])
            if eds[b] <= k else None for b in range(B)]
    cfg = AlignerConfig(W=W, O=W // 3, k=k)
    fz = genasm_tb_fused_op(pat, txt, cfg=cfg, commit_limit=stride,
                            max_ops=max_ops, max_steps=max_steps, tile=8)
    committed["fused"] = [
        ops_to_string(np.array(fz["ops"])[b][:int(fz["n_ops"][b])])
        if eds[b] <= k else None for b in range(B)]
    assert (committed["edges4"] == committed["and"] == committed["band"]
            == committed["fused"])


def test_fused_windowed_alignment_matches_jnp(rng):
    """pallas_fused through GenASMAligner + serve engine: equal to the jnp
    backend on clean reads."""
    from repro.core.aligner import GenASMAligner
    from repro.data.genome import ReadSimConfig, simulate_reads, synth_genome

    g = synth_genome(15_000, seed=77)
    rs = simulate_reads(g, 3, ReadSimConfig(read_len=120, error_rate=0.06,
                                            seed=78))
    cfg = AlignerConfig(W=32, O=12, k=8)
    # rescue_rounds=0: nothing here fails (asserted below), and skipping the
    # extra k-doubling round compiles keeps tier-1 fast; rescue through the
    # fused backend is covered by test_fused_rescue_doubles_k (slow) and
    # tests/test_rescue.py
    res_j = GenASMAligner(cfg, rescue_rounds=0).align(rs.reads,
                                                      rs.ref_segments)
    res_f = GenASMAligner(cfg, rescue_rounds=0, backend="pallas_fused").align(
        rs.reads, rs.ref_segments)
    assert not res_f.failed.any()
    assert list(res_j.dist) == list(res_f.dist)
    assert res_j.cigars == res_f.cigars


def _kernel_call(pats, txts, cfg, **kw):
    return genasm_tb_fused_op(jnp.array(pats), jnp.array(txts), cfg=cfg,
                              commit_limit=cfg.stride, max_ops=cfg.tb_max_ops,
                              max_steps=cfg.tb_max_steps, **kw)


def test_fused_tile_grouping_invariance(rng):
    """Per-lane results must not depend on which problem tile a lane lands
    in (whole-tile early termination only changes how many levels run, and
    the walk never visits levels above a lane's own dist).  This is the
    property that makes sharded dispatch bit-identical: the mesh regroups
    lanes into per-device tiles (kernels.ops)."""
    cfg = AlignerConfig(W=16, O=6, k=4)
    pats, txts, _ = batch(rng, 16, 4, 16)
    a = _kernel_call(pats, txts, cfg, tile=4)
    b = _kernel_call(pats, txts, cfg, tile=16)
    for key in ("ops", "n_ops", "dist", "read_adv", "ref_adv", "cost"):
        np.testing.assert_array_equal(np.array(a[key]), np.array(b[key]),
                                      err_msg=key)


def _tail_batch(rng, cfg, B, wt):
    """Ragged reversed tails (sentinel-padded), incl. the edge lanes the
    band proof's clips must survive: empty pattern, empty text, both."""
    from repro.core.bitops import SENTINEL_PAT, SENTINEL_TEXT
    W, k = cfg.W, cfg.k
    pat = np.full((B, W), SENTINEL_PAT, np.uint8)
    txt = np.full((B, wt), SENTINEL_TEXT, np.uint8)
    ml = np.zeros(B, np.int32)
    nl = np.zeros(B, np.int32)
    edge = [(0, 3), (3, 0), (0, 0)]
    for b in range(B):
        if b < len(edge):
            m, n = edge[b]
        else:
            m = int(rng.integers(1, W + 1))
            n = int(np.clip(m + rng.integers(-k, k + 1), 1, wt))
        if m:
            p = rng.integers(0, 4, m).astype(np.uint8)
            pat[b, :m] = p[::-1]
        if n:
            t = mutate_seq(pat[b, :m][::-1].copy() if m else
                           rng.integers(0, 4, n).astype(np.uint8),
                           int(rng.integers(0, k + 1)), rng)[:n]
            if len(t) < n:
                t = np.concatenate(
                    [t, rng.integers(0, 4, n - len(t)).astype(np.uint8)])
            txt[b, :n] = t[::-1]
        ml[b], nl[b] = m, n
    return pat, txt, ml, nl


@pytest.mark.parametrize("W,O,k", [
    (64, 24, 12),   # headline geometry: band is a strict win (nwb < nw)
    (32, 10, 15),   # nwb = 2: two-word band windows
    (16, 6, 4),     # boundary: nwb == nw — band forced, no strict win
])
def test_tail_banded_bit_identical_to_full_store(W, O, k, rng):
    """The tentpole's bit-exactness bar at kernel level: the Scrooge-style
    banded tail store (per-lane diagonal DENT window, analytic column 0)
    produces the same traceback dict as the full-SENE-table fallback on
    ragged differential tails — every key, every lane, including empty
    pattern/text edge lanes and a ragged last tile."""
    import dataclasses
    from repro.kernels.ops import genasm_tail_fused_op
    full = AlignerConfig(W=W, O=O, k=k, tail_store="full")
    band = dataclasses.replace(full, tail_store="band")
    assert band.tail_banded and not full.tail_banded
    wt = W + 4 * k
    pat, txt, ml, nl = _tail_batch(rng, full, 6, wt)   # 6 lanes, tile=4
    args = (jnp.asarray(pat), jnp.asarray(txt), jnp.asarray(ml),
            jnp.asarray(nl))
    kw = dict(n_text=wt, commit_limit=2 * (W + wt), max_ops=W + wt,
              max_steps=W + wt + 4, tile=4)
    a = genasm_tail_fused_op(*args, cfg=full, **kw)
    b = genasm_tail_fused_op(*args, cfg=band, **kw)
    assert set(a) == set(b)
    for key in a:
        np.testing.assert_array_equal(np.array(a[key]), np.array(b[key]),
                                      err_msg=key)
    assert bool(np.array(a["ok"]).all())
    assert bool(np.array(a["solved"]).any())           # corpus nontrivial


def test_gpu_band_as_output_bit_identical_to_scratch(rng):
    """The Triton lowering's structural trick: backend='pallas_gpu' declares
    the DENT band as an extra GMEM-backed *output* block (jax's Triton
    backend has no scratch memory) while the kernel body is byte-for-byte
    the same function — output refs precede scratch refs, so band_ref lands
    in the identical positional slot.  Both square and tail kernels must be
    bit-identical to the pallas_fused scratch path, every key, in interpret
    mode (this always runs; the compiled-CUDA twin below is skip-guarded)."""
    import dataclasses
    from repro.kernels.ops import genasm_tail_fused_op
    cfg = AlignerConfig(W=32, O=10, k=9)
    gpu = dataclasses.replace(cfg, backend="pallas_gpu")
    pats, txts, _ = batch(rng, 32, 9, 8)
    a = _kernel_call(pats, txts, cfg, tile=4)
    b = _kernel_call(pats, txts, gpu, tile=4)
    assert set(a) == set(b)
    for key in a:
        np.testing.assert_array_equal(np.array(a[key]), np.array(b[key]),
                                      err_msg=key)
    wt = cfg.W + 4 * cfg.k
    pat, txt, ml, nl = _tail_batch(rng, cfg, 6, wt)
    args = (jnp.asarray(pat), jnp.asarray(txt), jnp.asarray(ml),
            jnp.asarray(nl))
    kw = dict(n_text=wt, commit_limit=2 * (cfg.W + wt), max_ops=cfg.W + wt,
              max_steps=cfg.W + wt + 4, tile=4)
    for store in ("band", "full"):   # both tail stores have a GPU lowering
        ct = dataclasses.replace(cfg, tail_store=store)
        gt = dataclasses.replace(gpu, tail_store=store)
        at = genasm_tail_fused_op(*args, cfg=ct, **kw)
        bt = genasm_tail_fused_op(*args, cfg=gt, **kw)
        assert set(at) == set(bt)
        for key in at:
            np.testing.assert_array_equal(np.array(at[key]),
                                          np.array(bt[key]),
                                          err_msg=f"{store}:{key}")


@pytest.mark.skipif(
    jax.default_backend() not in GPU_PLATFORMS,
    reason="no CUDA/ROCm device — compiled Triton parity needs a real GPU; "
           "interpret-mode parity above covers the lowering structure "
           "(see docs/backends.md)")
def test_gpu_compiled_parity_real_device(rng):
    """On a real GPU runner: the actually-compiled Triton kernels (this is
    what default_interpret flips to) must be bit-identical to interpret
    mode.  CI's gpu-parity step inverse-guards this: it fails the build if
    this test silently skips on a runner that reports a GPU backend."""
    import dataclasses
    cfg = dataclasses.replace(AlignerConfig(W=32, O=10, k=9),
                              backend="pallas_gpu")
    pats, txts, _ = batch(rng, 32, 9, 8)
    interp = _kernel_call(pats, txts, cfg, tile=4, interpret=True)
    compiled = _kernel_call(pats, txts, cfg, tile=4, interpret=False)
    for key in ("ops", "n_ops", "dist", "read_adv", "ref_adv", "cost"):
        np.testing.assert_array_equal(np.array(interp[key]),
                                      np.array(compiled[key]), err_msg=key)


from jax.experimental.pallas import tpu as pltpu  # noqa: E402

_TPU_INTERPRET = getattr(pltpu, "force_tpu_interpret_mode", None)


@pytest.mark.skipif(_TPU_INTERPRET is None,
                    reason="this jax lacks pltpu.force_tpu_interpret_mode "
                           "(added after 0.4.37) — parity runs once CI's "
                           "jax is upgraded; see docs/backends.md")
def test_fused_kernels_tpu_interpret_parity(rng):
    """ROADMAP item: the fused kernel under pltpu.force_tpu_interpret_mode
    (the TPU lowering semantics, emulated) must be bit-identical to plain
    interpret mode, so interpret=False defaults can be flipped safely on
    real TPUs."""
    cfg = AlignerConfig(W=16, O=6, k=4)
    pats, txts, _ = batch(rng, 16, 4, 8)
    plain = _kernel_call(pats, txts, cfg, tile=4, interpret=True)
    with _TPU_INTERPRET():
        tpu_interp = _kernel_call(pats, txts, cfg, tile=4, interpret=False)
    for key in ("ops", "n_ops", "dist", "read_adv", "ref_adv", "cost"):
        np.testing.assert_array_equal(np.array(plain[key]),
                                      np.array(tpu_interp[key]), err_msg=key)


@pytest.mark.slow
def test_fused_rescue_doubles_k(rng):
    """rescue-round k doubling recompiles the fused kernel with the doubled
    threshold."""
    from repro.core.aligner import GenASMAligner
    from repro.data.genome import ReadSimConfig, simulate_reads, synth_genome

    g = synth_genome(15_000, seed=77)
    # high-error pair: some window exceeds k=4 -> rescued with doubled k
    rs2 = simulate_reads(g, 2, ReadSimConfig(read_len=100, error_rate=0.25,
                                             seed=79))
    al = GenASMAligner(AlignerConfig(W=32, O=12, k=4),
                       rescue_rounds=2, backend="pallas_fused")
    res = al.align(rs2.reads, rs2.ref_segments)
    for i in range(len(rs2.reads)):
        if not res.failed[i]:
            validate_cigar(rs2.reads[i], rs2.ref_segments[i], res.ops[i],
                           expected_dist=res.dist[i])
    assert (res.k_used[~res.failed] >= 4).all()
    assert (res.k_used[~res.failed] > 4).any()   # at least one needed rescue
