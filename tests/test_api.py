"""The session front door (repro.api): compile stability, parity, streaming.

Claims enforced:
  * compile stability — a ragged stream of 20 mixed-length batches AOT-
    compiles each (length bucket, lane class) EXACTLY once, counted by the
    session's own CompileCache (misses == lowerings == distinct buckets;
    every further dispatch is a cache hit, including a full second pass),
  * the session is bit-identical to the legacy GenASMAligner door on the
    differential corpus (ops, dist, k_used, failed, consumption),
  * submit()/results() stream: double buffering caps in-flight dispatches
    at spec.max_inflight and retires oldest-first; futures resolve out of
    order; results() drains and forgets,
  * warmup() is an explicit method: a warmed session serves the stream
    with zero additional lowerings,
  * lane/bucket quantisation math (incl. the engine's pad_to_batch=False
    path, where the session's power-of-two lane classes take over batch
    shape stability from the engine).
"""
import numpy as np
import pytest

from repro.api import AlignSpec, CompileCache, plan
from repro.core.config import AlignerConfig, resolve_config
from repro.distributed.sharding import bucket_lanes, quantise_lanes

CFG = AlignerConfig(W=16, O=6, k=4)     # = test_differential.CFG

# one length class per band: read lens stay inside one pow2 bucket
_LEN_BANDS = ((24, 30), (50, 60), (100, 120))


def _ragged_stream(rng, n_batches=20, lanes=4):
    """n_batches of `lanes` (read, ref) pairs; batch j draws every length
    from one band so its bucket is deterministic, and bands rotate so the
    stream is genuinely mixed-length."""
    batches = []
    for j in range(n_batches):
        lo, hi = _LEN_BANDS[j % len(_LEN_BANDS)]
        reads, refs = [], []
        for _ in range(lanes):
            L = int(rng.integers(lo, hi + 1))
            read = rng.integers(0, 4, L).astype(np.uint8)
            reads.append(read)                # exact match: rounds=0 enough,
            refs.append(read.copy())          # dist == 0, nothing fails
        batches.append((reads, refs))
    return batches


@pytest.fixture(scope="module")
def stream_session():
    """One planned session shared by the streaming tests (its CompileCache
    persists, so later tests assert counter DELTAS).  cache='private':
    these tests count exact lowerings, so they must not see executables
    other suites put in the process-shared store (sharing itself is
    proven in tests/test_executor.py)."""
    return plan(CFG, rescue_rounds=0, batch_lanes=4, max_inflight=2,
                cache="private")


@pytest.fixture(scope="module")
def stream(stream_session):
    return _ragged_stream(np.random.default_rng(77))


def test_ragged_stream_compiles_each_bucket_exactly_once(stream_session,
                                                         stream):
    s = stream_session
    expected = set()
    for reads, refs in stream:
        expected.add((s.bucket_for(max(len(r) for r in reads),
                                   max(len(f) for f in refs)),
                      bucket_lanes(len(reads), s.cfg, s.mesh)))
        res = s.align(reads, refs)
        assert not res.failed.any()
    assert len(expected) == len(_LEN_BANDS)        # the stream is mixed
    assert s.stats["dispatches"] == len(stream)
    cs = s.cache.stats()
    # THE compile-stability claim: one lowering per distinct bucket, ever
    assert cs["misses"] == cs["lowerings"] == cs["executables"] \
        == len(expected)
    assert cs["hits"] == len(stream) - len(expected)
    # a whole second pass over the same ragged stream compiles NOTHING
    for reads, refs in stream:
        s.align(reads, refs)
    cs2 = s.cache.stats()
    assert cs2["lowerings"] == cs["lowerings"]
    assert cs2["hits"] == 2 * len(stream) - len(expected)
    assert sum(s.cache.bucket_hits.values()) == cs2["hits"]


def test_futures_resolve_out_of_order_and_double_buffering(stream_session,
                                                           stream):
    s = stream_session
    low0 = s.cache.lowerings
    futs = []
    for reads, refs in stream[:6]:           # 6 dispatches through 3 buckets
        for r, f in zip(reads, refs):
            futs.append(s.submit(r, f))
        # double buffering: at most max_inflight dispatches ever in flight
        assert len(s._inflight) <= s.spec.max_inflight
    # with 6 dispatches and max_inflight=2, the oldest retired eagerly:
    # their futures resolved while later batches were still being padded
    assert any(f.done() for f in futs[:4])
    assert not all(f.done() for f in futs)
    # resolve a LATE future first — earlier dispatches retire in order
    last = futs[-1].result()
    assert last["ok"] and last["dist"] == 0      # exact-match pairs
    assert all(f.done() for f in futs)
    got = s.results()
    # result() counts as collecting: the directly-collected rid is gone
    assert set(got) == {f.rid for f in futs} - {futs[-1].rid}
    assert s.results() == {}                     # drained and forgotten
    assert not s._open                           # streaming memory bounded
    assert s.cache.lowerings == low0             # streaming reused every exe


def test_warmup_is_a_method_not_a_side_effect(stream):
    """One band only: warm its bucket explicitly, then traffic is pure
    cache hits (the full 3-band warm+stream version is the serve example,
    a CI smoke job)."""
    s = plan(CFG, rescue_rounds=0, batch_lanes=4, cache="private")
    assert s.cache.lowerings == 0                # planning compiles nothing
    band = [b for b in stream
            if s.bucket_for(len(b[0][0]), len(b[1][0]))
            == s.bucket_for(_LEN_BANDS[0][1], _LEN_BANDS[0][1])]
    snap = s.warmup([(max(len(r) for r in reads), max(len(f) for f in refs))
                     for reads, refs in band])
    assert snap["lowerings"] == 1
    for reads, refs in band:
        s.align(reads, refs)
    assert s.cache.lowerings == snap["lowerings"]   # traffic compiles nothing


def test_session_bit_identical_to_legacy_aligner(corpus, diff_aligned):
    """Acceptance: the bucketed, AOT-compiled session reproduces
    GenASMAligner.align bit-for-bit on the differential corpus, although
    its pad widths are pow2 buckets rather than the batch's ragged max."""
    from tests.test_differential import CFG as DCFG, ROUNDS
    reads, refs, _ = corpus
    base = diff_aligned("jnp")
    s = plan(DCFG, rescue_rounds=ROUNDS, batch_lanes=len(reads))
    res = s.align(reads, refs)
    np.testing.assert_array_equal(res.failed, base.failed)
    np.testing.assert_array_equal(res.dist, base.dist)
    np.testing.assert_array_equal(res.k_used, base.k_used)
    np.testing.assert_array_equal(res.read_consumed, base.read_consumed)
    np.testing.assert_array_equal(res.ref_consumed, base.ref_consumed)
    assert res.cigars == base.cigars
    for a, b in zip(res.ops, base.ops):
        np.testing.assert_array_equal(a, b)
    # and the one summary dict both doors share
    assert res.summary(base_k=DCFG.k) == base.summary(base_k=DCFG.k)


@pytest.mark.slow
def test_session_device_rescue_mode_matches_bucket_mode(corpus):
    """rescue_mode='device' (whole on-device ladder per bucket, 1 upload +
    1 download) and 'bucket' (compacted per-rung dispatches) are the same
    alignment function.  (@slow: a second full-ladder AOT compile.)"""
    from tests.test_differential import CFG as DCFG, ROUNDS
    reads, refs, _ = corpus
    a = plan(DCFG, rescue_rounds=ROUNDS, rescue_mode="bucket",
             batch_lanes=len(reads)).align(reads, refs)
    b = plan(DCFG, rescue_rounds=ROUNDS, rescue_mode="device",
             batch_lanes=len(reads)).align(reads, refs)
    np.testing.assert_array_equal(a.failed, b.failed)
    np.testing.assert_array_equal(a.dist, b.dist)
    np.testing.assert_array_equal(a.k_used, b.k_used)
    for x, y in zip(a.ops, b.ops):
        np.testing.assert_array_equal(x, y)


def test_plan_resolves_and_validates_once():
    s = plan(CFG, backend="jnp", k=6, batch_lanes=3)
    assert s.cfg.k == 6 and s.cfg.W == CFG.W
    assert s.spec.batch_lanes == 4          # quantised to a pow2 lane class
    with pytest.raises(TypeError):
        plan(CFG, not_a_knob=1)
    with pytest.raises(AssertionError):
        plan(CFG, rescue_mode="teleport")
    with pytest.raises(ValueError, match="store"):
        resolve_config(CFG, backend="pallas_fused", store="and")
    assert AlignSpec(cfg=CFG).key() == AlignSpec(cfg=CFG).key()


def test_gpu_spec_keys_cache_separately_from_fused():
    """A pallas_gpu spec round-trips through fingerprint()/CompileCache
    without colliding with pallas_fused: the backend knob is hashed like
    every other field (fingerprint covers ALL dataclass fields), so the
    two lowerings of the same geometry can never serve each other's
    executables from the process-wide shared cache."""
    gpu = resolve_config(CFG, backend="pallas_gpu")
    tpu = resolve_config(CFG, backend="pallas_fused")
    assert gpu.fingerprint() != tpu.fingerprint()
    # equal configs fingerprint equal: the round-trip half of the contract
    assert gpu.fingerprint() == resolve_config(CFG,
                                               backend="pallas_gpu"
                                               ).fingerprint()
    ka, kb = AlignSpec(cfg=gpu).key(), AlignSpec(cfg=tpu).key()
    assert ka != kb
    c = CompileCache()
    assert c.get((ka, 64), lambda: "exe-gpu") == "exe-gpu"
    assert c.get((kb, 64), lambda: "exe-tpu") == "exe-tpu"
    assert c.get((ka, 64), lambda: "never") == "exe-gpu"   # hit, no rebuild
    assert (c.hits, c.misses) == (1, 2)


def test_lane_and_bucket_quantisation_math(monkeypatch):
    cfg = CFG
    assert quantise_lanes(5, cfg, None) == 5        # unsharded quantum is 1
    assert bucket_lanes(5, cfg, None) == 8          # pow2 lane class
    assert bucket_lanes(0, cfg, None) == 1
    assert bucket_lanes(bucket_lanes(50, cfg, None), cfg, None) \
        == bucket_lanes(50, cfg, None) == 64        # idempotent unsharded
    # the negotiated ladder adaptive batching walks: quantised classes up
    # to (and including) the ceiling's class, ascending
    from repro.distributed.sharding import lane_classes, mesh_fingerprint
    assert lane_classes(64, cfg, None) == (1, 2, 4, 8, 16, 32, 64)
    assert lane_classes(5, cfg, None) == (1, 2, 4, 8)
    assert mesh_fingerprint(None) == ("nomesh",)
    # a mesh-like quantum (lane_tile * n_devices) — patched, no devices
    from repro.distributed import sharding
    monkeypatch.setattr(sharding, "pair_pad_multiple",
                        lambda cfg, mesh: 6)
    assert sharding.quantise_lanes(5, cfg, "fake-mesh") == 6
    assert sharding.quantise_lanes(7, cfg, "fake-mesh") == 12
    # lane classes are quantise(2^j) = 6, 12, 18, 36, ... : smallest >= n
    assert sharding.bucket_lanes(5, cfg, "fake-mesh") == 6
    assert sharding.bucket_lanes(7, cfg, "fake-mesh") == 12
    assert sharding.bucket_lanes(13, cfg, "fake-mesh") == 18
    # idempotent: a planned batch_lanes never inflates at dispatch time
    for n in (6, 12, 18, 36):
        assert sharding.bucket_lanes(n, cfg, "fake-mesh") == n
    # the ladder under a non-pow2 quantum: every rung is a quantised class
    assert sharding.lane_classes(13, cfg, "fake-mesh") == (6, 12, 18)


@pytest.mark.slow
def test_engine_pad_to_batch_false_leans_on_session_buckets(corpus):
    """pad_to_batch=False: the engine no longer pads to batch_size, so the
    SESSION's pow2 lane classes are what keeps shapes stable — 7 requests
    become dispatches of 8 and 2 lanes, with engine-level padded_lanes 0.
    (@slow: two fresh lane-class compiles; the quantisation math itself is
    covered tier-1 by test_lane_and_bucket_quantisation_math, and the
    sharded pad_multiple path by tests/test_multidevice.py.)"""
    from repro.serve.engine import AlignmentEngine, AlignRequest
    from tests.test_differential import CFG as DCFG
    reads, refs, _ = corpus
    eng = AlignmentEngine(DCFG, batch_size=5, rescue_rounds=0,
                          pad_to_batch=False)
    assert eng.batch_size == 5              # quantum 1 unsharded
    for i in range(7):
        eng.submit(AlignRequest(rid=i, read=reads[i], ref=refs[i]))
    stats = eng.serve_until_empty()
    assert stats["batches"] == 2 and stats["padded_lanes"] == 0
    assert stats["aligned"] + stats["failed"] == 7
    ses = eng.aligner
    assert ses.stats["dispatches"] == 2
    assert ses.stats["lanes"] == 8 + 2      # session lane classes
    assert ses.stats["pad_lanes"] == 3      # 5->8; 2->2
    assert set(eng.results) == set(range(7))


def test_compile_cache_counters_unit():
    c = CompileCache()
    built = []
    assert c.get("a", lambda: built.append(1) or "exe-a") == "exe-a"
    assert c.get("a", lambda: built.append(1) or "never") == "exe-a"
    assert c.get("b", lambda: "exe-b") == "exe-b"
    assert (c.hits, c.misses, c.lowerings, len(c)) == (1, 2, 2, 2)
    assert built == [1]
    assert c.stats()["bucket_hits"] == {"a": 1}
