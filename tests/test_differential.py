"""Differential fuzz suite: seeded random (read, ref, error-profile) pairs
aligned by EVERY backend (jnp / pallas / pallas_fused / pallas_gpu) and by
both rescue modes (host numpy loop vs on-device masked k-doubling),
checked against the
classic DP oracle (core.oracle) and the KSW2-like banded DP baseline
(baselines.dp) with unit costs.

The claims CI enforces here:
  * every produced CIGAR is a valid alignment whose cost equals the
    reported dist (oracle.validate_cigar),
  * dist is never below the true edit distance (windowed GenASM is an
    upper-bound heuristic), and matches the banded-DP baseline within the
    expected windowing slack on well-behaved profiles,
  * all backends and both rescue modes are bit-identical (ops, dist,
    k_used, failed) — the fused-tail + on-device-rescue acceptance sweep
    (>= 200 pairs) runs nightly (@slow), a fast subset on every push.

Profiles deliberately include indel-heavy, homopolymer, N-base (read 'N'
encodes to SENTINEL_PAT, ref 'N' to SENTINEL_TEXT — see
core.aligner.encode_ref) and length-mismatch corner cases.  Uses the
tests/_hyp shim, so it runs with or without hypothesis installed.
"""
import numpy as np
import pytest

from repro.baselines.dp import banded_affine_dist
from repro.core.aligner import GenASMAligner
from repro.core.bitops import SENTINEL_PAT, SENTINEL_TEXT
from repro.core.config import AlignerConfig
from repro.core.oracle import levenshtein, validate_cigar
from tests._hyp import given, settings, st

CFG = AlignerConfig(W=16, O=6, k=4)
ROUNDS = 1
PROFILES = ("uniform", "indel_heavy", "homopolymer", "n_base", "len_mismatch")
# err rate, (sub, ins, del) weights
_PROFILE_ERR = {
    "uniform": (0.08, (40, 35, 25)),
    "indel_heavy": (0.15, (10, 45, 45)),
    "homopolymer": (0.12, (25, 40, 35)),
    "n_base": (0.06, (40, 35, 25)),
    "len_mismatch": (0.08, (40, 35, 25)),
}


def _walk_read(ref, rng, err, fracs, read_len):
    """Emit a read by walking ref with a (sub, ins, del) error profile;
    returns (read, ref_span_consumed)."""
    sub_f, ins_f, del_f = fracs
    tot = sub_f + ins_f + del_f
    p_sub, p_ins, p_del = (err * f / tot for f in (sub_f, ins_f, del_f))
    out = []
    i = 0
    while len(out) < read_len and i < len(ref):
        x = rng.random()
        if x < p_del:
            i += 1
        elif x < p_del + p_ins:
            out.append(int(rng.integers(0, 4)))
        elif x < p_del + p_ins + p_sub:
            out.append(int((ref[i] + 1 + rng.integers(0, 3)) % 4))
            i += 1
        else:
            out.append(int(ref[i]))
            i += 1
    while len(out) < read_len:
        out.append(int(rng.integers(0, 4)))
    return np.array(out[:read_len], np.uint8), i


def _homopolymer_ref(rng, length):
    out = []
    while len(out) < length:
        out.extend([int(rng.integers(0, 4))] * int(1 + rng.integers(1, 8)))
    return np.array(out[:length], np.uint8)


def make_pair(rng, profile, read_len=36):
    ref_len = int(read_len * 1.3) + 8
    if profile == "homopolymer":
        base = _homopolymer_ref(rng, ref_len)
    else:
        base = rng.integers(0, 4, ref_len).astype(np.uint8)
    err, fracs = _PROFILE_ERR[profile]
    read, span = _walk_read(base, rng, err, fracs, read_len)
    ref = base[:span].copy()
    if profile == "n_base":
        read = np.where(rng.random(len(read)) < 0.04,
                        np.uint8(SENTINEL_PAT), read)     # read 'N'
        ref = np.where(rng.random(len(ref)) < 0.04,
                       np.uint8(SENTINEL_TEXT), ref)      # ref 'N'
    elif profile == "len_mismatch":
        if rng.random() < 0.5:
            ref = ref[:max(4, int(len(ref) * 0.7))]       # ref too short
        else:                                             # ref too long
            extra = rng.integers(0, 4, int(rng.integers(4, 12)))
            ref = np.concatenate([ref, extra.astype(np.uint8)])
    return read, ref


def make_corpus(seed, n_per_profile, read_len=36,
                profiles=PROFILES):
    rng = np.random.default_rng(seed)
    reads, refs, profs = [], [], []
    for profile in profiles:
        for _ in range(n_per_profile):
            r, f = make_pair(rng, profile, read_len)
            reads.append(r)
            refs.append(f)
            profs.append(profile)
    return reads, refs, profs


# `corpus` and `diff_aligned` are session fixtures in tests/conftest.py
# (shared with the CIGAR invariant suite in tests/test_cigar.py).


def test_cigars_valid_and_dist_upper_bounds_oracle(corpus, diff_aligned):
    """Every non-failed lane: CIGAR is a valid alignment, its cost equals
    the reported dist, and dist >= the true edit distance."""
    reads, refs, profs = corpus
    res = diff_aligned("jnp")
    n_solved = 0
    for i in range(len(reads)):
        if res.failed[i]:
            continue
        validate_cigar(reads[i], refs[i], res.ops[i],
                       expected_dist=res.dist[i])
        assert res.dist[i] >= levenshtein(reads[i], refs[i]), profs[i]
        n_solved += 1
    # the benign profiles must overwhelmingly solve
    benign = [i for i, p in enumerate(profs) if p != "len_mismatch"]
    assert sum(not res.failed[i] for i in benign) >= int(0.8 * len(benign))
    assert n_solved > 0


def _assert_bit_identical(res, ref_res, label):
    assert list(res.dist) == list(ref_res.dist), label
    assert list(res.failed) == list(ref_res.failed), label
    assert list(res.k_used) == list(ref_res.k_used), label
    assert res.cigars == ref_res.cigars, label
    for a, b in zip(res.ops, ref_res.ops):
        np.testing.assert_array_equal(a, b, err_msg=label)


def test_fused_backend_bit_identical(corpus, diff_aligned):
    """pallas_fused (fused main windows + fused rectangular tail + on-device
    rescue) == jnp on the mixed-profile corpus, bit for bit."""
    _assert_bit_identical(diff_aligned("pallas_fused"), diff_aligned("jnp"),
                          "pallas_fused")


def test_fused_banded_tail_bit_identical(corpus, diff_aligned):
    """The Scrooge-style banded tail store, FORCED on (this geometry has
    nwb == nw, so 'auto' falls back to the full store — this leg pins the
    fallback-boundary case where the band covers whole words), must still
    be bit-identical to jnp across the mixed-profile corpus, rescue
    included."""
    import dataclasses
    reads, refs, _ = corpus
    cfg = dataclasses.replace(CFG, tail_store="band")
    assert not CFG.tail_band_supported          # boundary: no strict win
    res = GenASMAligner(cfg, rescue_rounds=ROUNDS,
                        backend="pallas_fused").align(reads, refs)
    _assert_bit_identical(res, diff_aligned("jnp"), "banded tail")


def test_gpu_backend_bit_identical(corpus, diff_aligned):
    """pallas_gpu (the Triton lowering of the same fused kernels, band as
    a GMEM output block instead of VMEM scratch) == jnp on the
    mixed-profile corpus, bit for bit — interpret mode on this CPU
    runner, the compiled-CUDA parity leg lives in test_kernel_fused and
    is inverse-guarded in CI."""
    _assert_bit_identical(diff_aligned("pallas_gpu"), diff_aligned("jnp"),
                          "pallas_gpu")


def test_gpu_backend_host_rescue_bit_identical(corpus, diff_aligned):
    """pallas_gpu under the host numpy rescue loop too: both rescue modes
    of the new backend hit the full corpus (the acceptance contract —
    5 profiles x both rescue modes, bit-identical to jnp)."""
    _assert_bit_identical(diff_aligned("pallas_gpu", "host"),
                          diff_aligned("jnp"), "pallas_gpu host rescue")


@pytest.mark.slow
def test_split_pallas_backend_bit_identical(corpus, diff_aligned):
    """The split kernel (DC on-chip, band to HBM, jnp traceback) too; its
    per-window DC identity is already covered in tier-1 by test_kernels."""
    _assert_bit_identical(diff_aligned("pallas"), diff_aligned("jnp"),
                          "pallas")


@pytest.mark.slow
def test_device_rescue_matches_host_loop(corpus, diff_aligned):
    """On-device masked rescue == legacy host numpy loop, bit for bit.
    (@slow: the host loop re-pads/re-compiles per round; tier-1 keeps the
    host-vs-device gate via tests/test_rescue.py's smaller geometry.)"""
    dev = diff_aligned("jnp", "device")
    host = diff_aligned("jnp", "host")
    assert list(dev.dist) == list(host.dist)
    assert list(dev.failed) == list(host.failed)
    assert list(dev.k_used) == list(host.k_used)
    assert dev.cigars == host.cigars
    for a, b in zip(dev.ops, host.ops):
        np.testing.assert_array_equal(a, b)


def test_dist_matches_banded_dp_baseline(corpus, diff_aligned):
    """Against baselines/dp.py with unit costs (= edit distance inside the
    band): windowed dist is never below it, and stays within the expected
    windowing slack on the uniform profile."""
    reads, refs, profs = corpus
    res = diff_aligned("jnp")
    B = len(reads)
    m = max(len(r) for r in reads)
    n = max(len(f) for f in refs)
    pat = np.full((B, m), SENTINEL_PAT, np.uint8)
    txt = np.full((B, n), SENTINEL_TEXT, np.uint8)
    ml = np.zeros(B, np.int32)
    nl = np.zeros(B, np.int32)
    for i, (r, f) in enumerate(zip(reads, refs)):
        pat[i, :len(r)] = r
        ml[i] = len(r)
        txt[i, :len(f)] = f
        nl[i] = len(f)
    import jax.numpy as jnp
    dp = np.asarray(banded_affine_dist(
        jnp.asarray(pat, jnp.int32), jnp.asarray(txt, jnp.int32),
        jnp.asarray(ml), jnp.asarray(nl), bw=32, m=m))
    for i in range(B):
        if res.failed[i]:
            continue
        assert res.dist[i] >= dp[i], (i, profs[i])
        if profs[i] == "uniform":
            assert res.dist[i] <= dp[i] * 1.5 + 3, (i, profs[i])


@pytest.mark.slow
@given(st.integers(0, 2**31 - 1))
@settings(max_examples=2, deadline=None)
def test_fuzz_random_seeds_host_device_and_oracle(seed):
    """Property-style sweep over fresh corpora: host-loop and on-device
    rescue agree, and the produced alignments stay oracle-valid.  Shapes
    are held fixed across examples so the jit cache is reused."""
    reads, refs, _ = make_corpus(seed=seed, n_per_profile=2)
    # pin the padded ref width across examples: one max-width ref
    rng = np.random.default_rng(seed + 1)
    width = int(36 * 1.3) + 20
    refs = [f[:width] for f in refs]
    refs[0] = np.concatenate(
        [refs[0], rng.integers(0, 4, width - len(refs[0])).astype(np.uint8)])
    dev = GenASMAligner(CFG, rescue_rounds=ROUNDS).align(reads, refs)
    host = GenASMAligner(CFG, rescue_rounds=ROUNDS,
                         rescue_mode="host").align(reads, refs)
    assert list(dev.dist) == list(host.dist)
    assert list(dev.failed) == list(host.failed)
    assert list(dev.k_used) == list(host.k_used)
    for i in range(len(reads)):
        np.testing.assert_array_equal(dev.ops[i], host.ops[i])
        if not dev.failed[i]:
            validate_cigar(reads[i], refs[i], dev.ops[i],
                           expected_dist=dev.dist[i])
            assert dev.dist[i] >= levenshtein(reads[i], refs[i])


@pytest.mark.slow
def test_differential_sweep_fused_vs_host_jnp_200_pairs():
    """The acceptance sweep (nightly): >= 200 mixed-profile pairs, fused
    backend + fused tail + on-device rescue vs the host-loop jnp path —
    bit-identical ops, dist, k_used and failed on every lane."""
    reads, refs, profs = make_corpus(seed=424242, n_per_profile=44,
                                     read_len=72)
    assert len(reads) >= 200
    cfg = AlignerConfig(W=32, O=12, k=6)
    host = GenASMAligner(cfg, rescue_rounds=2, rescue_mode="host").align(
        reads, refs)
    dev = GenASMAligner(cfg, rescue_rounds=2,
                        backend="pallas_fused").align(reads, refs)
    assert list(dev.dist) == list(host.dist)
    assert list(dev.failed) == list(host.failed)
    assert list(dev.k_used) == list(host.k_used)
    assert dev.cigars == host.cigars
    for i, (a, b) in enumerate(zip(dev.ops, host.ops)):
        np.testing.assert_array_equal(a, b, err_msg=f"lane {i} ({profs[i]})")
    # the corpus must actually exercise rescue and failure paths
    assert (dev.k_used[~dev.failed] > cfg.k).any()
    for i in range(len(reads)):
        if not dev.failed[i]:
            validate_cigar(reads[i], refs[i], dev.ops[i],
                           expected_dist=dev.dist[i])
