"""Rescue semantics: the on-device masked k-doubling loop vs the host loop.

Properties enforced:
  * rescue_rounds=0 is exactly plain align_pairs (plus k_used bookkeeping),
  * k_used is minimal on the k-doubling ladder (the previous rung fails),
  * failed / k_used / ops agree between host-loop and on-device rescue,
  * lanes are independent: permuting the batch permutes the results
    (the per-lane mask never leaks state across lanes),
  * the on-device path performs exactly one upload and one download per
    batch, independent of how many rescue rounds run (the zero
    per-round-round-trip claim); the host loop pays per executed round.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import transfer
from repro.core.aligner import GenASMAligner
from repro.core.config import AlignerConfig
from repro.core.oracle import validate_cigar
from repro.core.windowing import (SENTINEL_READ, SENTINEL_REF, align_pairs,
                                  align_pairs_rescued, rescue_schedule,
                                  self_tail_width)

CFG = AlignerConfig(W=16, O=6, k=2)
ROUNDS = 2                                     # ladder [2, 4, 8]


def _mk_corpus(seed=5, n=8, read_len=36):
    """Error gradient (clean ... heavy-indel) + one decoy: spans the whole
    k-doubling ladder, including never-solved lanes."""
    from tests.test_differential import _walk_read

    rng = np.random.default_rng(seed)
    reads, refs = [], []
    for i in range(n):
        ref = rng.integers(0, 4, int(read_len * 1.3) + 8).astype(np.uint8)
        err = (0.0, 0.05, 0.1, 0.18, 0.28, 0.4)[i % 6]
        read, span = _walk_read(ref, rng, err, (30, 35, 35), read_len)
        reads.append(read)
        refs.append(ref[:span].copy())
    # decoy: unrelated ref of plausible length -> fails the whole ladder
    reads.append(reads[0].copy())
    refs.append(rng.integers(0, 4, len(refs[0])).astype(np.uint8))
    return reads, refs


def _pad_batch(reads, refs, cfg, rescue_rounds):
    wt = self_tail_width(rescue_schedule(cfg, rescue_rounds)[-1])
    max_r = max(len(r) for r in reads)
    B = len(reads)
    rpad = np.full((B, max_r + cfg.W + 1), SENTINEL_READ, np.uint8)
    fpad = np.full((B, max(len(f) for f in refs) + cfg.W + wt + 1),
                   SENTINEL_REF, np.uint8)
    rlen = np.zeros(B, np.int32)
    flen = np.zeros(B, np.int32)
    for i, (r, f) in enumerate(zip(reads, refs)):
        rpad[i, :len(r)] = r
        rlen[i] = len(r)
        fpad[i, :len(f)] = f
        flen[i] = len(f)
    return (jnp.asarray(rpad), jnp.asarray(rlen), jnp.asarray(fpad),
            jnp.asarray(flen)), max_r


@pytest.fixture(scope="module")
def corpus():
    return _mk_corpus()


@pytest.fixture(scope="module")
def dev_res(corpus):
    return GenASMAligner(CFG, rescue_rounds=ROUNDS).align(*corpus)


@pytest.fixture(scope="module")
def host_res(corpus):
    return GenASMAligner(CFG, rescue_rounds=ROUNDS,
                         rescue_mode="host").align(*corpus)


def test_rescue_schedule_doubles_and_caps():
    ks = [c.k for c in rescue_schedule(CFG, 5)]
    assert ks == [2, 4, 8, 15]                 # doubled, capped at W-1, deduped
    assert [c.k for c in rescue_schedule(CFG, 0)] == [2]
    capped = AlignerConfig(W=16, O=6, k=15)
    assert [c.k for c in rescue_schedule(capped, 3)] == [15]


def test_rescue_rounds_zero_equals_plain_align_pairs(corpus):
    reads, refs = corpus
    args, max_r = _pad_batch(reads, refs, CFG, 0)
    plain = align_pairs(*args, cfg=CFG, max_read_len=max_r)
    resc = align_pairs_rescued(*args, cfg=CFG, max_read_len=max_r,
                               rescue_rounds=0)
    for key in ("n_ops", "dist", "failed", "read_consumed", "ref_consumed"):
        np.testing.assert_array_equal(np.asarray(resc[key]),
                                      np.asarray(plain[key]), err_msg=key)
    np.testing.assert_array_equal(np.asarray(resc["ops"]),
                                  np.asarray(plain["ops"]))
    failed = np.asarray(plain["failed"])
    np.testing.assert_array_equal(np.asarray(resc["k_used"]),
                                  np.where(failed, 0, CFG.k))
    assert int(resc["n_rounds"]) == 1


def test_k_used_minimal_on_ladder(corpus, dev_res):
    """Solving at k_used implies failing at the previous ladder rung.
    Lanes are grouped by rung so each distinct prev-k compiles one batched
    align instead of one per lane."""
    reads, refs = corpus
    ks = [c.k for c in rescue_schedule(CFG, ROUNDS)]
    rescued = [i for i in range(len(reads))
               if not dev_res.failed[i] and dev_res.k_used[i] > CFG.k]
    assert rescued, "corpus must exercise the ladder"
    by_rung = {}
    for i in rescued:
        prev_k = ks[ks.index(int(dev_res.k_used[i])) - 1]
        by_rung.setdefault(prev_k, []).append(i)
        validate_cigar(reads[i], refs[i], dev_res.ops[i],
                       expected_dist=dev_res.dist[i])
    for prev_k, lanes in by_rung.items():
        again = GenASMAligner(
            AlignerConfig(W=CFG.W, O=CFG.O, k=prev_k),
            rescue_rounds=0).align([reads[i] for i in lanes],
                                   [refs[i] for i in lanes])
        assert again.failed.all(), \
            f"lanes {lanes}: k_used minimal claim broken at k={prev_k}"


def test_failed_flag_agrees_host_vs_device(corpus, dev_res, host_res):
    np.testing.assert_array_equal(dev_res.failed, host_res.failed)
    np.testing.assert_array_equal(dev_res.k_used, host_res.k_used)
    np.testing.assert_array_equal(dev_res.dist, host_res.dist)
    for a, b in zip(dev_res.ops, host_res.ops):
        np.testing.assert_array_equal(a, b)
    assert dev_res.failed[-1]                  # the decoy never aligns
    assert not dev_res.failed[0]               # the clean lane always does


def test_gpu_backend_rescue_ladder_bit_identical(corpus, dev_res):
    """The full k-doubling ladder under backend='pallas_gpu' (Triton
    lowering, interpret mode here) == the jnp on-device ladder, bit for
    bit — including the decoy lane that fails every rung."""
    gpu = GenASMAligner(CFG, rescue_rounds=ROUNDS,
                        backend="pallas_gpu").align(*corpus)
    np.testing.assert_array_equal(gpu.failed, dev_res.failed)
    np.testing.assert_array_equal(gpu.k_used, dev_res.k_used)
    np.testing.assert_array_equal(gpu.dist, dev_res.dist)
    assert gpu.cigars == dev_res.cigars
    for a, b in zip(gpu.ops, dev_res.ops):
        np.testing.assert_array_equal(a, b)


def test_lane_independence_under_permutation(corpus, dev_res):
    """Permuting the batch permutes the results: the rescue mask freezes
    solved lanes without leaking state across lanes.  Same shapes/config as
    dev_res, so the permuted align reuses its compile."""
    reads, refs = corpus
    perm = np.random.default_rng(9).permutation(len(reads))
    shuf = GenASMAligner(CFG, rescue_rounds=ROUNDS).align(
        [reads[i] for i in perm], [refs[i] for i in perm])
    for loc, glob in enumerate(perm):
        assert shuf.dist[loc] == dev_res.dist[glob]
        assert shuf.failed[loc] == dev_res.failed[glob]
        assert shuf.k_used[loc] == dev_res.k_used[glob]
        np.testing.assert_array_equal(shuf.ops[loc], dev_res.ops[glob])


def test_session_bucket_rescue_bit_identical_to_host_loop(corpus, host_res):
    """The rescue-efficiency item (ROADMAP): repro.api.AlignSession's
    'bucket' rescue gathers still-failed lanes and compacts them into the
    next-smaller length/lane bucket per k-doubling rung — solved lanes'
    windows are never recomputed (unlike the on-device ladder, which
    re-runs the whole batch under a mask) and shapes stay bucket-stable
    (unlike the host loop, which re-traces ragged subsets).  Must be
    bit-identical per lane to rescue_mode='host'."""
    from repro.api import plan
    reads, refs = corpus
    # cache='private': the lowerings count below must not see executables
    # other suites put in the process-shared store
    s = plan(CFG, rescue_rounds=ROUNDS, rescue_mode="bucket",
             batch_lanes=len(reads), cache="private")
    res = s.align(reads, refs)
    np.testing.assert_array_equal(res.failed, host_res.failed)
    np.testing.assert_array_equal(res.dist, host_res.dist)
    np.testing.assert_array_equal(res.k_used, host_res.k_used)
    np.testing.assert_array_equal(res.read_consumed, host_res.read_consumed)
    np.testing.assert_array_equal(res.ref_consumed, host_res.ref_consumed)
    assert res.cigars == host_res.cigars
    for a, b in zip(res.ops, host_res.ops):
        np.testing.assert_array_equal(a, b)
    # compaction really happened: the decoy keeps every ladder rung alive,
    # and each rescue dispatch ran on a SMALLER lane class than round 0
    st = s.stats
    assert st["rescue_dispatches"] == ROUNDS
    assert st["rescue_lanes"] < st["rescue_dispatches"] * st["lanes"]
    # each rung's executable is its own cached bucket (round 0 + 2 rungs)
    assert s.cache.stats()["lowerings"] == 1 + ROUNDS


@pytest.mark.slow
def test_device_rescue_zero_per_round_roundtrips_fused_backend(corpus):
    """The transfer-counting acceptance check: with the fused backend the
    whole multi-round rescue costs exactly one host->device upload and one
    device->host download — zero per-round round-trips — while the host
    loop pays one of each per executed round.  (@slow: two fresh fused
    ladder compiles; tier-1 keeps the 1x/1x assertion in
    tests/test_multidevice.py where it rides the sharded parity run.)"""
    reads, refs = corpus
    reads, refs = reads[:4] + [reads[-1]], refs[:4] + [refs[-1]]
    transfer.reset()
    GenASMAligner(CFG, rescue_rounds=1,
                  backend="pallas_fused").align(reads, refs)
    s = transfer.stats()
    assert (s.h2d_calls, s.d2h_calls) == (1, 1)

    transfer.reset()
    GenASMAligner(CFG, rescue_rounds=1, rescue_mode="host",
                  backend="pallas_fused").align(reads, refs)
    s_host = transfer.stats()
    # the decoy fails k=2 and k=4, so both ladder rounds execute
    assert s_host.d2h_calls == 2
    assert s_host.h2d_calls == 2
