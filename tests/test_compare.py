"""benchmarks/compare.py gate semantics: what gates, what only reports.

The perf-trajectory gate is CI policy, so its edge cases are tested like
code: a zero baseline must not silently pass (regression), new metrics
report-but-don't-gate, and the direction signs gate floors vs ceilings
correctly.
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.compare import compare, render  # noqa: E402


def _report(**derived):
    return {"derived": derived}


def test_zero_baseline_reports_but_never_gates():
    """Regression: baseline 0 made `delta = 0.0` and the throughput floor
    `c >= 0 * (1 - t)` trivially true — any current value rendered as
    `ok +0.0%`.  It must surface as its own ungated status instead."""
    base = _report(session={"pairs_per_s": 0.0})
    cur = _report(session={"pairs_per_s": 123.0})
    rows, regressions, added, removed = compare(cur, base, 0.30)
    assert regressions == [] and added == [] and removed == []
    (name, b, c, delta, status), = rows
    assert name == "session.pairs_per_s" and (b, c) == (0.0, 123.0)
    assert delta is None
    assert status == "zero-baseline (not gated)"
    assert "ok" not in status
    table = render(rows, regressions, added, removed, 0.30, "BENCH_X.json")
    assert "zero-baseline (not gated)" in table and "✅" not in table
    # zero CURRENT against a real baseline is a genuine 100% drop: gated
    rows2, regs2, _, _ = compare(base, cur, 0.30)
    assert regs2 == ["session.pairs_per_s"]


def test_pending_hardware_rows_annotated_not_gated():
    """Zero on BOTH sides is a committed placeholder for hardware the
    runner lacks (the pallas_gpu family on CPU CI): it must render as
    'pending-hardware', distinct from the suspicious one-sided
    'zero-baseline', and gate nothing — until the first GPU nightly puts
    a real number on both sides, at which point the ordinary floor
    applies."""
    base = _report(aligners={"gpu_pairs_per_s": 0.0})
    cur = _report(aligners={"gpu_pairs_per_s": 0.0})
    rows, regs, added, removed = compare(cur, base, 0.30)
    assert regs == [] and added == [] and removed == []
    (name, b, c, delta, status), = rows
    assert name == "aligners.gpu_pairs_per_s" and (b, c) == (0.0, 0.0)
    assert delta is None
    assert status == "pending-hardware (not gated)"
    table = render(rows, regs, added, removed, 0.30, "BENCH_X.json")
    assert "pending-hardware (not gated)" in table
    assert "✅" not in table and "❌" not in table
    # first measured GPU run against the placeholder: still ungated
    # (zero-baseline), NOT a spurious pass or fail
    measured = _report(aligners={"gpu_pairs_per_s": 450.0})
    rows2, regs2, _, _ = compare(measured, base, 0.30)
    assert regs2 == []
    assert rows2[0][4] == "zero-baseline (not gated)"
    # and once both sides are measured, the throughput floor gates
    _, regs3, _, _ = compare(_report(aligners={"gpu_pairs_per_s": 100.0}),
                             measured, 0.30)
    assert regs3 == ["aligners.gpu_pairs_per_s"]


def test_direction_signs_gate_floor_and_ceiling():
    base = _report(session={"pairs_per_s": 100.0},
                   memory={"vmem_bytes": 1000.0})
    ok_cur = _report(session={"pairs_per_s": 80.0},
                     memory={"vmem_bytes": 1200.0})
    bad_cur = _report(session={"pairs_per_s": 60.0},
                      memory={"vmem_bytes": 1400.0})
    _, regs, _, _ = compare(ok_cur, base, 0.30)
    assert regs == []
    _, regs, _, _ = compare(bad_cur, base, 0.30)
    assert set(regs) == {"memory.vmem_bytes", "session.pairs_per_s"}


def test_mapper_throughput_is_gated():
    """mapped_reads_per_s joined GATED in this PR: a drop past the
    threshold must fail the gate like pairs/s does."""
    base = _report(mapper={"mapper_mapped_reads_per_s": 100.0})
    cur = _report(mapper={"mapper_mapped_reads_per_s": 50.0})
    _, regs, _, _ = compare(cur, base, 0.30)
    assert regs == ["mapper.mapper_mapped_reads_per_s"]


def test_added_and_removed_metrics_report_only():
    base = _report(session={"pairs_per_s": 100.0})
    cur = _report(session={"pairs_per_s": 100.0},
                  mapper={"mapper_mapped_reads_per_s": 10.0})
    rows, regs, added, removed = compare(cur, base, 0.30)
    assert regs == [] and removed == []
    assert added == ["mapper.mapper_mapped_reads_per_s"]


def test_gateway_slo_latency_semantics():
    """The PR-8 SLO keys: latency_p99_ms and shed_rate gate GROWTH (a
    ceiling, like vmem_bytes), deadline_hit_rate gates DROPS (a floor,
    like throughput) — latency at its widened tolerance (see
    test_latency_p99_widened_tolerance), the rates at the default."""
    base = _report(gateway={"latency_p99_ms": 10.0, "shed_rate": 0.20,
                            "deadline_hit_rate": 1.0})
    ok_cur = _report(gateway={"latency_p99_ms": 12.0, "shed_rate": 0.25,
                              "deadline_hit_rate": 0.80})
    _, regs, _, _ = compare(ok_cur, base, 0.30)
    assert regs == []
    worse = _report(gateway={"latency_p99_ms": 25.0, "shed_rate": 0.22,
                             "deadline_hit_rate": 0.60})
    _, regs, _, _ = compare(worse, base, 0.30)
    assert set(regs) == {"gateway.latency_p99_ms",
                         "gateway.deadline_hit_rate"}
    # the shed ceiling fails on its own too
    shed_storm = _report(gateway={"latency_p99_ms": 10.0,
                                  "shed_rate": 0.50,
                                  "deadline_hit_rate": 1.0})
    _, regs, _, _ = compare(shed_storm, base, 0.30)
    assert regs == ["gateway.shed_rate"]


def test_gateway_slo_improvements_never_gate():
    """Lower latency, fewer sheds, higher hit rate: all strictly better —
    the direction-aware gate must stay green in the good direction."""
    base = _report(gateway={"latency_p99_ms": 10.0, "shed_rate": 0.20,
                            "deadline_hit_rate": 0.90})
    better = _report(gateway={"latency_p99_ms": 1.0, "shed_rate": 0.0,
                              "deadline_hit_rate": 1.0})
    rows, regs, _, _ = compare(better, base, 0.30)
    assert regs == []
    assert all(status == "ok" for *_, status in rows)


def test_meta_provenance_rendered_beside_table():
    """benchmarks.run writes a ``meta`` block (jax version, cpu count,
    git sha, timestamp, platform); render() must show it for both
    reports — and say so explicitly when a pre-PR9 report has none — so
    a regression caused by a different machine/jax/sha is diagnosable
    at a glance."""
    base = _report(session={"pairs_per_s": 100.0})
    cur = {"derived": {"session": {"pairs_per_s": 100.0}},
           "meta": {"jax_version": "0.4.37", "cpu_count": 1,
                    "git_sha": "abc1234",
                    "timestamp_utc": "2026-08-08T00:00:00+00:00",
                    "platform": "Linux-x86_64"}}
    rows, regs, added, removed = compare(cur, base, 0.30)
    table = render(rows, regs, added, removed, 0.30, "BENCH_X.json",
                   current=cur, baseline=base)
    assert "> current: jax=0.4.37 cpus=1 sha=abc1234" in table
    assert "> baseline: no meta block (pre-PR9 report)" in table
    # meta must never leak into the gate itself
    assert regs == [] and added == [] and removed == []


def test_latency_p99_widened_tolerance():
    """latency_p99_ms carries a 3x tolerance multiplier (1-core runner
    tail noise): +80% growth passes at the default 0.30 threshold, while
    a genuine order-of-magnitude regression still fails."""
    base = _report(gateway={"latency_p99_ms": 10.0, "shed_rate": 0.20})
    noisy = _report(gateway={"latency_p99_ms": 18.0, "shed_rate": 0.20})
    _, regs, _, _ = compare(noisy, base, 0.30)
    assert regs == []                      # within 30% * 3.0 = 90%
    bad = _report(gateway={"latency_p99_ms": 25.0, "shed_rate": 0.20})
    _, regs, _, _ = compare(bad, base, 0.30)
    assert regs == ["gateway.latency_p99_ms"]
    # shed_rate keeps the TIGHT default: +40% growth fails
    shed = _report(gateway={"latency_p99_ms": 10.0, "shed_rate": 0.28})
    _, regs, _, _ = compare(shed, base, 0.30)
    assert regs == ["gateway.shed_rate"]
