"""Long-read windowed alignment: validity, accuracy vs full DP, variants."""
import numpy as np
import pytest

from repro.core.aligner import GenASMAligner
from repro.core.config import AlignerConfig
from repro.core.oracle import levenshtein, validate_cigar
from repro.data.genome import ReadSimConfig, simulate_reads, synth_genome


@pytest.fixture(scope="module")
def readset():
    g = synth_genome(60_000, seed=7)
    return simulate_reads(g, 6, ReadSimConfig(read_len=500, error_rate=0.08,
                                              seed=13))


@pytest.mark.parametrize("store,et", [("band", True), ("and", True),
                                      ("edges4", False)])
def test_windowed_alignment_valid_all_variants(readset, store, et):
    cfg = AlignerConfig(W=64, O=24, k=12, store=store, early_term=et)
    al = GenASMAligner(cfg)
    res = al.align(readset.reads, readset.ref_segments)
    assert not res.failed.any()
    for i in range(len(readset.reads)):
        validate_cigar(readset.reads[i], readset.ref_segments[i],
                       res.ops[i], expected_dist=res.dist[i])


def test_improved_equals_unimproved_distances(readset):
    """The paper's improvements change memory traffic, not results."""
    d = {}
    for store in ("band", "edges4"):
        cfg = AlignerConfig(W=64, O=24, k=12, store=store,
                            early_term=(store == "band"))
        res = GenASMAligner(cfg).align(readset.reads, readset.ref_segments)
        d[store] = list(res.dist)
    assert d["band"] == d["edges4"]


def test_windowed_distance_near_optimal(readset):
    """Windowed alignment is a heuristic >= true edit distance; with W=64
    O=24 on 8% error reads it should be within a few percent."""
    cfg = AlignerConfig(W=64, O=24, k=12)
    res = GenASMAligner(cfg).align(readset.reads, readset.ref_segments)
    for i in range(3):
        ed = levenshtein(readset.reads[i], readset.ref_segments[i])
        assert res.dist[i] >= ed
        assert res.dist[i] <= ed * 1.08 + 3


def test_rescue_on_high_error_pair(rng):
    """A pair exceeding k in some window gets rescued with doubled k."""
    g = synth_genome(20_000, seed=21)
    rs = simulate_reads(g, 3, ReadSimConfig(read_len=300, error_rate=0.30,
                                            seed=22))
    al = GenASMAligner(AlignerConfig(W=64, O=24, k=8), rescue_rounds=2)
    res = al.align(rs.reads, rs.ref_segments)
    assert (res.k_used[~res.failed] >= 8).all()
    for i in range(len(rs.reads)):
        if not res.failed[i]:
            validate_cigar(rs.reads[i], rs.ref_segments[i], res.ops[i],
                           expected_dist=res.dist[i])
    assert res.failed.sum() <= 1  # most should rescue at k=16/32


def test_decoy_pairs_fail(rng):
    g = synth_genome(50_000, seed=31)
    rs = simulate_reads(g, 2, ReadSimConfig(read_len=300, error_rate=0.05,
                                            seed=32))
    decoys = [g[40_000:40_000 + len(s)] for s in rs.ref_segments]
    al = GenASMAligner(AlignerConfig(W=64, O=24, k=12), rescue_rounds=0)
    res = al.align(rs.reads, decoys)
    assert res.failed.all()
