"""Long-read windowed alignment: validity, accuracy vs full DP, variants.

The simulated read set and the per-variant alignment results are session-
scoped fixtures (tests/conftest.py): each aligner config is jitted and run
once, shared by every test below."""
import numpy as np
import pytest

from repro.core.aligner import GenASMAligner
from repro.core.config import AlignerConfig
from repro.core.oracle import levenshtein, validate_cigar
from repro.data.genome import ReadSimConfig, simulate_reads, synth_genome

CFG_BAND = AlignerConfig(W=64, O=24, k=12, store="band", early_term=True)
CFG_EDGES = AlignerConfig(W=64, O=24, k=12, store="edges4", early_term=False)
CFG_AND = AlignerConfig(W=64, O=24, k=12, store="and", early_term=True)


@pytest.mark.parametrize("cfg", [
    pytest.param(CFG_BAND, id="band"),
    # edges4/and ride nightly: tier-1 covers the store-mode equivalence at
    # window scale via test_kernel_fused/test_genasm_tb (W=32), and the
    # W=64 edges4 fill is the slowest single compile in the suite
    pytest.param(CFG_EDGES, id="edges4", marks=pytest.mark.slow),
    pytest.param(CFG_AND, id="and", marks=pytest.mark.slow),
])
def test_windowed_alignment_valid_all_variants(readset, aligned, cfg):
    res = aligned(cfg)
    assert not res.failed.any()
    for i in range(len(readset.reads)):
        validate_cigar(readset.reads[i], readset.ref_segments[i],
                       res.ops[i], expected_dist=res.dist[i])


@pytest.mark.slow
def test_improved_equals_unimproved_distances(aligned):
    """The paper's improvements change memory traffic, not results.
    (@slow with the edges4 variant above — it triggers the same compile.)"""
    assert list(aligned(CFG_BAND).dist) == list(aligned(CFG_EDGES).dist)


def test_windowed_distance_near_optimal(readset, aligned):
    """Windowed alignment is a heuristic >= true edit distance; with W=64
    O=24 on 8% error reads it should be within a few percent."""
    res = aligned(CFG_BAND)
    for i in range(3):
        ed = levenshtein(readset.reads[i], readset.ref_segments[i])
        assert res.dist[i] >= ed
        assert res.dist[i] <= ed * 1.08 + 3


@pytest.mark.slow
def test_rescue_on_high_error_pair(rng):
    """A pair exceeding k in some window gets rescued with doubled k.
    (@slow: a W=64 ladder compile; tier-1 rescue semantics live in
    tests/test_rescue.py at W=16.)"""
    g = synth_genome(20_000, seed=21)
    rs = simulate_reads(g, 2, ReadSimConfig(read_len=200, error_rate=0.20,
                                            seed=22))
    al = GenASMAligner(AlignerConfig(W=64, O=24, k=8), rescue_rounds=1)
    res = al.align(rs.reads, rs.ref_segments)
    assert (res.k_used[~res.failed] >= 8).all()
    for i in range(len(rs.reads)):
        if not res.failed[i]:
            validate_cigar(rs.reads[i], rs.ref_segments[i], res.ops[i],
                           expected_dist=res.dist[i])
    assert res.failed.sum() <= 1  # most should rescue at k=16


def test_decoy_pairs_fail(readset):
    """The reads against unrelated reference segments must fail (same
    window geometry as the shared readset -> reuses its compile)."""
    g = synth_genome(50_000, seed=31)
    reads = readset.reads[:2]
    decoys = [g[40_000:40_000 + len(s)] for s in readset.ref_segments[:2]]
    al = GenASMAligner(CFG_BAND, rescue_rounds=0)
    res = al.align(reads, decoys)
    assert res.failed.all()
