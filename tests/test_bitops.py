"""Property tests for the multi-word bitvector primitives."""
import jax.numpy as jnp
import numpy as np
from tests._hyp import given, settings, st

from repro.core.bitops import (WORD_BITS, build_pm, extract_window, get_bit,
                               n_words, ones_below, shift1, window_bit)


def to_int(words):
    """(NW,) uint32 LSW-first -> python int."""
    return sum(int(w) << (32 * i) for i, w in enumerate(np.asarray(words)))


@given(st.integers(1, 4), st.lists(st.integers(0, 2**32 - 1), min_size=1,
                                   max_size=4), st.integers(0, 1))
@settings(max_examples=60, deadline=None)
def test_shift1_matches_python_int(nw, words, carry):
    words = (words + [0] * nw)[:nw]
    v = jnp.array(words, jnp.uint32)
    got = to_int(shift1(v, carry))
    want = ((to_int(words) << 1) | carry) & ((1 << (32 * nw)) - 1)
    assert got == want


@given(st.integers(1, 3), st.integers(0, 95))
@settings(max_examples=20, deadline=None)
def test_ones_below_and_get_bit(nw, d):
    d = d % (nw * 32 + 1)
    v = ones_below(jnp.int32(d), nw)
    x = to_int(v)
    for i in range(nw * 32):
        bit = (x >> i) & 1
        assert bit == (0 if i < d else 1)
        assert int(get_bit(v, jnp.int32(i))) == bit


@given(st.lists(st.integers(0, 3), min_size=1, max_size=80))
@settings(max_examples=20, deadline=None)
def test_build_pm_semantics(pat):
    nw = n_words(len(pat))
    pm = build_pm(jnp.array([pat], jnp.int32), nw)  # (1, 4, NW)
    for c in range(4):
        x = to_int(pm[0, c])
        for i in range(nw * 32):
            want = 0 if (i < len(pat) and pat[i] == c) else 1
            assert (x >> i) & 1 == want


@given(st.integers(2, 4), st.data())
@settings(max_examples=40, deadline=None)
def test_extract_window_roundtrip(nw, data):
    words = data.draw(st.lists(st.integers(0, 2**32 - 1), min_size=nw,
                               max_size=nw))
    nwb = data.draw(st.integers(1, nw - 1))
    base = data.draw(st.integers(0, 32 * (nw - nwb)))
    v = jnp.array(words, jnp.uint32)
    win = extract_window(v, jnp.int32(base), nwb)
    x = to_int(words)
    want = (x >> base) & ((1 << (32 * nwb)) - 1)
    assert to_int(win) == want
    # window_bit reads absolute indices
    for off in (0, 5, 32 * nwb - 1):
        assert int(window_bit(win, base, base + off)) == (want >> off) & 1
