"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment
deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import ARCH_IDS, get_config, get_model, tiny_config
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_state, make_train_step


def make_batch(cfg, B=2, S=32, rng=None):
    key = jax.random.PRNGKey(3)
    if cfg.family == "audio":
        b = {"embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                         jnp.bfloat16),
             "labels": jax.random.randint(key, (B, S, cfg.n_codebooks), 0,
                                          cfg.vocab)}
    else:
        b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        b["positions"] = jnp.stack([pos] * 3)
    return b


# the model-zoo sweep runs nightly; tier-1 model coverage comes from the
# (cheaper) semantics tests
FAST_ARCHS = set()


@pytest.mark.parametrize("arch", [
    pytest.param(a, marks=() if a in FAST_ARCHS else (pytest.mark.slow,))
    for a in ARCH_IDS])
def test_forward_and_train_step(arch):
    cfg = tiny_config(get_config(arch))
    model = get_model(cfg)
    batch = make_batch(cfg)
    state = init_state(model, jax.random.PRNGKey(0))

    # forward: shapes + finiteness
    logits, aux, _ = model.forward(state["params"], batch, mode="train")
    B, S = 2, 32
    if cfg.n_codebooks:
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab_padded)
    else:
        assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    # one train step: loss finite and params move
    step = make_train_step(model, AdamWConfig(lr=1e-3, total_steps=10,
                                              warmup_steps=1))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), state["params"],
        new_state["params"])
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma2-2b", "zamba2-2.7b",
                                  "xlstm-125m", "musicgen-medium"])
def test_prefill_decode_shapes(arch):
    cfg = tiny_config(get_config(arch))
    model = get_model(cfg)
    batch = make_batch(cfg)
    batch.pop("labels")
    state = init_state(model, jax.random.PRNGKey(0))
    logits, cache = model.prefill(state["params"], batch)
    assert logits.shape[1] == 1
    dec_cache = model.init_cache(2, 40)
    db = {"cache_pos": jnp.int32(32)}
    if cfg.family == "audio":
        db["embeds"] = batch["embeds"][:, :1]
    else:
        db["tokens"] = batch["tokens"][:, :1]
    if cfg.family == "vlm":
        db["positions"] = batch["positions"][:, :, :1]
    lg, new_cache = model.decode_step(state["params"], db, dec_cache)
    assert lg.shape[1] == 1
    assert bool(jnp.isfinite(lg.astype(jnp.float32)).all())


def test_two_full_configs_match_assignment_numbers():
    c = get_config("qwen3-moe-235b-a22b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.n_experts, c.top_k) == \
        (94, 4096, 64, 4, 1536, 151936, 128, 8)
    c = get_config("gemma2-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (26, 2304, 8, 4, 9216, 256000)
    assert c.sliding_window == 4096 and c.attn_softcap == 50.0
