"""Scratch accounting: the footprint numbers are real, not estimates.

Three layers must agree word for word, per fused kernel, per (W, k, tile)
grid point:

  1. the ``pltpu.VMEM`` scratch shapes the kernels actually declare
     (kernels.genasm_dc.fused_scratch_shapes / tail_scratch_shapes),
  2. the ``vmem_bytes`` / ``vmem_bytes_tail`` numbers the benchmarks and
     the bucket planner consume,
  3. the analytic counting model (core.counting.kernel_scratch_words /
     tail_scratch_words) the paper-claim report is computed from.

Plus the dispatch policy around them: ``tail_store='auto'`` picks the
Scrooge-style banded store exactly when it is a strict win (nwb < nw),
forcing works both ways, and the planner's ``lane_tile='auto'`` ceilings
follow the bytes.  Pure shape math — no Pallas compiles, tier-1 fast.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.config import AlignerConfig, resolve_config
from repro.core.counting import (gpu_lane_state_words, gpu_store_words,
                                 gpu_tail_store_words, kernel_scratch_words,
                                 reduction_report, tail_scratch_words)
from repro.core.windowing import (GPU_LANE_CEILING, GPU_LANE_QUANTUM,
                                  plan_lane_tile)
from repro.kernels.genasm_dc import (fused_scratch_shapes,
                                     gpu_fused_store_shapes,
                                     gpu_tail_store_shapes,
                                     tail_scratch_shapes, vmem_bytes,
                                     vmem_bytes_tail)

# (W, k) grid: headline geometry, a wide-k square, a band-not-a-win
# boundary case (nwb == nw at W=16/k=4 and W=32/k=15), and a multi-word one
GRID = [(64, 12), (64, 16), (32, 15), (32, 7), (16, 4), (128, 15)]
TILES = [8, 256]


def _cfg(W, k, **kw):
    return AlignerConfig(W=W, O=max(1, W // 3), k=k, **kw)


def _declared_bytes(specs) -> int:
    return sum(int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize
               for s in specs)


@pytest.mark.parametrize("W,k", GRID)
@pytest.mark.parametrize("tile", TILES)
def test_square_fused_declared_equals_reported_equals_model(W, k, tile):
    """After the store elimination the square kernels' only materialised
    table is the DENT band: declared VMEM == vmem_bytes == counting."""
    cfg = _cfg(W, k)
    declared = _declared_bytes(fused_scratch_shapes(cfg, tile))
    assert declared == vmem_bytes(cfg, tile)
    assert declared == 4 * kernel_scratch_words(cfg, tile)


@pytest.mark.parametrize("W,k", GRID)
@pytest.mark.parametrize("tile", TILES)
@pytest.mark.parametrize("store", ["auto", "band", "full"])
def test_tail_declared_equals_reported_equals_model(W, k, tile, store):
    """Same tri-equality for the rectangular-tail kernel in every store
    mode, including the no-band-proof fallback boundary (auto == full
    when nwb == nw)."""
    cfg = _cfg(W, k, tail_store=store)
    n_text = cfg.W + 4 * cfg.k
    declared = _declared_bytes(tail_scratch_shapes(cfg, tile, n_text))
    assert declared == vmem_bytes_tail(cfg, tile, n_text)
    assert declared == 4 * tail_scratch_words(cfg, tile, n_text)
    # the shapes follow the mode: banded keeps nwb band words per column
    # with column 0 analytic, full keeps the whole (n_text+1, nw) table
    (spec,) = tail_scratch_shapes(cfg, tile, n_text)
    if cfg.tail_banded:
        assert spec.shape == (cfg.k + 1, n_text, cfg.nwb, tile)
    else:
        assert spec.shape == (cfg.k + 1, n_text + 1, cfg.nw, tile)


@pytest.mark.parametrize("W,k", GRID)
def test_auto_mode_bands_exactly_when_strict_win(W, k):
    """'auto' == 'band' iff nwb < nw; at the boundary (nwb == nw) the band
    would not shrink the store, so auto falls back to the full table —
    and forcing either mode is always honoured."""
    auto, band, full = (_cfg(W, k, tail_store=s)
                        for s in ("auto", "band", "full"))
    assert band.tail_banded and not full.tail_banded
    assert auto.tail_banded == auto.tail_band_supported == (auto.nwb < auto.nw)
    n_text = auto.W + 4 * auto.k
    if auto.tail_band_supported:
        assert vmem_bytes_tail(band, 8, n_text) < vmem_bytes_tail(full, 8,
                                                                  n_text)
        assert vmem_bytes_tail(auto, 8, n_text) == vmem_bytes_tail(band, 8,
                                                                   n_text)
    else:
        assert vmem_bytes_tail(auto, 8, n_text) == vmem_bytes_tail(full, 8,
                                                                   n_text)


@pytest.mark.parametrize("W,k", GRID)
@pytest.mark.parametrize("tile", TILES)
def test_gpu_declared_equals_model_and_tpu_band(W, k, tile):
    """The Triton path's per-backend scratch model: the band the GPU
    wrappers declare as a GMEM output block (gpu_*_store_shapes) equals
    the core.counting gpu_* model word for word — and equals the TPU
    path's VMEM scratch, because the store IS the same band; only the
    memory space differs (jax's Triton lowering has no scratch memory)."""
    cfg = _cfg(W, k, backend="pallas_gpu")
    declared = _declared_bytes(gpu_fused_store_shapes(cfg, tile))
    assert declared == 4 * gpu_store_words(cfg, tile)
    assert declared == _declared_bytes(fused_scratch_shapes(cfg, tile))
    n_text = cfg.W + 4 * cfg.k
    for store in ("auto", "band", "full"):
        cfg_s = _cfg(W, k, backend="pallas_gpu", tail_store=store)
        d = _declared_bytes(gpu_tail_store_shapes(cfg_s, tile, n_text))
        assert d == 4 * gpu_tail_store_words(cfg_s, tile, n_text)
        assert d == _declared_bytes(tail_scratch_shapes(cfg_s, tile, n_text))


def test_gpu_planner_uses_register_model():
    """backend='pallas_gpu' switches plan_lane_tile to the register-budget
    model: warp quantum, CTA ceiling, and the live-column word count per
    lane (two live columns x (k+1) levels x nw words) as the denominator —
    NOT the 16 MiB VMEM scratch budget (the GPU band store is GMEM)."""
    for W, k in GRID:
        cfg = _cfg(W, k, backend="pallas_gpu")
        tile = plan_lane_tile(cfg)
        assert tile % GPU_LANE_QUANTUM == 0
        assert GPU_LANE_QUANTUM <= tile <= GPU_LANE_CEILING
        per_lane = gpu_lane_state_words(cfg)
        assert per_lane == 2 * (cfg.k + 1) * cfg.nw
        budget = 64 * 1024
        if tile < GPU_LANE_CEILING:
            assert per_lane * tile <= budget
            assert per_lane * (tile + GPU_LANE_QUANTUM) > budget \
                or tile == GPU_LANE_QUANTUM
    # headline geometry: 52 words/lane -> capped at the CTA ceiling
    assert plan_lane_tile(_cfg(64, 12, backend="pallas_gpu")) == 1024
    # the refusal contract carries over, naming the geometry
    with pytest.raises(ValueError, match=r"W=64 k=12"):
        plan_lane_tile(_cfg(64, 12, backend="pallas_gpu"),
                       reg_budget_words=10)
    # and 'auto' resolves through the same per-backend model
    c = resolve_config(None, W=64, O=24, k=12, backend="pallas_gpu",
                       lane_tile="auto")
    assert c.lane_tile == 1024


def test_headline_reduction_is_at_least_2x():
    """The PR claim at the headline geometry (W=64, O=24, k=12, tile=256):
    banded tail scratch is >= 2x smaller than the full store."""
    cfg = AlignerConfig(W=64, O=24, k=12)
    full = dataclasses.replace(cfg, tail_store="full")
    assert cfg.tail_banded                      # auto picks the band here
    b, f = vmem_bytes_tail(cfg, 256), vmem_bytes_tail(full, 256)
    assert b == 1_490_944 and f == 3_008_512    # the committed bench rows
    assert f / b >= 2.0


def test_reduction_report_reconciles_with_kernel_scratch():
    """Satellite claim: counting's vmem_bytes_per_problem IS the fused
    kernel's declared per-problem band scratch — one source of truth, not
    two estimates (any avg_levels: footprint is allocation, not fill)."""
    for W, k in GRID:
        cfg = _cfg(W, k)
        rep = reduction_report(cfg, avg_levels=1.7)
        per_problem = rep["vmem_bytes_per_problem"]
        assert per_problem == 4 * kernel_scratch_words(cfg, 1)
        for tile in TILES:
            assert per_problem * tile == vmem_bytes(cfg, tile)


def test_planner_tile_follows_the_bytes():
    """plan_lane_tile spends exactly the reclaimed scratch: quantised,
    clamped to [quantum, ceiling], and the planned tile's worst-kernel
    footprint fits the budget while one more quantum would not (unless
    clamped)."""
    budget = 16 * 2**20
    for W, k in GRID:
        for store in ("auto", "full"):
            cfg = _cfg(W, k, tail_store=store)
            tile = plan_lane_tile(cfg, budget, quantum=128, ceiling=4096)
            assert tile % 128 == 0 and 128 <= tile <= 4096
            worst = max(vmem_bytes(cfg, tile),
                        vmem_bytes_tail(cfg, tile))
            if tile < 4096:
                assert worst <= budget
                bigger = max(vmem_bytes(cfg, tile + 128),
                             vmem_bytes_tail(cfg, tile + 128))
                assert bigger > budget or tile == 128
    # the headline geometry: the banded tail buys exactly a 2x wider tile
    banded = plan_lane_tile(AlignerConfig(W=64, O=24, k=12))
    full = plan_lane_tile(AlignerConfig(W=64, O=24, k=12, tail_store="full"))
    assert (banded, full) == (2816, 1408)


def test_planner_refuses_vmem_over_commit():
    """Regression: a budget too small for even ONE quantum of lanes used to
    fall back to `max(tile, quantum)` — handing the kernel a full quantum
    of scratch the budget never covered.  It must refuse, naming the
    geometry and the bytes, and stay exact at the one-quantum boundary."""
    from repro.core.counting import kernel_scratch_words, tail_scratch_words
    cfg = _cfg(64, 12)
    per_quantum = 128 * 4 * max(kernel_scratch_words(cfg, 1),
                                tail_scratch_words(cfg, 1))
    with pytest.raises(ValueError, match=r"W=64 k=12"):
        plan_lane_tile(cfg, per_quantum - 1, quantum=128)
    with pytest.raises(ValueError):
        plan_lane_tile(cfg, 1, quantum=128)
    # exactly one quantum of budget plans exactly one quantum of lanes
    assert plan_lane_tile(cfg, per_quantum, quantum=128) == 128


def test_lane_tile_auto_resolves_through_the_planner():
    """resolve_config/plan accept lane_tile='auto' and bake in the planned
    ceiling against the final geometry (tail_store included)."""
    c = resolve_config(None, W=64, O=24, k=12, lane_tile="auto")
    assert c.lane_tile == plan_lane_tile(c) == 2816
    c2 = resolve_config(None, W=64, O=24, k=12, lane_tile="auto",
                        tail_store="full")
    assert c2.lane_tile == 1408
    # explicit tiles pass through untouched
    assert resolve_config(None, W=64, O=24, k=12, lane_tile=64).lane_tile == 64


def test_fingerprint_covers_tail_store():
    """tail_store shapes an executable (it picks the kernel body), so it
    must key the compile cache: different store modes, different specs."""
    a = _cfg(64, 12, tail_store="auto")
    b = _cfg(64, 12, tail_store="full")
    assert a.fingerprint() != b.fingerprint()
