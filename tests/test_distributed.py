"""Distributed behaviour on virtual device meshes.  Needs
XLA_FLAGS=--xla_force_host_platform_device_count BEFORE jax import, which
must not leak into the other (single-device) tests -> subprocesses."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, n_dev: int = 8, timeout=480):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=REPO)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


@pytest.mark.slow
def test_sharded_train_step_runs_and_matches_single_device():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models.registry import get_config, get_model, tiny_config
        from repro.optim.adamw import AdamWConfig
        from repro.train.step import (abstract_state, init_state,
                                      make_train_step, state_partition_specs)
        from repro.launch.dryrun import tree_shardings, batch_pspec
        from repro.launch.mesh import make_test_mesh, use_mesh
        from repro.data.tokens import TokenStream

        cfg = tiny_config(get_config('llama3.2-1b'))
        model = get_model(cfg)
        step = make_train_step(model, AdamWConfig(lr=1e-3, total_steps=10,
                                                  warmup_steps=1))
        state = init_state(model, jax.random.PRNGKey(0))
        batch = TokenStream(cfg.vocab, 8, 32, seed=1).batch_at(0)

        # single-device result
        _, m1 = jax.jit(step)(state, batch)

        mesh = make_test_mesh((4, 2), ('data', 'model'))
        st_sh = tree_shardings(abstract_state(model),
                               state_partition_specs(model), mesh)
        b_sh = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, batch_pspec(
                jax.ShapeDtypeStruct(s.shape, s.dtype), mesh)), batch)
        with use_mesh(mesh):
            stp = jax.jit(step, in_shardings=(st_sh, b_sh),
                          out_shardings=(st_sh, None))
            state_d = jax.device_put(state, st_sh)
            batch_d = jax.device_put(batch, b_sh)
            _, m2 = stp(state_d, batch_d)
        l1, l2 = float(m1['loss']), float(m2['loss'])
        assert abs(l1 - l2) / l1 < 2e-2, (l1, l2)
        print('OK', l1, l2)
    """)
    assert "OK" in out


def test_compressed_allreduce_accuracy():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import compressed_allreduce
        from repro.launch.mesh import make_test_mesh, shard_map
        mesh = make_test_mesh((8,), ('data',))
        x = np.random.default_rng(0).standard_normal((8, 4097)).astype('f4')
        f = jax.jit(shard_map(
            lambda xs: compressed_allreduce(xs[0], 'data')[None],
            mesh=mesh, in_specs=P('data', None), out_specs=P('data', None),
            check=False))
        out = np.asarray(f(x))
        want = x.sum(0)
        err = np.abs(out - want[None]).max() / np.abs(want).max()
        assert err < 0.05, err
        print('OK', err)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_elastic_reshard_across_mesh_sizes():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.registry import get_config, get_model, tiny_config
        from repro.train.step import init_state, state_partition_specs, abstract_state
        from repro.launch.dryrun import tree_shardings
        from repro.launch.mesh import make_test_mesh
        from repro.checkpoint.ckpt import save_checkpoint, restore_checkpoint
        import tempfile, pathlib

        cfg = tiny_config(get_config('llama3.2-1b'))
        model = get_model(cfg)
        state = init_state(model, jax.random.PRNGKey(0))
        d = pathlib.Path(tempfile.mkdtemp())
        mesh_a = make_test_mesh((2, 4), ('data', 'model'))
        sh_a = tree_shardings(abstract_state(model),
                              state_partition_specs(model), mesh_a)
        state_a = jax.device_put(state, sh_a)
        save_checkpoint(d, state_a, 5)

        # 'scale down': restore the same checkpoint under a 2x2 mesh
        mesh_b = make_test_mesh((2, 2), ('data', 'model'))
        sh_b = tree_shardings(abstract_state(model),
                              state_partition_specs(model), mesh_b)
        state_b, step = restore_checkpoint(d, abstract_state(model),
                                           shardings=sh_b)
        assert step == 5
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(state_b)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print('OK elastic')
    """)
    assert "OK elastic" in out


@pytest.mark.slow
def test_aligner_shards_over_mesh():
    """(@slow: superseded in tier-1 by tests/test_multidevice.py, which
    asserts bit-identical sharded-vs-single results rather than just a
    successful sharded run.)"""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.config import AlignerConfig
        from repro.serve.align_step import make_align_step
        from repro.launch.mesh import make_test_mesh, use_mesh
        from repro.data.genome import ReadSimConfig, simulate_reads, synth_genome
        from repro.core.windowing import self_tail_width

        g = synth_genome(30000, seed=2)
        rs = simulate_reads(g, 8, ReadSimConfig(read_len=200, error_rate=0.06,
                                                seed=3))
        cfg = AlignerConfig(W=64, O=24, k=12)
        mesh = make_test_mesh((8,), ('data',))
        stepf = make_align_step(cfg, 200, mesh)
        wt = self_tail_width(cfg)
        B = 8
        reads = np.full((B, 200 + cfg.W + 1), 255, np.uint8)
        refs = np.full((B, 300 + cfg.W + wt + 1), 9, np.uint8)
        rl = np.zeros(B, np.int32); fl = np.zeros(B, np.int32)
        for i in range(B):
            reads[i, :len(rs.reads[i])] = rs.reads[i]; rl[i] = len(rs.reads[i])
            refs[i, :len(rs.ref_segments[i])] = rs.ref_segments[i]
            fl[i] = len(rs.ref_segments[i])
        from jax.sharding import NamedSharding, PartitionSpec as P
        bsh = NamedSharding(mesh, P(('data',), None))
        vsh = NamedSharding(mesh, P(('data',)))
        args = (jax.device_put(jnp.array(reads), bsh),
                jax.device_put(jnp.array(rl), vsh),
                jax.device_put(jnp.array(refs), bsh),
                jax.device_put(jnp.array(fl), vsh))
        with use_mesh(mesh):
            out, summary = stepf(*args)
        assert int(summary['n_failed']) == 0
        print('OK aligned', int(summary['total_edits']))
    """)
    assert "OK aligned" in out


@pytest.mark.slow
def test_rescued_aligner_shards_over_mesh():
    """make_align_step_rescued: the on-device k-doubling ladder inside one
    sharded jitted step — high-error pairs rescue without any host
    round-trip on any shard."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.config import AlignerConfig
        from repro.serve.align_step import make_align_step_rescued
        from repro.launch.mesh import make_test_mesh, use_mesh
        from repro.data.genome import ReadSimConfig, simulate_reads, synth_genome
        from repro.core.windowing import rescue_schedule, self_tail_width

        g = synth_genome(30000, seed=2)
        rs = simulate_reads(g, 8, ReadSimConfig(read_len=80, error_rate=0.18,
                                                seed=3))
        cfg = AlignerConfig(W=32, O=12, k=4)
        rounds = 1
        mesh = make_test_mesh((8,), ('data',))
        stepf = make_align_step_rescued(cfg, 80, mesh, rescue_rounds=rounds)
        wt = self_tail_width(rescue_schedule(cfg, rounds)[-1])
        B = 8
        reads = np.full((B, 80 + cfg.W + 1), 255, np.uint8)
        refs = np.full((B, 120 + cfg.W + wt + 1), 9, np.uint8)
        rl = np.zeros(B, np.int32); fl = np.zeros(B, np.int32)
        for i in range(B):
            reads[i, :len(rs.reads[i])] = rs.reads[i]; rl[i] = len(rs.reads[i])
            refs[i, :len(rs.ref_segments[i])] = rs.ref_segments[i]
            fl[i] = len(rs.ref_segments[i])
        from jax.sharding import NamedSharding, PartitionSpec as P
        bsh = NamedSharding(mesh, P(('data',), None))
        vsh = NamedSharding(mesh, P(('data',)))
        args = (jax.device_put(jnp.array(reads), bsh),
                jax.device_put(jnp.array(rl), vsh),
                jax.device_put(jnp.array(refs), bsh),
                jax.device_put(jnp.array(fl), vsh))
        with use_mesh(mesh):
            out, summary = stepf(*args)
        ku = np.asarray(out['k_used'])
        failed = np.asarray(out['failed'])
        assert int(summary['n_rescued']) == int(((ku > cfg.k) & ~failed).sum())
        assert int(summary['rounds_run']) >= 1
        print('OK rescued', int(summary['n_rescued']), int(summary['n_failed']))
    """)
    assert "OK rescued" in out
