"""The multi-tenant gateway (repro.api.gateway): deadlines, priority
lanes, cancellation, load shedding — proven the way schedulers must be:

  * DETERMINISTICALLY — every scheduling decision is a pure function of
    (queues, now): a fake clock plus scripted arrival traces pin exact
    shed/expire/preempt decisions, with zero time.sleep anywhere in this
    file (the only waiting is on real completion events);
  * UNDER REAL THREADS — ≥8 concurrent clients hammer one gateway over
    the rescue-exercising differential corpus and every per-request
    record must be bit-identical to a serial AlignSession run (per-lane
    results are batch-composition independent — PR-3 invariance — so the
    scheduler may reorder work in time, never in value), including a
    close()-while-submitting race.

The session-level primitives the gateway builds on (result(timeout=),
cancel() atomicity vs dispatch, thread-safe submit) are covered in
tests/test_executor.py.
"""
import threading

import numpy as np
import pytest

from repro.api import (DeadlineExceeded, Gateway, GatewayClosedError,
                       GatewayPolicy, RequestCancelled, ShedError, plan)
from repro.core.aligner import AlignResult
from tests.test_differential import CFG as DCFG, ROUNDS


class FakeClock:
    """Injectable time source: advances only when told to."""

    def __init__(self, t=0.0):
        self.t = float(t)

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _pair(rng, n, exact=True):
    ref = rng.integers(0, 4, n).astype(np.uint8)
    read = ref.copy()
    if not exact:
        read[::9] = (read[::9] + 1) % 4
    return read, ref


@pytest.fixture
def gw():
    """A sync-executor session + manual-pump gateway on a fake clock —
    the deterministic harness every scheduling test drives."""
    clk = FakeClock()
    s = plan(DCFG, rescue_rounds=0, batch_lanes=4, clock=clk)
    g = Gateway(s, GatewayPolicy(capacity=64, linger_s=0.05), clock=clk,
                auto_pump=False)
    yield g, clk
    g.close()
    s.close()


# --------------------------------------------------------------------------
# deterministic scheduling: priorities, deadlines, linger, margin
# --------------------------------------------------------------------------

def test_priority_zero_full_bucket_preempts_older_bulk(gw, rng):
    """A full latency-lane (priority 0) bucket dispatches BEFORE an older
    but partial bulk (priority 1) bucket; the bulk batch follows only
    once its linger age makes it urgent.  Exact dispatch_log assertion."""
    g, clk = gw
    bulk = g.tenant("bulk", priority=1)
    lat = g.tenant("lat", priority=0)
    bf = [bulk.submit(*_pair(rng, 200)) for _ in range(2)]   # older, partial
    clk.advance(0.01)
    lf = [lat.submit(*_pair(rng, 24)) for _ in range(4)]     # full class
    assert g.pump(clk()) == 1                 # ONLY the full latency bucket
    assert list(g.dispatch_log) == [(0, (32, 32), 4)]
    clk.advance(0.05)                         # bulk's linger age reached
    assert g.pump(clk()) == 1
    assert list(g.dispatch_log)[1] == (1, (256, 256), 2)
    assert all(f.result(timeout=30)["ok"] for f in lf + bf)
    assert [f.deadline_met for f in lf] == [True] * 4        # no deadline


def test_equal_priority_dispatches_oldest_arrival_first(gw, rng):
    """Within one priority, bucket batches go out in oldest-head order
    (no bucket starvation by a busier sibling)."""
    g, clk = gw
    t = g.tenant("t", priority=1)
    a = [t.submit(*_pair(rng, 24)) for _ in range(4)]        # full at t=0
    clk.advance(0.001)
    b = [t.submit(*_pair(rng, 100)) for _ in range(4)]       # full at t+
    assert g.pump(clk()) == 2
    assert list(g.dispatch_log) == [(1, (32, 32), 4), (1, (128, 128), 4)]
    for f in a + b:
        f.result(timeout=30)


def test_deadline_sweep_expires_exactly_the_due_requests(gw, rng):
    """The sweep fails QUEUED requests with now >= deadline — exactly
    those — freeing their slots; the survivor still dispatches."""
    g, clk = gw
    g.policy = GatewayPolicy(capacity=64, linger_s=10.0)   # expiry only
    t = g.tenant("t", priority=0)
    f_tight = t.submit(*_pair(rng, 24), deadline_s=0.10)
    f_loose = t.submit(*_pair(rng, 24), deadline_s=10.0)
    clk.advance(0.09)
    g.pump(clk())                             # 0.09 < 0.10: nothing expires
    assert not f_tight.done() and g.stats["dispatched"] == 0
    clk.advance(0.02)
    g.pump(clk())                             # now past deadline: expire
    with pytest.raises(DeadlineExceeded):
        f_tight.result()
    assert f_tight.cancelled() and f_tight.deadline_met is False
    assert g.stats["expired"] == 1
    assert f_loose.result(timeout=30)["ok"]   # result() force-dispatches
    assert f_loose.deadline_met is True
    assert g.stats["deadline_hits"] == 1 and g.stats["completed"] == 1


def test_expired_request_is_never_dispatched(gw, rng):
    """Expiry frees the queue slot BEFORE dispatch: the session never
    sees the request (no lane is wasted on a dead deadline)."""
    g, clk = gw
    t = g.tenant("t", priority=0)
    f = t.submit(*_pair(rng, 24), deadline_s=0.01)
    clk.advance(1.0)
    g.pump(clk())
    assert f.done() and g.stats["dispatched"] == 0
    assert g.session.stats["dispatches"] == 0


def test_service_margin_dispatches_partial_before_expiry(gw, rng):
    """With service_margin_s, a queued deadline within the margin makes
    its PARTIAL batch urgent now — the request completes instead of
    expiring at the next sweep."""
    g, clk = gw
    g.policy = GatewayPolicy(capacity=64, linger_s=10.0,
                             service_margin_s=0.05)
    t = g.tenant("t", priority=0)
    f = t.submit(*_pair(rng, 24), deadline_s=0.10)
    g.pump(clk())                             # t=0: 0.10 - 0.05 > 0 — wait
    assert not f.done() and g.stats["dispatched"] == 0
    clk.advance(0.06)                         # deadline within the margin
    assert g.pump(clk()) == 1
    assert g.stats["partial_dispatches"] == 1
    assert f.result(timeout=30)["ok"] and f.deadline_met is True


def test_deadline_scored_at_completion_for_dispatched_requests(gw, rng):
    """A request that dispatches in time but RETIRES late is completed
    (never expired) yet scored as a deadline miss — the SLO accounting
    the deadline-hit-rate benchmark row reports."""
    g, clk = gw
    t = g.tenant("t", priority=0)
    futs = [t.submit(*_pair(rng, 24), deadline_s=0.5) for _ in range(4)]
    assert g.pump(clk()) == 1                 # full bucket: dispatched at t=0
    clk.advance(1.0)                          # ...but retires past deadline
    recs = [f.result(timeout=30) for f in futs]
    assert all(r["ok"] for r in recs)
    assert [f.deadline_met for f in futs] == [False] * 4
    assert g.stats["expired"] == 0
    assert g.stats["deadline_misses"] == 4 and g.stats["deadline_hits"] == 0


# --------------------------------------------------------------------------
# load shedding: exact admission decisions
# --------------------------------------------------------------------------

def test_shed_thresholds_exact_per_priority(rng):
    """Admission sheds at exactly in_system >= capacity * shed_frac[p]:
    with capacity 8 and fracs (1.0, 0.5), priority 1 sheds at 4 pairs in
    the system while priority 0 admits through 7 and sheds at 8.
    Rejection is fast — a shed request is never queued."""
    clk = FakeClock()
    s = plan(DCFG, rescue_rounds=0, batch_lanes=4, clock=clk)
    g = Gateway(s, GatewayPolicy(capacity=8, shed_frac=(1.0, 0.5)),
                clock=clk, auto_pump=False)
    t0, t1 = g.tenant("a", priority=0), g.tenant("b", priority=1)
    for _ in range(3):
        t1.submit(*_pair(rng, 24))            # 0,1,2 in system: admitted
    t1.submit(*_pair(rng, 24))                # 3 < 4: the last p1 admit
    with pytest.raises(ShedError):
        t1.submit(*_pair(rng, 24))            # 4 >= 8*0.5: p1 sheds
    for _ in range(4):
        t0.submit(*_pair(rng, 24))            # 4..7 < 8: p0 still admits
    with pytest.raises(ShedError):
        t0.submit(*_pair(rng, 24))            # 8 >= 8: full — even p0
    assert g.stats["shed"] == 2 and g.stats["submitted"] == 8
    assert g.in_system() == 8                 # sheds never queued
    assert g.tenant_stats["b"]["shed"] == 1
    g.close()
    s.close()


def test_capacity_derives_from_session_inflight_signal(rng):
    """capacity=None wires admission to the session's occupancy signals:
    batch_lanes * (max_inflight + 1), moving with the adaptive bound."""
    s = plan(DCFG, rescue_rounds=0, batch_lanes=4, max_inflight=2)
    g = Gateway(s, GatewayPolicy(), auto_pump=False)
    assert g.capacity() == 4 * (2 + 1)
    s._max_inflight = 5                       # the adaptive controller widens
    assert g.capacity() == 4 * (5 + 1)        # ...and admission follows
    g.close()
    s.close()


def test_completion_returns_admission_headroom(gw, rng):
    """in_system() counts queued + dispatched-but-unfinished exactly:
    forcing completion returns the headroom and a shed-then-retry
    succeeds."""
    g, clk = gw
    g.policy = GatewayPolicy(capacity=4)
    t = g.tenant("t", priority=0)
    futs = [t.submit(*_pair(rng, 24)) for _ in range(4)]
    with pytest.raises(ShedError):
        t.submit(*_pair(rng, 24))
    g.pump(clk())                             # dispatch: still outstanding
    with pytest.raises(ShedError):
        t.submit(*_pair(rng, 24))             # dispatched != finished
    for f in futs:
        f.result(timeout=30)                  # retire -> headroom returns
    assert g.in_system() == 0
    assert t.submit(*_pair(rng, 24)).result(timeout=30)["ok"]


# --------------------------------------------------------------------------
# cancellation
# --------------------------------------------------------------------------

def test_cancel_queued_frees_admission_slot(gw, rng):
    """Cancelling a gateway-queued request frees its slot before any
    dispatch: admission headroom returns immediately and the cancelled
    future fails with RequestCancelled.  Idempotent."""
    g, clk = gw
    g.policy = GatewayPolicy(capacity=2)
    t = g.tenant("t", priority=0)
    f1 = t.submit(*_pair(rng, 24))
    f2 = t.submit(*_pair(rng, 24))
    with pytest.raises(ShedError):
        t.submit(*_pair(rng, 24))
    assert f1.cancel() is True and f1.cancel() is True
    with pytest.raises(RequestCancelled):
        f1.result()
    f3 = t.submit(*_pair(rng, 24))            # the freed slot admits again
    assert g.stats["cancelled"] == 1 and g.session.stats["dispatches"] == 0
    for f in (f2, f3):
        assert f.result(timeout=30)["ok"]


def test_cancel_after_dispatch_is_false_and_lane_completes(gw, rng):
    """Once the pump moved a request onto a lane, cancel() is False (the
    lane is committed exactly once — never freed twice) and the result
    arrives normally."""
    g, clk = gw
    t = g.tenant("t", priority=0)
    futs = [t.submit(*_pair(rng, 24)) for _ in range(4)]
    assert g.pump(clk()) == 1
    assert futs[0].cancel() is False
    assert not futs[0].cancelled()
    assert futs[0].result(timeout=30)["ok"]
    assert futs[0].cancel() is False          # done-and-uncancelled stays
    assert g.stats["cancelled"] == 0
    assert g.session.stats["dispatches"] == 1


def test_close_without_drain_fails_queued_fast(gw, rng):
    """close(drain=False) cancels everything still queued (fail-fast
    futures) and later submits refuse with GatewayClosedError."""
    g, clk = gw
    t = g.tenant("t", priority=1)
    f = t.submit(*_pair(rng, 24))
    g.close(drain=False)
    with pytest.raises(RequestCancelled):
        f.result()
    with pytest.raises(GatewayClosedError):
        t.submit(*_pair(rng, 24))


def test_stats_reconcile(gw, rng):
    """Every admitted request is accounted exactly once: submitted ==
    completed + expired + cancelled + failed when idle."""
    g, clk = gw
    t0, t1 = g.tenant("a", priority=0), g.tenant("b", priority=1)
    done = [t0.submit(*_pair(rng, 24)) for _ in range(4)]
    gone = t1.submit(*_pair(rng, 24), deadline_s=0.01)
    cut = t1.submit(*_pair(rng, 24))
    cut.cancel()
    clk.advance(1.0)
    g.pump(clk())
    for f in done:
        f.result(timeout=30)
    st = g.gateway_stats()
    assert st["submitted"] == 6
    assert (st["completed"] + st["expired"] + st["cancelled"]
            + st["failed"]) == 6
    assert st["queued"] == 0 and st["outstanding"] == 0
    assert gone.done() and cut.done()


# --------------------------------------------------------------------------
# real threads: the hammer + the close race
# --------------------------------------------------------------------------

def test_gateway_hammer_bit_identical_to_serial(corpus):
    """THE acceptance claim: 8 concurrent client threads × mixed priority
    lanes push the differential corpus (bucket rescue exercised) through
    ONE gateway on a threaded session with the background sweeper
    running — and every per-request record is bit-identical to a serial
    AlignSession run of the same pairs."""
    reads, refs, _ = corpus
    kw = dict(rescue_rounds=ROUNDS, rescue_mode="bucket", batch_lanes=8)
    base = plan(DCFG, **kw)
    serial = [base.submit(r, f_) for r, f_ in zip(reads, refs)]
    base.flush()
    want = AlignResult.from_records([f.result() for f in serial])
    base.close()

    s = plan(DCFG, executor="thread", **kw)
    g = Gateway(s, GatewayPolicy(capacity=len(reads) + 8, linger_s=0.001))
    g.start_sweeper(0.002)
    nthreads = 8
    shards = [list(range(i, len(reads), nthreads)) for i in range(nthreads)]
    got = [None] * nthreads
    errs = []

    def client(i):
        try:
            ten = g.tenant(f"t{i}", priority=i % 3, deadline_s=120.0)
            futs = [ten.submit(reads[j], refs[j]) for j in shards[i]]
            got[i] = [f.result(timeout=120) for f in futs]
        except BaseException as e:             # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(nthreads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs, errs
    recs = [None] * len(reads)
    for i, idxs in enumerate(shards):
        for rec, j in zip(got[i], idxs):
            recs[j] = rec
    gw_res = AlignResult.from_records(recs)
    np.testing.assert_array_equal(gw_res.failed, want.failed)
    np.testing.assert_array_equal(gw_res.dist, want.dist)
    np.testing.assert_array_equal(gw_res.k_used, want.k_used)
    assert gw_res.cigars == want.cigars
    st = g.gateway_stats()
    assert st["completed"] == len(reads)
    assert st["shed"] == 0 and st["expired"] == 0
    assert st["deadline_hits"] == len(reads)   # generous SLO: all hit
    g.close()
    s.close()


def test_gateway_close_while_submitting_race(rng):
    """close(drain=True) racing concurrent submitters: every admitted
    future resolves (drained or completed), refused submits see
    GatewayClosedError or ShedError, and nothing hangs or double-frees."""
    pairs = [_pair(np.random.default_rng(900 + i), 24) for i in range(16)]
    s = plan(DCFG, rescue_rounds=0, batch_lanes=4, executor="thread")
    g = Gateway(s, GatewayPolicy(capacity=64, linger_s=0.001))
    start = threading.Barrier(3)
    admitted, errs = [], []

    def submitter(lo):
        ten = g.tenant(f"t{lo}", priority=0)
        start.wait()
        for i in range(lo, lo + 8):
            try:
                admitted.append(ten.submit(*pairs[i]))
            except (GatewayClosedError, ShedError):
                return
            except BaseException as e:         # pragma: no cover
                errs.append(e)
                return

    t1 = threading.Thread(target=submitter, args=(0,))
    t2 = threading.Thread(target=submitter, args=(8,))
    t1.start(); t2.start()
    start.wait()                               # maximise the overlap
    g.close(drain=True)
    t1.join(); t2.join()
    assert not errs, errs
    for f in admitted:                         # admitted => resolved
        assert f.result(timeout=30)["dist"] == 0
    st = g.gateway_stats()
    assert st["completed"] == len(admitted)
    assert st["queued"] == 0 and st["outstanding"] == 0
    g.close()                                  # idempotent
    s.close()
