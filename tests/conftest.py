import numpy as np
import pytest


def mutate_seq(p, n_edits, rng, extend_to=None):
    """Apply n random edits to code array p; optionally pad/trim to a length."""
    t = list(p)
    for _ in range(n_edits):
        r = rng.random()
        pos = int(rng.integers(0, max(1, len(t))))
        if r < 0.4 and t:
            t[pos] = int(rng.integers(0, 4))
        elif r < 0.7:
            t.insert(pos, int(rng.integers(0, 4)))
        elif len(t) > 1:
            del t[pos]
    if extend_to is not None:
        t = (t + list(rng.integers(0, 4, extend_to)))[:extend_to]
    return np.array(t, dtype=np.uint8)


@pytest.fixture
def rng():
    return np.random.default_rng(1234)
