import sys

import numpy as np
import pytest


def pytest_addoption(parser):
    """The CI stress job's knobs (no pytest-repeat dependency): --count
    re-runs every collected test N times, --switch-interval shrinks the
    interpreter's thread switch interval so the executor/gateway thread
    suites are forced through many more interleavings per run."""
    parser.addoption("--count", type=int, default=1, metavar="N",
                     help="repeat each test N times (stress job)")
    parser.addoption("--switch-interval", type=float, default=None,
                     metavar="S",
                     help="sys.setswitchinterval(S) for the whole run "
                          "(e.g. 1e-5 to jitter thread interleavings; "
                          "the CPython default is 5e-3)")


def pytest_configure(config):
    si = config.getoption("--switch-interval")
    if si is not None:
        sys.setswitchinterval(si)


def pytest_generate_tests(metafunc):
    count = metafunc.config.getoption("--count")
    if count > 1:
        metafunc.fixturenames.append("_stress_rep")
        metafunc.parametrize("_stress_rep", range(count),
                             ids=[f"rep{i}" for i in range(count)])


def mutate_seq(p, n_edits, rng, extend_to=None):
    """Apply n random edits to code array p; optionally pad/trim to a length."""
    t = list(p)
    for _ in range(n_edits):
        r = rng.random()
        pos = int(rng.integers(0, max(1, len(t))))
        if r < 0.4 and t:
            t[pos] = int(rng.integers(0, 4))
        elif r < 0.7:
            t.insert(pos, int(rng.integers(0, 4)))
        elif len(t) > 1:
            del t[pos]
    if extend_to is not None:
        t = (t + list(rng.integers(0, 4, extend_to)))[:extend_to]
    return np.array(t, dtype=np.uint8)


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


@pytest.fixture(scope="session")
def readset():
    """One simulated read set shared by the windowed-alignment tests (all
    variants align the same pairs, so compiles and results are reusable)."""
    from repro.data.genome import ReadSimConfig, simulate_reads, synth_genome
    g = synth_genome(40_000, seed=7)
    return simulate_reads(g, 4, ReadSimConfig(read_len=300, error_rate=0.08,
                                              seed=13))


@pytest.fixture(scope="session")
def corpus():
    """The differential mixed-profile corpus (session-scoped so the CIGAR
    invariant tests and the differential suite share one corpus and one
    jit cache — see tests/test_differential.py for the profiles)."""
    from tests.test_differential import make_corpus
    return make_corpus(seed=20260727, n_per_profile=6)


@pytest.fixture(scope="session")
def diff_aligned(corpus):
    """Session cache: each (backend, rescue_mode) aligns the differential
    corpus once, shared by test_differential and test_cigar."""
    from repro.core.aligner import GenASMAligner
    from tests.test_differential import CFG, ROUNDS
    reads, refs, _ = corpus
    cache = {}

    def run(backend, rescue_mode="device"):
        key = (backend, rescue_mode)
        if key not in cache:
            cache[key] = GenASMAligner(
                CFG, rescue_rounds=ROUNDS, backend=backend,
                rescue_mode=rescue_mode).align(reads, refs)
        return cache[key]

    return run


@pytest.fixture(scope="session")
def aligned(readset):
    """Session cache of GenASMAligner results keyed by (frozen) config:
    each aligner variant is jitted and executed once per session, however
    many tests consume its output."""
    from repro.core.aligner import GenASMAligner
    cache = {}

    def run(cfg):
        # rescue_rounds=1: read 0 needs exactly one k-doubling (k_used=24);
        # the on-device rescue compiles every ladder round up front, so the
        # shortest sufficient ladder keeps tier-1 compile time down — deeper
        # ladders get dedicated tests in tests/test_rescue.py.
        if cfg not in cache:
            cache[cfg] = GenASMAligner(cfg, rescue_rounds=1).align(
                readset.reads, readset.ref_segments)
        return cache[cfg]

    return run
