"""repro.obs — the unified observability subsystem (registry, tracer,
exporters) and its contract with the serving stack:

* registry semantics: memoised named/labelled metrics, kind conflicts,
  fixed histogram edges, labelled views, snapshots;
* tracer semantics: per-thread nesting stacks, injectable clock
  (byte-stable timestamps under a FakeClock — zero time.sleep), error
  attribution, bounded records;
* exporter formats: Prometheus exposition text, JSON-lines, perfetto
  (Chrome trace-event) JSON;
* the DISABLED contract: ``obs='off'`` resolves to the null bundle whose
  metrics/spans are process-wide singletons — identity is asserted, and
  tracemalloc holds the whole submit->align->retire path to ZERO
  obs-module allocations;
* legacy accessor == registry equality for all four migrated counter
  families (core.transfer, CompileCache/_SessionCacheView,
  gateway_stats(), the mapper funnel) — the migration's bit-equality
  acceptance criterion;
* the EXACT span tree of a 2-bucket ragged batch with one rescue rung,
  on a fake clock;
* the done-callback regression: a raising callback (even a
  BaseException) must be swallowed-and-recorded, never poison the
  session (pre-PR code let it unwind into the retire path).
"""
import json
import os
import threading
import tracemalloc

import numpy as np
import pytest

import repro.obs
from repro.api import AlignSession, CompileCache, Gateway, GatewayPolicy, plan
from repro.core import transfer
from repro.core.config import AlignerConfig
from repro.obs import (DEFAULT_EDGES, MetricsRegistry, NULL_METRIC,
                       NULL_REGISTRY, NULL_SPAN, NULL_TRACER, OBS_OFF, Obs,
                       Tracer, default_registry, perfetto_trace,
                       prometheus_text, qualified_name, resolve_obs,
                       trace_jsonl, write_artifacts)

CFG = AlignerConfig(W=16, O=6, k=2)
#: one spec shared by every session test below, so the process cache
#: lowers each bucket once for the whole module
PLAN_KW = dict(rescue_rounds=1, rescue_mode="bucket", batch_lanes=4)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = float(t)

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _corpus():
    """3 exact pairs + 1 decoy at len 30 (bucket 32x32 — fills the
    4-lane class) then 2 exact pairs at len 70 (bucket 128x128 —
    partial, flush-dispatched).  The decoy fails the whole k-doubling
    ladder, forcing exactly one compacted rescue rung."""
    rng = np.random.default_rng(77)
    mk = lambda n: rng.integers(0, 4, n).astype(np.uint8)  # noqa: E731
    reads, refs = [], []
    for _ in range(3):
        r = mk(30)
        reads.append(r)
        refs.append(r.copy())
    reads.append(mk(30))
    refs.append(mk(30))            # decoy: unrelated ref
    for _ in range(2):
        r = mk(70)
        reads.append(r)
        refs.append(r.copy())
    return reads, refs


# --------------------------------------------------------------------------
# registry semantics
# --------------------------------------------------------------------------

def test_registry_memoises_by_name_and_labels():
    reg = MetricsRegistry()
    c = reg.counter("x_total", tenant="a")
    assert reg.counter("x_total", tenant="a") is c
    assert reg.counter("x_total", tenant="b") is not c
    assert reg.counter("x_total") is not c
    c.inc()
    c.inc(5)
    assert c.value == 6
    g = reg.gauge("depth")
    g.set(3)
    g.add(-1)
    assert g.value == 2
    assert qualified_name(c.name, c.labels) == 'x_total{tenant="a"}'


def test_registry_kind_conflict_and_fixed_edges():
    reg = MetricsRegistry()
    reg.counter("m")
    with pytest.raises(TypeError):
        reg.gauge("m")
    h = reg.histogram("h_seconds", edges=(0.1, 1.0))
    assert reg.histogram("h_seconds", edges=(0.1, 1.0)) is h
    with pytest.raises(ValueError):
        reg.histogram("h_seconds", edges=(0.1, 2.0))


def test_histogram_cumulative_snapshot():
    h = MetricsRegistry().histogram("lat", edges=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    snap = h.snap()
    assert snap["buckets"] == {"0.1": 1, "1.0": 2, "+Inf": 3}
    assert snap["count"] == 3 and snap["sum"] == 0.05 + 0.5 + 5.0


def test_labeled_view_stamps_and_filters():
    base = MetricsRegistry()
    view = base.labeled(session="jnp")
    c = view.counter("pairs_total")
    assert c is base.counter("pairs_total", session="jnp")
    assert c.labels == (("session", "jnp"),)
    base.counter("other_total").inc()
    assert set(view.snapshot()) == {'pairs_total{session="jnp"}'}
    assert set(base.snapshot()) == {'pairs_total{session="jnp"}',
                                    "other_total"}
    nested = view.labeled(shard="0")
    assert nested.counter("pairs_total").labels == \
        (("session", "jnp"), ("shard", "0"))


def test_default_registry_is_process_global():
    assert default_registry() is default_registry()
    assert default_registry().enabled


# --------------------------------------------------------------------------
# tracer semantics
# --------------------------------------------------------------------------

def test_tracer_nesting_timestamps_and_error_attr():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("outer", x=1):
        clk.advance(1.0)
        with tr.span("inner"):
            clk.advance(0.5)
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("no")
    inner, outer, boom = tr.records()
    assert (inner["name"], inner["t0"], inner["t1"]) == ("inner", 1.0, 1.5)
    assert inner["parent"] == outer["sid"]
    assert (outer["t0"], outer["t1"], outer["parent"]) == (0.0, 1.5, None)
    assert outer["attrs"] == {"x": 1}
    assert boom["attrs"]["error"] == "RuntimeError"


def test_tracer_stacks_are_per_thread():
    tr = Tracer(clock=FakeClock())
    with tr.span("main.open"):
        t = threading.Thread(
            target=lambda: tr.span("worker").__enter__().__exit__(
                None, None, None), name="obs-worker")
        t.start()
        t.join()
    worker, main = tr.records()
    assert worker["parent"] is None        # not a fake child of main.open
    assert worker["thread"] == "obs-worker"
    assert main["parent"] is None


def test_tracer_records_are_bounded():
    tr = Tracer(clock=FakeClock(), maxlen=4)
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    assert [r["name"] for r in tr.records()] == ["s6", "s7", "s8", "s9"]


# --------------------------------------------------------------------------
# exporters
# --------------------------------------------------------------------------

def test_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter("req_total", tenant="a").inc(3)
    reg.gauge("depth").set(2)
    h = reg.histogram("lat_seconds", edges=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    text = prometheus_text(reg)
    for line in (
        "# TYPE depth gauge",
        "depth 2",
        "# TYPE lat_seconds histogram",
        'lat_seconds_bucket{le="0.1"} 1',
        'lat_seconds_bucket{le="1.0"} 2',
        'lat_seconds_bucket{le="+Inf"} 3',
        f"lat_seconds_sum {h.sum}",
        "lat_seconds_count 3",
        "# TYPE req_total counter",
        'req_total{tenant="a"} 3',
    ):
        assert line in text.splitlines(), line
    assert prometheus_text(MetricsRegistry()) == ""


def test_jsonl_and_perfetto_export():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("work", lanes=4):
        clk.advance(0.002)
    lines = trace_jsonl(tr).splitlines()
    assert len(lines) == 1
    rec = json.loads(lines[0])
    assert rec["name"] == "work" and rec["attrs"] == {"lanes": 4}
    assert (rec["t0"], rec["t1"]) == (0.0, 0.002)

    doc = perfetto_trace(tr)
    assert doc["displayTimeUnit"] == "ms"
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert meta[0]["args"]["name"] == rec["thread"]
    (x,) = xs
    assert x["ts"] == 0.0 and x["dur"] == pytest.approx(2000.0)
    assert x["args"]["lanes"] == 4 and x["args"]["sid"] == rec["sid"]


def test_write_artifacts(tmp_path):
    obs = Obs.private(clock=FakeClock())
    obs.counter("c_total").inc()
    with obs.span("s"):
        pass
    paths = write_artifacts(obs, str(tmp_path), prefix="t")
    assert sorted(paths) == ["jsonl", "perfetto", "prometheus"]
    assert "c_total 1" in open(paths["prometheus"]).read()
    assert json.loads(open(paths["jsonl"]).read())["name"] == "s"
    assert json.load(open(paths["perfetto"]))["traceEvents"]


# --------------------------------------------------------------------------
# the disabled bundle: identity + zero allocations
# --------------------------------------------------------------------------

def test_resolve_obs_contract():
    assert resolve_obs("off") is OBS_OFF
    assert resolve_obs(False) is OBS_OFF
    bundle = Obs.private()
    assert resolve_obs(bundle) is bundle
    fresh = resolve_obs(None)
    assert fresh.enabled and fresh is not bundle
    with pytest.raises(TypeError):
        resolve_obs(42)


def test_null_bundle_identity():
    assert OBS_OFF.counter("anything", label="x") is NULL_METRIC
    assert OBS_OFF.gauge("g") is NULL_METRIC
    assert OBS_OFF.histogram("h") is NULL_METRIC
    assert OBS_OFF.span("s", a=1) is NULL_SPAN
    assert OBS_OFF.labeled(session="x") is not None
    assert OBS_OFF.labeled(session="x").counter("c") is NULL_METRIC
    assert NULL_REGISTRY.labeled(anything="y") is NULL_REGISTRY
    assert not OBS_OFF.enabled
    assert OBS_OFF.snapshot() == {} and OBS_OFF.prometheus() == ""
    assert NULL_TRACER.records() == []
    NULL_METRIC.inc()
    NULL_METRIC.observe(1.0)
    NULL_METRIC.set(5)
    assert NULL_METRIC.value == 0


def test_obs_off_session_is_a_true_noop():
    """plan(obs='off'): every session metric IS the null singleton, and a
    full submit->align->retire(+rescue) cycle performs ZERO allocations
    attributable to the repro.obs module (tracemalloc, filtered)."""
    reads, refs = _corpus()
    with plan(CFG, **PLAN_KW, obs="off") as s:
        assert s.obs is OBS_OFF
        assert all(m is NULL_METRIC for m in s._m.values())
        assert s.stats == {k: 0 for k in AlignSession.STAT_METRICS}
        s.align(reads, refs)           # warm: compiles outside the window

        obs_dir = os.path.dirname(repro.obs.__file__)
        filters = [tracemalloc.Filter(True, os.path.join(obs_dir, "*"))]
        tracemalloc.start()
        # one traced steady-state pass first: lets CPython's frame
        # freelist and the (still-enabled, process-global) transfer
        # counters reach steady state under tracing, so the measured
        # window is pure per-align cost
        s.align(reads, refs)
        before = tracemalloc.take_snapshot()
        res = s.align(reads, refs)
        after = tracemalloc.take_snapshot()
        tracemalloc.stop()
        diff = after.filter_traces(filters).compare_to(
            before.filter_traces(filters), "lineno")
        grew = [d for d in diff if d.size_diff > 0 or d.count_diff > 0]
        assert not grew, grew
        # the telemetry trade is explicit: stats read zeros, results don't
        assert s.stats["requests"] == 0
        assert not res.failed[:3].any() and res.failed[3]


# --------------------------------------------------------------------------
# legacy accessors == registry reads (the four migrated families)
# --------------------------------------------------------------------------

def test_transfer_family_matches_registry():
    transfer.reset()
    snap0 = default_registry().snapshot()
    assert snap0["transfer_h2d_calls_total"] == 0
    x = np.zeros((4, 8), np.uint8)
    dev = transfer.to_device((x, x))
    transfer.to_host(dev)
    s = transfer.stats()
    snap = default_registry().snapshot()
    assert s.h2d_calls == snap["transfer_h2d_calls_total"] == 1
    assert s.d2h_calls == snap["transfer_d2h_calls_total"] == 1
    assert s.h2d_bytes == snap["transfer_h2d_bytes_total"] == 2 * x.nbytes
    assert s.d2h_bytes == snap["transfer_d2h_bytes_total"]
    # reset() is per-family, never registry-wide
    marker = default_registry().counter("compile_cache_hits_total").value
    transfer.reset()
    assert transfer.stats() == transfer.TransferStats()
    assert default_registry().counter(
        "compile_cache_hits_total").value == marker


def test_compile_cache_family_matches_registry():
    reg = MetricsRegistry()
    cc = CompileCache(registry=reg)
    cc.get(("k1",), lambda: "exe1")
    cc.get(("k1",), lambda: "exe1")
    cc.get(("k2",), lambda: "exe2")
    snap = reg.snapshot()
    assert cc.hits == snap["compile_cache_hits_total"] == 1
    assert cc.misses == snap["compile_cache_misses_total"] == 2
    assert cc.lowerings == snap["compile_cache_lowerings_total"] == 2
    assert cc.stats()["lowerings"] == 2


def test_session_and_cache_view_families_match_registry():
    reads, refs = _corpus()
    with plan(CFG, **PLAN_KW) as s:
        s.align(reads, refs)
        snap = s.obs.snapshot()
        for key, name in AlignSession.STAT_METRICS.items():
            assert s.stats[key] == snap[name], (key, name)
        assert s.stats["requests"] == 6
        assert s.stats["dispatches"] == 2
        assert s.stats["rescue_dispatches"] == 1
        # the per-session cache view rides the same registry
        assert s.cache.hits == snap["session_cache_hits_total"]
        assert s.cache.misses == snap["session_cache_misses_total"]
        assert s.cache.lowerings == snap["session_cache_lowerings_total"]
        assert s.cache.shared_hits == snap["session_cache_shared_hits_total"]


def test_gateway_family_matches_registry():
    clk = FakeClock()
    s = plan(CFG, rescue_rounds=0, batch_lanes=4, clock=clk)
    g = Gateway(s, GatewayPolicy(capacity=64), clock=clk, auto_pump=False)
    try:
        rng = np.random.default_rng(3)
        ten = g.tenant("acme")
        pairs = []
        for _ in range(4):
            r = rng.integers(0, 4, 30).astype(np.uint8)
            pairs.append(ten.submit(r, r.copy()))
        g.pump(clk())
        for gf in pairs:
            assert gf.result()["ok"]
        snap = g.obs.snapshot()            # gateway shares the session obs
        for key, name in Gateway.STAT_METRICS.items():
            assert g.stats[key] == snap[name], (key, name)
        assert g.stats["submitted"] == 4 and g.stats["completed"] == 4
        out = g.gateway_stats()
        assert out["submitted"] == snap["gateway_submitted_total"]
        assert out["tenants"]["acme"]["completed"] == \
            snap['gateway_tenant_completed_total{tenant="acme"}'] == 4
        # live-load gauges mirror the functional ints
        assert out["queued"] == snap["gateway_queued"] == 0
        assert out["outstanding"] == snap["gateway_outstanding"] == 0
        # completion latency lands in the histogram
        assert snap["gateway_latency_seconds"]["count"] == 4
    finally:
        g.close()
        s.close()


def test_mapper_funnel_matches_registry_deltas():
    from repro.data.genome import ReadSimConfig, simulate_reads, synth_genome
    from repro.mapper import ReadMapper

    genome = synth_genome(30_000, seed=3)
    rs = simulate_reads(genome, 4, ReadSimConfig(read_len=200,
                                                 error_rate=0.05, seed=4))
    with ReadMapper(genome, backend="jnp", W=32, O=12, k=8,
                    rescue_rounds=1, batch_lanes=8) as m:
        b1 = m.map_batch(rs.reads[:2])
        b2 = m.map_batch(rs.reads[2:])
        snap = m.obs.snapshot()
        for key, name in ReadMapper.FUNNEL_METRICS.items():
            assert b1.stats[key] + b2.stats[key] == snap[name], (key, name)
        assert snap["mapper_batches_total"] == 2
        assert b1.stats["n_reads"] == 2 and b2.stats["n_reads"] == 2
        for b in (b1, b2):
            assert b.stats["kill_rate"] == \
                b.stats["n_killed"] / max(1, b.stats["n_candidates"])
        # funnel spans nested under the batch span
        recs = m.obs.tracer.records()
        batches = [r for r in recs if r["name"] == "mapper.map_batch"]
        assert len(batches) == 2
        for stage in ("index.lookup", "chain", "prefilter", "align"):
            stage_recs = [r for r in recs if r["name"] == stage]
            assert len(stage_recs) == 2, stage
            assert {r["parent"] for r in stage_recs} == \
                {b["sid"] for b in batches}


# --------------------------------------------------------------------------
# the exact span tree of a session dispatch (fake clock, zero sleeps)
# --------------------------------------------------------------------------

def test_session_trace_exact_span_tree():
    """2-bucket ragged batch, one rescue rung, sync executor, FakeClock:
    the complete trace is byte-stable — exact names, nesting, attrs and
    (never-advanced) timestamps."""
    clk = FakeClock()
    reads, refs = _corpus()
    with plan(CFG, **PLAN_KW, clock=clk) as s:
        res = s.align(reads, refs)
    assert not res.failed[:3].any() and res.failed[3]
    recs = s.obs.tracer.records()
    assert [r["name"] for r in recs] == [
        "device.execute", "session.dispatch",   # bucket 32x32 (4 lanes)
        "device.execute", "session.dispatch",   # bucket 128x128 (flush)
        "rescue.rung", "retire.decode",         # decoy forces one rung
        "retire.decode",
    ]
    exe_a, disp_a, exe_b, disp_b, rung, ret_a, ret_b = recs
    assert disp_a["attrs"] == {"bucket": "32x32", "lanes": 4, "n_real": 4}
    assert disp_b["attrs"] == {"bucket": "128x128", "lanes": 2, "n_real": 2}
    assert exe_a["parent"] == disp_a["sid"] and disp_a["parent"] is None
    assert exe_b["parent"] == disp_b["sid"] and disp_b["parent"] is None
    assert rung["attrs"] == {"k": 4, "lanes": 1, "n_todo": 1}
    assert rung["parent"] == ret_a["sid"] and ret_a["parent"] is None
    assert ret_a["attrs"] == {"n": 4} and ret_b["attrs"] == {"n": 2}
    assert ret_b["parent"] is None
    # FakeClock never advanced: every timestamp is exactly 0.0, and the
    # whole trace ran on this thread (sync executor)
    assert {r["t0"] for r in recs} == {0.0} and {r["t1"] for r in recs} == {0.0}
    assert {r["thread"] for r in recs} == {threading.current_thread().name}
    # sids are allocated in OPEN order (dispatch before its child)
    assert disp_a["sid"] < exe_a["sid"] < disp_b["sid"] < exe_b["sid"]


# --------------------------------------------------------------------------
# done-callback regression: raising callbacks never poison the session
# --------------------------------------------------------------------------

class _Boom(BaseException):
    """Deliberately NOT an Exception: the pre-PR code caught only
    Exception in _run_callbacks, so a BaseException (KeyboardInterrupt in
    a client hook) unwound into the retire path and poisoned the
    session."""


@pytest.mark.parametrize("executor", ["sync", "thread"])
def test_raising_done_callback_is_recorded_not_poisoning(executor):
    reads, refs = _corpus()
    with plan(CFG, **PLAN_KW, executor=executor) as s:
        futs = [s.submit(r, f) for r, f in zip(reads[:4], refs[:4])]

        def boom(_fut):
            raise _Boom("client hook blew up")

        seen = []
        futs[0].add_done_callback(boom)
        futs[1].add_done_callback(seen.append)
        s.flush()
        recs = [f.result() for f in futs]      # no SessionPoisonedError
        assert [r["ok"] for r in recs] == [True, True, True, False]
        assert seen == [futs[1]]               # other callbacks still ran
        assert s.stats["callback_errors"] == 1
        assert s.obs.counter("session_callback_errors_total").value == 1
        # the session stays fully usable afterwards
        res = s.align(reads[:3], refs[:3])
        assert not res.failed.any()
        assert s.stats["callback_errors"] == 1


def test_callback_on_already_done_future_also_guarded():
    reads, refs = _corpus()
    with plan(CFG, **PLAN_KW) as s:
        fut = s.submit(reads[0], refs[0])
        s.flush()
        assert fut.result()["ok"]

        def boom(_fut):
            raise _Boom("late hook")

        fut.add_done_callback(boom)            # runs immediately — guarded
        assert s.stats["callback_errors"] == 1
        assert not s.align(reads[:2], refs[:2]).failed.any()
