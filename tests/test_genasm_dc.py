"""GenASM-DC == Levenshtein level sets (the exactness claim both fill
orders are tested against)."""
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.core.config import AlignerConfig
from repro.core.genasm import dc_dmajor, dc_jmajor
from repro.core.oracle import levenshtein
from tests.conftest import mutate_seq

seq = st.lists(st.integers(0, 3), min_size=1, max_size=48)


@given(seq, seq, st.integers(1, 12))
@settings(max_examples=12, deadline=None)
def test_jmajor_distance_matches_oracle(p, t, k):
    m_pad = 64
    pat = jnp.array([p + [255] * (m_pad - len(p))], jnp.int32)
    txt = jnp.array([t + [9] * (m_pad - len(t))], jnp.int32)
    res = dc_jmajor(pat, txt, jnp.array([len(p)]), jnp.array([len(t)]),
                    k=k, n=m_pad, nw=2, store="and")
    ed = levenshtein(np.array(p), np.array(t))
    want = ed if ed <= k else k + 1
    assert int(res.dist[0]) == want


@pytest.mark.parametrize("W,k", [(16, 3), (32, 9), (64, 12), (96, 15)])
def test_dmajor_matches_oracle_square(W, k, rng):
    cfg = AlignerConfig(W=W, O=max(1, W // 3), k=k)
    B = 12
    pats, txts, eds = [], [], []
    for _ in range(B):
        p = rng.integers(0, 4, W).astype(np.uint8)
        t = mutate_seq(p, int(rng.integers(0, k + 3)), rng, extend_to=W)
        pats.append(p); txts.append(t)
        eds.append(levenshtein(p, t))
    res = dc_dmajor(jnp.array(np.stack(pats)), jnp.array(np.stack(txts)),
                    cfg=cfg)
    want = np.array([e if e <= k else k + 1 for e in eds])
    assert (np.array(res.dist) == want).all()


def test_early_termination_stops_levels(rng):
    cfg = AlignerConfig(W=32, O=12, k=12, early_term=True)
    p = rng.integers(0, 4, 32).astype(np.uint8)
    t = p.copy()  # identical -> distance 0
    res = dc_dmajor(jnp.array([p] * 4), jnp.array([t] * 4), cfg=cfg)
    assert int(res.dist[0]) == 0
    assert int(res.levels_run) == 1      # level 0 solved it; ET stopped
    cfg2 = AlignerConfig(W=32, O=12, k=12, early_term=False)
    res2 = dc_dmajor(jnp.array([p] * 4), jnp.array([t] * 4), cfg=cfg2)
    assert int(res2.levels_run) == cfg2.k + 1
    assert int(res2.dist[0]) == 0
