"""Optional-`hypothesis` shim for the property tests.

When hypothesis is installed the real `given`/`settings`/`strategies` are
re-exported unchanged.  When it is absent (e.g. the bare container the
tier-1 suite runs in) a minimal seeded-random fallback provides the same
surface the tests use — `st.integers`, `st.lists`, `st.data`, `@given`,
`@settings(max_examples=..., deadline=...)` — generating a deterministic
stream of examples per test (seeded from the test name), so the suite
collects and passes everywhere.  The fallback does not shrink failures;
install hypothesis (see requirements-dev.txt) for real property testing.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 25

    class _Strategy:
        """A strategy is just a sampler: sample(rng) -> value."""

        def __init__(self, sample):
            self.sample = sample

    class _DataObject:
        """Fallback for st.data(): interactive draws from the example rng."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.sample(self._rng)

    class _DataStrategy(_Strategy):
        def __init__(self):
            super().__init__(lambda rng: _DataObject(rng))

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def sample(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.sample(rng) for _ in range(n)]
            return _Strategy(sample)

        @staticmethod
        def data():
            return _DataStrategy()

    st = _St()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **kw):
        def deco(fn):
            fn._hyp_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            max_ex = getattr(fn, "_hyp_max_examples", _DEFAULT_MAX_EXAMPLES)
            base_seed = zlib.crc32(
                f"{fn.__module__}.{fn.__qualname__}".encode())

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                for ex in range(max_ex):
                    rng = np.random.default_rng((base_seed, ex))
                    vals = [s.sample(rng) for s in strategies]
                    try:
                        fn(*args, *vals, **kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example #{ex} for {fn.__name__}: "
                            f"args={vals!r}") from e
            # pytest must not see the strategy parameters as fixtures:
            # drop functools.wraps' __wrapped__ so the reported signature
            # is (*args, **kwargs) rather than fn's.
            del wrapper.__wrapped__
            return wrapper
        return deco
