"""Registry/input-spec invariants for all 40 (arch x shape) cells."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.registry import (ARCH_IDS, SHAPES, get_config, get_model,
                                   input_specs, shape_applicable)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_well_formed(arch, shape):
    cfg = get_config(arch)
    if not shape_applicable(cfg, shape):
        assert shape == "long_500k"
        assert cfg.family not in ("ssm", "hybrid")
        return
    S, GB, kind = SHAPES[shape]
    specs = input_specs(cfg, shape)
    b = specs["batch"]
    lead = next(iter(b.values())).shape[0]
    if "positions" in b:
        assert b["positions"].shape[0] == 3        # M-RoPE
    if kind == "train":
        assert "labels" in b
        key = "embeds" if cfg.family == "audio" else "tokens"
        assert b[key].shape[:2] == (GB, S)
    elif kind == "prefill":
        key = "embeds" if cfg.family == "audio" else "tokens"
        assert b[key].shape[:2] == (GB, S)
        assert "labels" not in b
    else:
        assert "cache" in specs
        key = "embeds" if cfg.family == "audio" else "tokens"
        assert b[key].shape[:2] == (GB, 1)
        # every cache leaf is an abstract spec (no allocation)
        for leaf in jax.tree_util.tree_leaves(specs["cache"]):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_long_500k_runs_only_for_subquadratic():
    runs = [a for a in ARCH_IDS
            if shape_applicable(get_config(a), "long_500k")]
    assert sorted(runs) == ["xlstm-125m", "zamba2-2.7b"]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_internally_consistent(arch):
    import math
    from repro.models.common import ParamSpec
    cfg = get_config(arch)
    model = get_model(cfg)
    specs = model.param_specs()
    n = 0
    for ps in jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, ParamSpec)):
        assert isinstance(ps, ParamSpec)
        assert len(ps.spec) == len(ps.shape)
        n += math.prod(ps.shape)
    assert n > 0
