"""repro.mapper: index/chain/pre-filter units + end-to-end differential.

The load-bearing claims:

* the minimizer index finds true-locus anchors under the simulator's
  error profile (seeding recall),
* chaining turns them into candidate windows that cover the true locus
  within a few bases at each end,
* the X-drop pre-filter separates true loci from planted partial-repeat
  decoys (kill specificity/sensitivity),
* and the pipeline's final CIGARs are BIT-IDENTICAL to a direct
  AlignSession.align on the same (read, segment) pairs — the mapper adds
  a front half, it never changes alignment semantics.

Small geometry (W=32 jnp, 400bp reads) keeps this tier-1 fast.
"""
import numpy as np
import pytest

from repro.api import plan
from repro.data.genome import (ReadSimConfig, plant_decoys, simulate_reads,
                               synth_genome)
from repro.mapper import (MapperConfig, MinimizerIndex, ReadMapper,
                          chain_anchors, minimizers, pack_pairs,
                          xdrop_extend)

SESSION_KW = dict(backend="jnp", W=32, O=12, k=8, rescue_rounds=2,
                  batch_lanes=16)


@pytest.fixture(scope="module")
def world():
    """Genome with planted partial-repeat decoys + simulated reads."""
    g = synth_genome(120_000, seed=21)
    cfg = ReadSimConfig(read_len=400, error_rate=0.10, seed=22)
    rs = simulate_reads(g, 24, cfg)
    g2, decoy_pos = plant_decoys(g, rs, decoys_per_read=4, chunk=160,
                                 divergence=0.03, seed=23)
    return g2, rs, decoy_pos


@pytest.fixture(scope="module")
def mapped(world):
    g2, rs, _ = world
    with ReadMapper(g2, **SESSION_KW) as m:
        out = m.map_batch(rs.reads)
        cands = [m.candidates(r) for r in rs.reads]
    return out, cands


# -- units -----------------------------------------------------------------

def test_minimizers_shared_on_identical_stretches():
    """Two sequences sharing an error-free stretch >= w + k - 1 select at
    least one common minimizer inside it — the anchor-recall invariant."""
    rng = np.random.default_rng(1)
    core = rng.integers(0, 4, 60).astype(np.uint8)
    a = np.concatenate([rng.integers(0, 4, 37).astype(np.uint8), core])
    b = np.concatenate([rng.integers(0, 4, 11).astype(np.uint8), core])
    ha, _ = minimizers(a, 13, 8)
    hb, _ = minimizers(b, 13, 8)
    assert len(np.intersect1d(ha, hb)) >= 1
    # sentinel-poisoned k-mers never become minimizers
    c = a.copy()
    c[45] = 255
    _, pc = minimizers(c, 13, 8)
    assert all(not (p <= 45 < p + 13) for p in pc)


def test_index_anchors_lie_on_true_diagonal():
    g = synth_genome(50_000, seed=2)
    idx = MinimizerIndex.build(g)
    read = g[7000:7400].copy()
    qpos, rpos = idx.anchors(read)
    assert len(qpos) >= 10
    assert np.all(rpos - qpos == 7000)      # exact copy: one diagonal
    st = idx.stats()
    assert st["n_minimizers"] > 0 and 0.1 < st["density"] < 0.5


def test_chain_extrapolates_candidate_window():
    # anchors on diagonal 5000 with +-2 indel drift, plus a stray cluster
    q = np.array([40, 120, 200, 290, 360, 50, 60])
    r = np.array([5040, 5121, 5198, 5292, 5360, 9050, 9061])
    cands = chain_anchors(q, r, read_len=400, min_anchors=3)
    assert len(cands) == 1                   # stray pair < min_anchors
    c = cands[0]
    assert abs(c.ref_start - 5000) <= 4
    assert abs(c.ref_end - 5400) <= 4
    assert c.score == 5


def test_xdrop_separates_true_from_decoy():
    rng = np.random.default_rng(3)
    seg = rng.integers(0, 4, 160).astype(np.uint8)
    read = seg[:128].copy()
    read[::10] = (read[::10] + 1) % 4        # ~10% mismatches
    decoy = rng.integers(0, 4, 160).astype(np.uint8)
    reads, refs = pack_pairs([read, read], [seg, decoy], 128, 16, lanes=16)
    scores = np.asarray(xdrop_extend(reads, refs, band=16, x_drop=24))
    true_s, decoy_s = int(scores[0]), int(scores[1])
    assert true_s >= 0.25 * 128              # survives the keep threshold
    assert decoy_s < 0.25 * 128              # frozen early, killed
    assert np.all(scores[2:] == 0)           # all-sentinel pad lanes


# -- end to end ------------------------------------------------------------

def test_mapper_recall_and_precision_on_decoy_rich_reads(world, mapped):
    g2, rs, decoy_pos = world
    out, _ = mapped
    st = out.stats
    assert st["n_reads"] == 24
    # decoys seeded extra candidates, and the pre-filter killed them
    assert st["n_candidates"] > st["n_reads"]
    assert st["n_killed"] > 0 and st["kill_rate"] > 0.2
    hits = sum(1 for mr, tp in zip(out.mapped, rs.true_pos)
               if mr.ok and abs(mr.ref_start - tp) <= 20)
    assert hits / st["n_reads"] >= 0.95      # recall floor
    for mr in out.mapped:                    # precision: never a decoy
        if mr.ok:
            i = mr.read_id
            assert all(abs(mr.ref_start - dp) > 50 for dp in decoy_pos[i])
    # decoy-locus candidates were specifically the killed ones
    killed_starts = [c.ref_start for mr in out.mapped
                     for c in mr.candidates if c.killed]
    assert any(any(abs(ks - dp) < 200 for dp in decoy_pos.ravel())
               for ks in killed_starts)


def test_mapper_cigars_bit_identical_to_direct_session(world, mapped):
    """The differential contract: for each mapped read, aligning the SAME
    (read, genome[c.ref_start:c.ref_end]) pair through a fresh
    AlignSession yields the same cigar/dist/k_used byte for byte."""
    g2, rs, _ = world
    out, cands = mapped
    pairs = []
    for mr in out.mapped[:8]:
        if not mr.ok:
            continue
        c = next(c for c in cands[mr.read_id]
                 if c.ref_start == mr.ref_start)
        pairs.append((mr, rs.reads[mr.read_id], g2[c.ref_start:c.ref_end]))
    assert len(pairs) >= 6
    with plan(**SESSION_KW) as s:
        res = s.align([p[1] for p in pairs], [p[2] for p in pairs])
    for (mr, _, _), cig, dist in zip(pairs, res.cigars, res.dist):
        assert mr.cigar == cig
        assert mr.dist == int(dist)


def test_mapper_prefilter_off_maps_same_loci(world, mapped):
    """With the pre-filter disabled nothing is killed; decoy candidates
    just fail to align inside the k ladder, so the chosen loci match the
    filtered run (slower, same answer)."""
    g2, rs, _ = world
    out, _ = mapped
    cfg = MapperConfig(prefilter=False)
    with ReadMapper(g2, cfg, **SESSION_KW) as m:
        out2 = m.map_batch(rs.reads[:5])
    assert out2.stats["n_killed"] == 0
    assert out2.stats["n_aligned"] == out2.stats["n_candidates"]
    for a, b in zip(out.mapped[:5], out2.mapped):
        assert (a.ok, a.ref_start) == (b.ok, b.ref_start)


def test_mapper_handles_unmappable_and_string_reads(world):
    g2, _, _ = world
    with ReadMapper(g2, **SESSION_KW) as m:
        junk = "".join("ACGT"[i % 4] for i in range(200))  # low-complexity
        mr = m.map_read(junk)
        assert not mr.ok and mr.ref_start == -1 and mr.cigar == ""
        # a genuine string read maps
        real = "".join("ACGT"[c] for c in g2[11000:11300])
        mr2 = m.map_read(real)
        assert mr2.ok and abs(mr2.ref_start - 11000) <= 8
