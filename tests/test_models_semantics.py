"""Deeper model-semantics tests: chunked == recurrent for SSD/mLSTM,
attention variants vs naive reference, MoE dispatch properties,
prefill+decode == full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import NO_WINDOW, attention
from repro.models.mamba2 import ssd_chunked
from repro.models.registry import get_config, get_model, tiny_config
from repro.serve.kvcache import pad_cache


def test_ssd_chunked_equals_recurrence():
    rng = np.random.default_rng(0)
    B, L, H, P, N = 2, 64, 3, 8, 5
    xh = jnp.array(rng.standard_normal((B, L, H, P)), jnp.float32)
    a_log = jnp.array(-np.abs(rng.standard_normal((B, L, H))) * 0.3)
    Bm = jnp.array(rng.standard_normal((B, L, N)), jnp.float32)
    Cm = jnp.array(rng.standard_normal((B, L, N)), jnp.float32)
    y_c, h_c = ssd_chunked(xh, a_log, Bm, Cm, chunk=16)
    # naive recurrence
    h = np.zeros((B, H, N, P))
    ys = np.zeros((B, L, H, P))
    for t in range(L):
        a = np.exp(np.asarray(a_log)[:, t])          # (B,H)
        h = h * a[:, :, None, None] + np.einsum(
            "bn,bhp->bhnp", np.asarray(Bm)[:, t], np.asarray(xh)[:, t])
        ys[:, t] = np.einsum("bn,bhnp->bhp", np.asarray(Cm)[:, t], h)
    np.testing.assert_allclose(np.asarray(y_c), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_c), h, rtol=2e-4, atol=2e-4)


def test_ssd_chunk_invariance():
    rng = np.random.default_rng(1)
    B, L, H, P, N = 1, 96, 2, 4, 6
    xh = jnp.array(rng.standard_normal((B, L, H, P)), jnp.float32)
    a_log = jnp.array(-np.abs(rng.standard_normal((B, L, H))) * 0.2)
    Bm = jnp.array(rng.standard_normal((B, L, N)), jnp.float32)
    Cm = jnp.array(rng.standard_normal((B, L, N)), jnp.float32)
    y1, _ = ssd_chunked(xh, a_log, Bm, Cm, chunk=8)
    y2, _ = ssd_chunked(xh, a_log, Bm, Cm, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


def naive_attention(q, k, v, scale, window, causal=True):
    B, S, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    out = np.zeros_like(np.asarray(q), dtype=np.float32)
    qn, kn, vn = map(np.asarray, (q, k, v))
    for b in range(B):
        for h in range(H):
            kvh = h // G
            s = qn[b, :, h] @ kn[b, :, kvh].T * scale
            for i in range(S):
                for j in range(S):
                    if j > i or j <= i - window:
                        s[i, j] = -1e30
            p = np.exp(s - s.max(-1, keepdims=True))
            p /= p.sum(-1, keepdims=True)
            out[b, :, h] = p @ vn[b, :, kvh]
    return out


@pytest.mark.parametrize("window", [NO_WINDOW, 5])
def test_attention_matches_naive(window):
    rng = np.random.default_rng(2)
    B, S, H, KV, Dh = 1, 12, 4, 2, 8
    q = jnp.array(rng.standard_normal((B, S, H, Dh)), jnp.float32)
    k = jnp.array(rng.standard_normal((B, S, KV, Dh)), jnp.float32)
    v = jnp.array(rng.standard_normal((B, S, KV, Dh)), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    out = attention(q, k, v, pos, pos, window=window, cap=0.0,
                    scale=1 / np.sqrt(Dh), q_chunk=5)  # forces chunked path
    want = naive_attention(q, k, v, 1 / np.sqrt(Dh), window)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3.2-1b", "zamba2-2.7b", "xlstm-125m"])
def test_prefill_decode_matches_full_forward(arch):
    """logits for token S from (prefill S) + (decode 1) must match the full
    forward pass — validates KV caches, SSM states and chunked==recurrent."""
    cfg = tiny_config(get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab)

    full_logits, _, _ = model.forward(params, {"tokens": toks}, mode="train")
    _, cache = model.prefill(params, {"tokens": toks[:, :S]})
    cache = pad_cache(cache, S + 1)
    dec_logits, _ = model.decode_step(
        params, {"tokens": toks[:, S:S + 1], "cache_pos": jnp.int32(S)}, cache)
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0].astype(jnp.float32)),
        np.asarray(full_logits[:, S].astype(jnp.float32)),
        rtol=0.08, atol=0.08)  # bf16 accumulation differences


def test_moe_routes_topk_and_balances():
    from repro.models.moe import moe_ffn
    cfg = tiny_config(get_config("olmoe-1b-7b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    p0 = jax.tree_util.tree_map(lambda a: a[0], params["layers"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
    y, aux = moe_ffn(p0, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux) >= 1.0 - 1e-3   # E * sum(me*fe) >= 1 by Cauchy-Schwarz


def test_gemma2_softcap_bounds_logits():
    cfg = tiny_config(get_config("gemma2-2b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # inflate head weights: without the cap logits would exceed 30
    params["embed"] = params["embed"] * 100.0
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab)
    logits, _, _ = model.forward(params, {"tokens": toks}, mode="train")
    assert float(jnp.max(jnp.abs(logits.astype(jnp.float32)))) <= 30.0 + 1e-3


def test_moe_matches_dense_reference():
    """With ample capacity every token is processed by exactly its top-k
    experts: sort-based dispatch == dense per-token expert mixture."""
    import dataclasses
    from repro.models.moe import moe_ffn, router_topk
    from repro.models.common import act_fn
    cfg = tiny_config(get_config("olmoe-1b-7b"))
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)   # no drops
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    p0 = jax.tree_util.tree_map(lambda a: a[0], params["layers"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model),
                          jnp.float32) * 0.3
    y, _ = moe_ffn(p0, x, cfg)
    # dense reference
    w, idx, _ = router_topk(x, p0["router"], cfg)
    act = act_fn(cfg.act)
    h = act(jnp.einsum("bsd,edf->bsef", x, p0["wg"])) * \
        jnp.einsum("bsd,edf->bsef", x, p0["wu"])
    ye_all = jnp.einsum("bsef,efd->bsed", h, p0["wd"])    # (B,S,E,D)
    ref = jnp.zeros_like(x)
    for kk in range(cfg.top_k):
        sel = jnp.take_along_axis(
            ye_all, idx[..., kk][..., None, None], axis=2)[:, :, 0]
        ref = ref + sel * w[..., kk][..., None]
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
