"""Myers (Edlib-like) and banded affine DP (KSW2-like) vs oracles."""
import jax.numpy as jnp
import numpy as np
from tests._hyp import given, settings, st

from repro.baselines.dp import affine_traceback, banded_affine_dist
from repro.baselines.myers import banded_traceback, myers_distance
from repro.core.oracle import levenshtein, validate_cigar

seq = st.lists(st.integers(0, 3), min_size=1, max_size=70)


@given(seq, seq)
@settings(max_examples=50, deadline=None)
def test_myers_matches_levenshtein(p, t):
    m_pad, n_pad = 96, 96
    pat = jnp.array([p + [255] * (m_pad - len(p))], jnp.int32)
    txt = jnp.array([t + [9] * (n_pad - len(t))], jnp.int32)
    d = myers_distance(pat, txt, jnp.array([len(p)], jnp.int32),
                       jnp.array([len(t)], jnp.int32), nw=3, n=n_pad)
    assert int(d[0]) == levenshtein(np.array(p), np.array(t))


@given(seq, seq)
@settings(max_examples=40, deadline=None)
def test_banded_dp_unit_costs_match_levenshtein(p, t):
    bw = 70
    m_pad, n_pad = 70, 70
    p, t = p[:m_pad], t[:n_pad]
    pat = jnp.array([p + [255] * (m_pad - len(p))], jnp.int32)
    txt = jnp.array([t + [9] * (n_pad - len(t))], jnp.int32)
    d = banded_affine_dist(pat, txt, jnp.array([len(p)], jnp.int32),
                           jnp.array([len(t)], jnp.int32), bw=bw, m=m_pad)
    assert int(d[0]) == levenshtein(np.array(p), np.array(t))


def test_affine_costs_prefer_long_gaps():
    # with gap-open cost, one long gap beats two short ones
    p = np.array([0, 1, 2, 3, 0, 1, 2, 3], np.int32)
    t = np.array([0, 1, 2, 3, 2, 2, 0, 1, 2, 3], np.int32)  # 2 inserted
    pat = jnp.array([list(p) + [255] * 8]); txt = jnp.array([list(t) + [9] * 6])
    d = banded_affine_dist(pat, txt, jnp.array([8]), jnp.array([10]),
                           bw=8, m=16, sub=4, gapo=6, gape=2)
    # one gap of len2: 6 + 2*2 = 10
    assert int(d[0]) == 10


def test_baseline_tracebacks_valid(rng):
    for _ in range(5):
        p = rng.integers(0, 4, 50).astype(np.uint8)
        t = list(p)
        for _ in range(6):
            t.insert(int(rng.integers(0, len(t))), int(rng.integers(0, 4)))
        t = np.array(t, np.uint8)
        ed = levenshtein(p, t)
        d1, ops1 = banded_traceback(p, t, k=12)
        assert d1 == ed
        validate_cigar(p, t, ops1, d1)
        d2, ops2 = affine_traceback(p, t, bw=12)
        assert d2 == ed
        validate_cigar(p, t, ops2, d2)
