"""Alignment-as-a-service through the ONE front door (repro.api): plan an
AlignSession, AOT warm-up its length buckets before traffic, stream ragged
requests as futures, and read the compile-stability counters — the paper's
GPU batch processing mapped to a production-shaped serving layer.

    PYTHONPATH=src python examples/serve_alignment.py [--requests 32]
        [--len 800] [--fast]
"""
import argparse

import numpy as np

from repro.api import plan
from repro.core.config import AlignerConfig
from repro.data.genome import ReadSimConfig, simulate_reads, synth_genome

ap = argparse.ArgumentParser()
ap.add_argument("--requests", type=int, default=32)
ap.add_argument("--len", type=int, default=800, dest="rlen")
ap.add_argument("--fast", action="store_true",
                help="small geometry for CI smoke runs")
args = ap.parse_args()

cfg = AlignerConfig(W=32, O=12, k=8) if args.fast \
    else AlignerConfig(W=64, O=24, k=12)
genome = synth_genome(200_000 if args.fast else 500_000, seed=3)
# a RAGGED stream: three read-length classes hitting different buckets
lens = [max(64, args.rlen // 4), max(96, args.rlen // 2), args.rlen]
streams = [simulate_reads(genome, -(-args.requests // len(lens)),
                          ReadSimConfig(read_len=L, error_rate=0.08,
                                        seed=9 + i))
           for i, L in enumerate(lens)]

session = plan(cfg, rescue_rounds=1, batch_lanes=8)
# warm-up is a METHOD: from a traffic sample, compile every length bucket
# before the first request arrives (one AOT executable per bucket) —
# including the smaller lane class the ragged stream tails land in
buckets = sorted({session.bucket_for(len(r), len(s))
                  for rs in streams
                  for r, s in zip(rs.reads, rs.ref_segments)})
session.warmup(buckets)
tail = -(-args.requests // len(lens)) % session.spec.batch_lanes
warm = session.warmup(buckets, lanes=tail) if tail \
    else session.cache.stats()
print(f"warmed {warm['executables']} executables "
      f"(lowerings={warm['lowerings']})")

futures = {}
for rs in streams:
    for read, seg in zip(rs.reads, rs.ref_segments):
        fut = session.submit(read, seg)   # routed to its length bucket;
        futures[fut.rid] = fut            # dispatches double-buffer
session.flush()
results = {rid: fut.result() for rid, fut in futures.items()}

st = session.session_stats()
ok = sum(1 for r in results.values() if r["ok"])
print(f"served {len(results)} requests in {st['dispatches']} dispatches "
      f"({st['pad_lanes']} pad lanes), {ok} aligned, "
      f"{len(results) - ok} failed, "
      f"{len(results) / max(st['wall_s'], 1e-9):.1f} req/s")
cc = st["compile_cache"]
print(f"compile cache: {cc['lowerings']} lowerings "
      f"({cc['lowerings'] - warm['lowerings']} after warm-up, rescue-rung "
      f"lane classes) for {st['dispatches'] + st['rescue_dispatches']} "
      f"dispatches, {cc['hits']} hits — steady state never re-traces")
r0 = results[0]
print(f"request 0: dist={r0['dist']} k_used={r0['k_used']} "
      f"cigar[:60]={r0['cigar'][:60]}")
assert ok > 0
