"""Alignment-as-a-service through the ONE front door (repro.api): plan an
AlignSession with the background retire executor, AOT warm-up its length
buckets before traffic, stream ragged requests as futures while host CIGAR
decode overlaps dispatch on the retire thread, and read the
compile-stability counters — the paper's GPU batch processing mapped to a
production-shaped serving layer.

    PYTHONPATH=src python examples/serve_alignment.py [--requests 32]
        [--len 800] [--fast]
"""
import argparse
import time

import numpy as np

from repro.api import plan
from repro.core.config import AlignerConfig
from repro.data.genome import ReadSimConfig, simulate_reads, synth_genome

ap = argparse.ArgumentParser()
ap.add_argument("--requests", type=int, default=32)
ap.add_argument("--len", type=int, default=800, dest="rlen")
ap.add_argument("--fast", action="store_true",
                help="small geometry for CI smoke runs")
ap.add_argument("--backend", choices=("jnp", "pallas", "pallas_fused",
                                      "pallas_gpu"), default="jnp",
                help="aligner execution path (docs/backends.md); Pallas "
                     "backends print whether they run interpreted or "
                     "compiled on this host")
ap.add_argument("--executor", choices=("thread", "sync"), default="thread",
                help="'thread' (default) retires dispatches on the "
                     "background executor so CIGAR decode overlaps "
                     "dispatch; 'sync' is the single-threaded reference")
ap.add_argument("--gateway", action="store_true",
                help="additionally demo the multi-tenant gateway: two "
                     "tenants (latency lane with deadlines vs bulk) on "
                     "concurrent client threads, with the SLO readout")
ap.add_argument("--metrics-dump", action="store_true",
                help="after each demo, dump its obs registry as "
                     "Prometheus exposition text (every printed number "
                     "above is derivable from this dump — see "
                     "docs/observability.md)")
args = ap.parse_args()

cfg = AlignerConfig(W=32, O=12, k=8, backend=args.backend) if args.fast \
    else AlignerConfig(W=64, O=24, k=12, backend=args.backend)
if args.backend != "jnp":
    # say which execution mode is actually in effect on this host — the
    # backend names a lowering, default_interpret decides where it runs
    # (docs/backends.md, "Three-way execution modes")
    from repro.kernels.ops import default_interpret
    mode = "interpret" if default_interpret(args.backend) else "compiled"
    print(f"backend {args.backend}: {mode} mode on this host "
          f"(jax default_backend={__import__('jax').default_backend()})")
genome = synth_genome(200_000 if args.fast else 500_000, seed=3)
# a RAGGED stream: three read-length classes hitting different buckets
lens = [max(64, args.rlen // 4), max(96, args.rlen // 2), args.rlen]
streams = [simulate_reads(genome, -(-args.requests // len(lens)),
                          ReadSimConfig(read_len=L, error_rate=0.08,
                                        seed=9 + i))
           for i, L in enumerate(lens)]

# the session is a context manager: __exit__ drains and stops the
# background retire thread (clean shutdown is part of the executor API)
with plan(cfg, rescue_rounds=1, batch_lanes=8,
          executor=args.executor) as session:
    # warm-up is a METHOD: from a traffic sample, compile every length
    # bucket before the first request arrives (one AOT executable per
    # bucket) — including the smaller lane class the ragged stream tails
    # land in
    buckets = sorted({session.bucket_for(len(r), len(s))
                      for rs in streams
                      for r, s in zip(rs.reads, rs.ref_segments)})
    session.warmup(buckets)
    tail = -(-args.requests // len(lens)) % session.spec.batch_lanes
    warm = session.warmup(buckets, lanes=tail) if tail \
        else session.cache.stats()
    print(f"warmed {warm['executables']} executables "
          f"(lowerings={warm['lowerings']})")

    # req/s is END-TO-END wall clock around the whole stream (submit ->
    # last result collected): the session's own wall_s/retire_wall_s split
    # per-thread time, which under the threaded executor overlaps and
    # would overstate throughput if divided into either alone
    t0 = time.time()
    futures = {}
    for rs in streams:
        for read, seg in zip(rs.reads, rs.ref_segments):
            fut = session.submit(read, seg)   # routed to its length bucket;
            futures[fut.rid] = fut            # retire overlaps dispatch
    session.flush()
    results = {rid: fut.result() for rid, fut in futures.items()}
    elapsed = max(time.time() - t0, 1e-9)

    st = session.session_stats()
    cc = st["compile_cache"]
    ok = sum(1 for r in results.values() if r["ok"])
    stalls = cc["lowerings"] - warm["lowerings"]
    print(f"served {len(results)} requests in {st['dispatches']} dispatches "
          f"({st['pad_lanes']} pad lanes), {ok} aligned, "
          f"{len(results) - ok} failed, "
          f"{len(results) / elapsed:.1f} req/s end-to-end"
          + (f" (incl. {stalls} mid-stream rescue-rung lowering(s) — the "
             f"residual warmup stall documented in docs/api.md)"
             if stalls else ""))
    if args.executor == "thread":
        # decode/rescue wall-clock that ran on the retire thread instead
        # of serialising after each dispatch (the overlap the executor buys)
        print(f"retire thread absorbed {st['retire_wall_s']:.3f}s of host "
              f"decode + rescue alongside {st['wall_s']:.3f}s of dispatch")
    print(f"compile cache: {cc['lowerings']} lowerings "
          f"({cc['lowerings'] - warm['lowerings']} after warm-up, rescue-rung "
          f"lane classes) for {st['dispatches'] + st['rescue_dispatches']} "
          f"dispatches, {cc['hits']} hits ({cc['shared_hits']} from other "
          f"sessions of this spec) — steady state never re-traces")
    r0 = results[0]
    print(f"request 0: dist={r0['dist']} k_used={r0['k_used']} "
          f"cigar[:60]={r0['cigar'][:60]}")
    assert ok > 0

if args.metrics_dump:
    # every stat printed above is a view over this registry — the dump IS
    # the session's whole story (docs/observability.md)
    print("\n# ---- session metrics (Prometheus exposition text) ----")
    print(session.obs.prometheus(), end="")

if args.gateway:
    # ---- the multi-tenant gateway: SLOs on top of the same session ----
    # two tenants on their own client threads: a latency lane (priority
    # 0, short reads, per-request deadline) and a bulk lane (priority 1,
    # long reads) — the gateway preempts bulk at bucket granularity,
    # sweeps deadlines on the background pump, and sheds reject-fast at
    # the occupancy-derived capacity (docs/api.md, "The multi-tenant
    # gateway").
    import threading

    from repro.api import Gateway, GatewayPolicy, ShedError

    short_rs, long_rs = streams[0], streams[-1]
    with plan(cfg, rescue_rounds=1, batch_lanes=8,
              executor=args.executor) as session:
        session.warmup(buckets)
        gw = Gateway(session, GatewayPolicy(linger_s=0.002))
        gw.start_sweeper(0.001)
        # deadline is a stall canary, not a latency target: interpret-mode
        # compiles on a 1-core CI runner stall several seconds mid-stream,
        # and a queued request expiring would trip the expired==0 assert
        # below (same 30s convention as benchmarks gateway_multitenant)
        latency = gw.tenant("latency", priority=0, deadline_s=30.0)
        bulk = gw.tenant("bulk", priority=1)
        shed = 0

        def client(ten, rs, pace):
            global shed
            for read, seg in zip(rs.reads, rs.ref_segments):
                try:
                    ten.submit(read, seg)
                except ShedError:
                    shed += 1
                time.sleep(pace)

        threads = [
            threading.Thread(target=client, args=(latency, short_rs, 0.002)),
            threading.Thread(target=client, args=(bulk, long_rs, 0.006)),
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        gw.close()                      # drains: every future resolves
        st = gw.gateway_stats()
        print(f"gateway: {st['completed']} completed over 2 tenants "
              f"(capacity {st['capacity']} from the session's inflight "
              f"signal), {st['deadline_hits']} deadline hits / "
              f"{st['deadline_misses']} misses, {st['expired']} expired, "
              f"{st['shed']} shed, {st['partial_dispatches']} partial "
              f"(linger/deadline-urgent) dispatches")
        for name, ts in st["tenants"].items():
            print(f"  tenant {name}: submitted={ts['submitted']} "
                  f"completed={ts['completed']} hits={ts['deadline_hits']}")
        assert st["completed"] > 0 and st["expired"] == 0
        if args.metrics_dump:
            # the gateway shares the session's obs domain: one dump
            # carries admission (gateway_*) AND serving (session_*)
            # counters, tenants as labels (docs/observability.md)
            print("\n# ---- gateway metrics (Prometheus exposition "
                  "text) ----")
            print(gw.obs.prometheus(), end="")
