"""Alignment-as-a-service: batched request queue over the aligner engine
(the paper's GPU batch processing mapped to the framework's serving layer).

    PYTHONPATH=src python examples/serve_alignment.py
"""
import numpy as np

from repro.data.genome import ReadSimConfig, simulate_reads, synth_genome
from repro.serve.engine import AlignmentEngine, AlignRequest

genome = synth_genome(500_000, seed=3)
rs = simulate_reads(genome, 32, ReadSimConfig(read_len=800, error_rate=0.08,
                                              seed=9))
engine = AlignmentEngine(batch_size=16)
for i, (read, seg) in enumerate(zip(rs.reads, rs.ref_segments)):
    engine.submit(AlignRequest(rid=i, read=read, ref=seg))

stats = engine.serve_until_empty()
ok = sum(1 for r in engine.results.values() if r["ok"])
print(f"served {len(engine.results)} requests in {stats['batches']} batches, "
      f"{ok} aligned, {stats['failed']} failed, "
      f"{len(engine.results)/stats['wall_s']:.1f} req/s")
r0 = engine.results[0]
print(f"request 0: dist={r0['dist']} k_used={r0['k_used']} "
      f"cigar[:60]={r0['cigar'][:60]}")
