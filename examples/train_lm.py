"""LM-framework example: train a reduced model for a few hundred steps with
checkpointing + fault-tolerant supervision (CPU-scale; the same driver
lowers unchanged onto the production mesh — see launch/dryrun.py).

    PYTHONPATH=src python examples/train_lm.py [--arch xlstm-125m]
"""
import argparse
import sys

from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="llama3.2-1b")
ap.add_argument("--steps", type=int, default=200)
args = ap.parse_args()

train_main([
    "--arch", args.arch, "--tiny", "--layers", "4",
    "--steps", str(args.steps), "--batch", "8", "--seq", "256",
    "--ckpt-dir", "/tmp/repro_example_ckpt", "--ckpt-every", "50",
])
