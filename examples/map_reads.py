"""End-to-end READ MAPPING driver: the front half the other examples skip.

`align_longreads.py` fabricates candidate chains from ground truth; this
driver discovers them the way a real mapper does — minimizer index over
the genome, seed + colinear chain to candidate loci, banded X-drop
pre-filter, then the survivors stream through the AlignSession front
door.  Decoys are PLANTED IN THE GENOME (partial repeats of each read's
interior, `data.genome.plant_decoys`), so the pipeline has to find and
reject them itself; the driver asserts the acceptance floor (>= 95% of
reads at their true locus under the default 10% error profile with 4
decoys/read) — docs/mapper.md records the measured numbers.

    PYTHONPATH=src python examples/map_reads.py [--reads 200] [--len 1000]
    PYTHONPATH=src python examples/map_reads.py --fast     # CI smoke size
"""
import argparse
import time

import numpy as np

from repro.data.genome import (ReadSimConfig, plant_decoys, simulate_reads,
                               synth_genome)
from repro.mapper import MapperConfig, ReadMapper

ap = argparse.ArgumentParser()
ap.add_argument("--reads", type=int, default=200)
ap.add_argument("--len", type=int, default=1000, dest="rlen")
ap.add_argument("--genome", type=int, default=1_000_000)
ap.add_argument("--decoys", type=int, default=4)
ap.add_argument("--error-rate", type=float, default=0.10)
ap.add_argument("--W", type=int, default=64)
ap.add_argument("--fast", action="store_true",
                help="CI smoke size: small geometry, fewer/shorter reads")
args = ap.parse_args()
if args.fast:
    args.reads, args.rlen, args.genome, args.W = 24, 400, 120_000, 32

genome = synth_genome(args.genome, seed=11)
rs = simulate_reads(genome, args.reads,
                    ReadSimConfig(read_len=args.rlen,
                                  error_rate=args.error_rate, seed=5))
genome, decoy_pos = plant_decoys(genome, rs, decoys_per_read=args.decoys,
                                 chunk=max(160, args.rlen // 4), seed=13)
print(f"{args.reads} reads x {args.rlen}bp @ {args.error_rate:.0%} error, "
      f"{args.decoys} planted decoys/read, genome {len(genome):,}bp")

t0 = time.time()
mapper = ReadMapper(genome, MapperConfig(),
                    W=args.W, O=args.W * 3 // 8, k=args.W * 3 // 16,
                    rescue_rounds=2, batch_lanes=64)
t_index = time.time() - t0
print(f"index: {mapper.index.stats()} ({t_index:.2f}s)")

with mapper:
    t0 = time.time()
    out = mapper.map_batch(rs.reads)      # first batch AOT-compiles buckets
    t_first = time.time() - t0
    t0 = time.time()
    out = mapper.map_batch(rs.reads)
    t_steady = time.time() - t0

st = out.stats
hits = sum(1 for mr, tp in zip(out.mapped, rs.true_pos)
           if mr.ok and abs(mr.ref_start - tp) <= 20)
decoy_hits = sum(1 for mr in out.mapped if mr.ok and
                 any(abs(mr.ref_start - dp) <= 50
                     for dp in decoy_pos[mr.read_id]))
recall = hits / st["n_reads"]
reads_per_s = st["n_reads"] / t_steady

print(f"funnel: {st['n_candidates']} candidates from {st['n_reads']} reads "
      f"-> {st['n_killed']} killed by X-drop ({st['kill_rate']:.0%}) "
      f"-> {st['n_aligned']} aligned -> {st['n_mapped']} mapped")
print(f"true locus: {hits}/{st['n_reads']} ({recall:.1%}); "
      f"mapped at a decoy: {decoy_hits}")
print(f"first batch {t_first:.2f}s (compiles), steady {t_steady:.2f}s = "
      f"{reads_per_s:.1f} mapped reads/s")

assert recall >= 0.95, f"recall {recall:.1%} below the 95% floor"
assert decoy_hits == 0, f"{decoy_hits} reads mapped at planted decoys"
assert st["kill_rate"] > 0.2, "pre-filter killed nothing"

# the batch stats above are registry-counter DELTAS; the cumulative story
# (both batches, plus the session's own serving counters) lives on the
# shared obs registry — docs/observability.md maps every name
snap = mapper.obs.snapshot()
print(f"registry: mapper_reads_total={snap['mapper_reads_total']} "
      f"mapper_candidates_total={snap['mapper_candidates_total']} "
      f"session_dispatches_total={snap['session_dispatches_total']} "
      f"({len(snap)} metrics — see `serve_alignment.py --metrics-dump` "
      f"for the full Prometheus dump)")
print("OK")
