"""Quickstart: align a handful of simulated long reads with the improved
GenASM aligner and show the paper's three ideas in action.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.aligner import GenASMAligner
from repro.core.config import AlignerConfig
from repro.core.counting import reduction_report
from repro.data.genome import ReadSimConfig, simulate_reads, synth_genome

genome = synth_genome(100_000, seed=1)
rs = simulate_reads(genome, 4, ReadSimConfig(read_len=600, error_rate=0.08,
                                             seed=2))

cfg = AlignerConfig(W=64, O=24, k=12, store="band", early_term=True)
aligner = GenASMAligner(cfg)
res = aligner.align(rs.reads, rs.ref_segments)

for i, cig in enumerate(res.cigars):
    print(f"read {i}: dist={res.dist[i]}  failed={res.failed[i]}")
    print(f"  cigar[:70] = {cig[:70]}...")

rep = reduction_report(cfg, avg_levels=7.0)
print("\npaper's improvements for this config (per window):")
print(f"  footprint: {rep['baseline_footprint_words']}w -> "
      f"{rep['improved_touched_words']:.0f}w "
      f"({rep['footprint_reduction_touched']:.1f}x, paper: 24x)")
print(f"  accesses : {rep['baseline_accesses']}w -> {rep['improved_accesses']}w "
      f"({rep['access_reduction']:.1f}x, paper: 12x)")
print(f"  on-chip bytes/problem: {rep['vmem_bytes_per_problem']}")
