"""End-to-end driver mirroring the paper's evaluation (§II): simulate
PacBio-like reads from a genome, generate candidate chains (true locus +
decoys), align every candidate with the improved GenASM, report throughput
and accuracy.  This is the paper-native e2e pipeline (the aligner is the
"model"; the pipeline is sim -> chain -> align -> report).

    PYTHONPATH=src python examples/align_longreads.py [--reads 16] [--len 2000]
"""
import argparse
import time

import numpy as np

from repro.api import plan
from repro.core.config import AlignerConfig
from repro.core.oracle import validate_cigar
from repro.data.genome import (ReadSimConfig, candidate_chains, simulate_reads,
                               synth_genome)

ap = argparse.ArgumentParser()
ap.add_argument("--reads", type=int, default=16)
ap.add_argument("--len", type=int, default=2000, dest="rlen")
ap.add_argument("--decoys", type=int, default=1)
ap.add_argument("--error-rate", type=float, default=0.10)
ap.add_argument("--genome", type=int, default=1_000_000)
ap.add_argument("--W", type=int, default=64)
ap.add_argument("--backend", choices=("jnp", "pallas", "pallas_fused",
                                      "pallas_gpu"), default="jnp",
                help="aligner execution path (docs/backends.md)")
args = ap.parse_args()

if args.backend != "jnp":
    # the backend names a lowering; default_interpret decides where it
    # actually runs on this host (docs/backends.md)
    import jax
    from repro.kernels.ops import default_interpret
    mode = "interpret" if default_interpret(args.backend) else "compiled"
    print(f"backend {args.backend}: {mode} mode on this host "
          f"(jax default_backend={jax.default_backend()})")

genome = synth_genome(args.genome, seed=11)
rs = simulate_reads(genome, args.reads,
                    ReadSimConfig(read_len=args.rlen,
                                  error_rate=args.error_rate, seed=5))
chains = candidate_chains(genome, rs, decoys_per_read=args.decoys)
print(f"{args.reads} reads x {args.rlen}bp @ {args.error_rate:.0%} error, "
      f"{len(chains)} candidate locations")

# the session front door: plan once, warm the one bucket this pipeline
# hits, and the steady-state pass is pure cache hits (no re-tracing)
session = plan(AlignerConfig(W=args.W, O=args.W * 3 // 8, k=args.W * 3 // 16,
                             backend=args.backend),
               rescue_rounds=1, batch_lanes=len(chains))
reads = [rs.reads[i] for i, _ in chains]
refs = [seg for _, seg in chains]

t0 = time.time()
res = session.align(reads, refs)          # first call AOT-compiles buckets
t_first = time.time() - t0
lowered = session.cache.lowerings
t0 = time.time()
res = session.align(reads, refs)
t_steady = time.time() - t0
assert session.cache.lowerings == lowered, "steady state re-traced!"

ok = ~res.failed
true_mask = np.array([j == 0 for i, (ri, _) in enumerate(chains)
                      for j in [i % (1 + args.decoys)]])
n_true = args.reads
aligned_true = sum(1 for i in range(len(chains))
                   if i % (1 + args.decoys) == 0 and ok[i])
rejected_decoys = sum(1 for i in range(len(chains))
                      if i % (1 + args.decoys) != 0 and not ok[i])
for i in range(0, len(chains), max(1, len(chains) // 4)):
    if ok[i]:
        validate_cigar(reads[i], refs[i], res.ops[i], res.dist[i])

bp = sum(len(r) for r in reads)
print(f"aligned true loci: {aligned_true}/{n_true}; "
      f"rejected decoys: {rejected_decoys}/{len(chains)-n_true}")
print(f"summary: {res.summary(base_k=session.cfg.k)}")
print(f"steady-state: {t_steady:.2f}s = {len(chains)/t_steady:.1f} pairs/s = "
      f"{bp/t_steady/1e6:.2f} Mbp/s (single CPU core, {args.backend} "
      f"backend)")
print(f"mean edit distance of true alignments: "
      f"{np.mean([res.dist[i] for i in range(len(chains)) if i % (1+args.decoys)==0 and ok[i]]):.1f} "
      f"(expected ~{args.error_rate*args.rlen*0.95:.0f})")
