"""Render the §Results-delta table: baseline snapshots vs final cells.

  PYTHONPATH=src python experiments/delta.py
"""
import json
import pathlib

HERE = pathlib.Path(__file__).parent
FINAL = HERE / "dryrun"
BASES = [("iter1(naive)", HERE / "dryrun_baseline_iter1"),
         ("iter2(pre-donation)", HERE / "dryrun_baseline_iter2")]


def load(d, name):
    p = d / name
    if not p.exists():
        return None
    r = json.loads(p.read_text())
    return r if "roofline" in r else None


def fmt(r):
    rf = r["roofline"]
    mem = r["singlepod"]["memory"]
    gb = (mem["argument_bytes"] + mem["temp_bytes"]) / 2**30
    return rf, gb


def main():
    rows = ["| cell | baseline | GB/dev | bound_s | dominant | → final GB/dev"
            " | bound_s | dominant |",
            "|---|---|---|---|---|---|---|---|"]
    for p in sorted(FINAL.glob("*__*.json")):
        name = p.name
        if name.startswith("genasm-aligner"):
            continue
        fin = load(FINAL, name)
        if fin is None:
            continue
        base = None
        tag = ""
        for t, d in BASES:
            b = load(d, name)
            if b is not None:
                base, tag = b, t
                break
        if base is None:
            continue
        bf, bgb = fmt(base)
        ff, fgb = fmt(fin)
        # only show cells where something moved >10%
        if abs(bgb - fgb) / max(bgb, 1e-9) < 0.10 and \
           abs(bf["bound_s"] - ff["bound_s"]) / max(bf["bound_s"], 1e-9) < 0.10:
            continue
        rows.append(
            f"| {fin['arch']}/{fin['shape']} | {tag} | {bgb:.1f} | "
            f"{bf['bound_s']:.3f} | {bf['dominant']} | **{fgb:.1f}** | "
            f"**{ff['bound_s']:.3f}** | {ff['dominant']} |")
    print("\n".join(rows))


if __name__ == "__main__":
    main()
