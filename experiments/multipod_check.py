"""Assert every runnable final cell compiled on BOTH meshes and summarize
the pod axis's effect on the collective schedule (EXPERIMENTS §Dry-run)."""
import json
import pathlib

HERE = pathlib.Path(__file__).parent / "dryrun"

rows = ["| cell | singlepod colls | multipod colls | Δall-reduce |",
        "|---|---|---|---|"]
bad = []
for p in sorted(HERE.glob("*__*.json")):
    r = json.loads(p.read_text())
    if "skipped" in r or "error" in r or "singlepod" not in r:
        if "error" in r:
            bad.append(p.name)
        continue
    if "multipod" not in r:
        if not p.name.startswith("genasm-aligner"):
            bad.append(p.name + " (no multipod)")
        continue
    sp = r["singlepod"]["collectives_schedule"]["counts"]
    mp = r["multipod"]["collectives_schedule"]["counts"]
    dar = mp.get("all-reduce", 0) - sp.get("all-reduce", 0)
    rows.append(f"| {r['arch']}/{r['shape']} | {sum(sp.values())} | "
                f"{sum(mp.values())} | {dar:+d} |")
print("\n".join(rows))
if bad:
    print("\nFAILED CELLS:", bad)
    raise SystemExit(1)
print("\nall runnable cells compiled on both meshes")
