"""Benchmark harness — one function per paper table.
Prints ``name,us_per_call,derived`` CSV (assignment format).

  PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import platform
import subprocess
import sys


def _meta() -> dict:
    """Provenance for one bench run — committed beside the numbers so a
    trajectory regression is diagnosable at a glance (PR 8's -28%
    container-noise confusion: same numbers, different machine)."""
    import jax

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=root,
            capture_output=True, text=True, timeout=10).stdout.strip()
    except Exception:
        sha = ""
    return {
        "jax_version": jax.__version__,
        "cpu_count": os.cpu_count(),
        "platform": platform.platform(),
        "git_sha": sha or "unknown",
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller problem sizes (CI)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also dump rows + derived metrics as JSON "
                         "(uploaded as a CI artifact to track the perf "
                         "trajectory)")
    ap.add_argument("--devices", type=int, default=0, metavar="N",
                    help="also benchmark the mesh-sharded aligner on N "
                         "forced host devices (re-execs a fresh "
                         "interpreter; reports per-device pairs/s and "
                         "transfer bytes)")
    ap.add_argument("--obs-dir", metavar="DIR", default=None,
                    help="dump the session-stream observability bundle "
                         "(Prometheus text, JSONL + perfetto traces) "
                         "into DIR — uploaded as nightly CI artifacts")
    args = ap.parse_args()

    meta = _meta()
    print(f"# meta: jax={meta['jax_version']} cpus={meta['cpu_count']} "
          f"sha={meta['git_sha']} at={meta['timestamp_utc']}")
    print(f"# meta: {meta['platform']}")
    print("name,us_per_call,derived")
    all_rows = []
    all_derived = {}

    def emit(rows):
        for n, us, d in rows:
            print(f"{n},{us:.1f},{d}")
            all_rows.append({"name": n, "us_per_call": float(us),
                             "derived": str(d)})

    from benchmarks import bench_aligners
    rows, derived = bench_aligners.table(
        n_reads=8 if args.fast else 24, read_len=500 if args.fast else 1000)
    emit(rows)
    all_derived["aligners"] = derived
    print(f"aligners/speedup_improved_vs_unimproved,0.0,"
          f"{derived['improved_vs_unimproved']:.2f}x_paper_cpu1.9x")
    print(f"aligners/speedup_improved_vs_edlib_like,0.0,"
          f"{derived['improved_vs_edlib_like']:.2f}x_paper_cpu1.7x")
    print(f"aligners/speedup_improved_vs_edlib_banded_model,0.0,"
          f"{derived['improved_vs_edlib_banded_model']:.2f}x")
    print(f"aligners/speedup_improved_vs_ksw2_like,0.0,"
          f"{derived['improved_vs_ksw2_like']:.2f}x_paper_cpu15.2x")
    print(f"aligners/speedup_dc_engine_vs_edlib_like,0.0,"
          f"{derived['dc_engine_vs_edlib_like']:.2f}x_paper_cpu1.7x")
    # The pallas_gpu paper-headline family (4.1x / 62x / 7.2x) rides in
    # the table() rows above (bench_aligners.gpu_rows): pending-hardware
    # zeros on CPU-only runners, measured — and gated via the
    # gpu_pairs_per_s derived key — on runners with a CUDA/ROCm device.

    # the session front door: ragged-stream pairs/s + bucket-hit stats
    # (the compile-stability numbers the PR-over-PR trajectory tracks).
    # One obs bundle spans the backend legs (labelled session=<backend>)
    # so --obs-dir can export the whole run's metrics + trace.
    from repro.obs import Obs, write_artifacts
    bench_obs = Obs.private()
    rows, derived = bench_aligners.session_stream(
        n_reads=9 if args.fast else 24,
        max_len=160 if args.fast else 400, obs=bench_obs)
    emit(rows)
    all_derived["session"] = derived
    if args.obs_dir:
        paths = write_artifacts(bench_obs, args.obs_dir, prefix="obs")
        for kind, p in paths.items():
            print(f"# wrote {kind} artifact: {p}", file=sys.stderr)

    # the serving executor: sync vs background-retire on a rescue-heavy
    # ragged stream (decode-overlap gain) + cross-session cache sharing.
    # Not shrunk under --fast: below ~24 pairs the stream is too short for
    # overlap to beat thread-handoff overhead, and this row carries the
    # decode-overlap claim in the committed BENCH_* trajectory.
    rows, derived = bench_aligners.session_concurrent()
    emit(rows)
    print(f"aligners/session_concurrent_overlap_gain,0.0,"
          f"{derived['concurrent_overlap_gain_jnp']:.2f}x_thread_vs_sync")
    all_derived["session_concurrent"] = derived

    # the multi-tenant gateway: SLO rows from a skewed 2-tenant open-loop
    # load (latency p50/p99, deadline-hit-rate) plus the deterministic
    # burst-shed rate.  compare.py gates p99/shed GROWTH and hit-rate
    # DROPS.  Not shrunk under --fast: the latency percentiles need the
    # full request count to mean anything.
    rows, derived = bench_aligners.gateway_multitenant()
    emit(rows)
    all_derived["gateway"] = derived

    # the mapping front half: seed/chain/pre-filter funnel feeding the
    # session — mapped-reads/s is gated by compare.py like pairs/s
    rows, derived = bench_aligners.mapper_stream(
        n_reads=12 if args.fast else 24,
        read_len=300 if args.fast else 400,
        genome_len=100_000 if args.fast else 200_000)
    emit(rows)
    all_derived["mapper"] = derived

    from benchmarks import bench_memory
    rows, derived = bench_memory.table()
    emit(rows)
    all_derived["memory"] = {k: {kk: float(vv) for kk, vv in v.items()}
                             for k, v in derived.items()}

    from benchmarks import bench_kernel
    rows, derived = bench_kernel.table(B=1024 if args.fast else 4096)
    emit(rows)
    all_derived["kernel"] = derived

    if args.devices > 0:
        rows, derived = bench_aligners.multidevice(n_devices=args.devices)
        emit(rows)
        all_derived["multidevice"] = derived

    try:
        from benchmarks import roofline_table
        rows, _ = roofline_table.rows()
        emit(rows)
    except Exception as e:  # dry-run cells not generated yet
        print(f"roofline/unavailable,0.0,{e}")

    print("# derived summary (JSON):")
    print(json.dumps(all_derived, indent=1, default=float))
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"meta": meta, "rows": all_rows,
                       "derived": all_derived}, fh, indent=1, default=float)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
