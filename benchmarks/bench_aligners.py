"""Paper §II speedup table: improved GenASM vs unimproved GenASM vs
Edlib-like (Myers) vs KSW2-like (banded affine DP).

Methodology (CPU container, single core, all contenders jit-compiled jnp —
same framework, steady-state medians):
  * GenASM rows time the FULL alignment (DC + traceback + CIGAR commit).
  * Baseline rows time their (bit-parallel / DP) scoring phase; their
    tracebacks are host loops here (C loops in the real tools), so GenASM
    speedups reported against them are conservative lower bounds.
Scale: reads are shorter than the paper's 10 kb (CPU budget); the per-bp
work model of every contender is linear in read length at fixed error
rate, so ratios transfer (noted in EXPERIMENTS.md).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.dp import banded_affine_dist
from repro.baselines.myers import myers_distance
from repro.core import transfer
from repro.core.aligner import GenASMAligner
from repro.core.config import AlignerConfig
from repro.data.genome import ReadSimConfig, simulate_reads, synth_genome


def _median_time(fn, reps=3):
    fn()  # warm / compile
    ts = []
    for _ in range(reps):
        t0 = time.time()
        fn()
        ts.append(time.time() - t0)
    return sorted(ts)[len(ts) // 2]


def run(n_reads=24, read_len=1000, error_rate=0.10, seed=0):
    g = synth_genome(400_000, seed=seed)
    rs = simulate_reads(g, n_reads, ReadSimConfig(read_len=read_len,
                                                  error_rate=error_rate,
                                                  seed=seed + 1))
    rows = []

    # --- GenASM variants: full alignment incl. traceback ---
    for name, cfg in (
        ("genasm_improved", AlignerConfig(W=64, O=24, k=12, store="band",
                                          early_term=True)),
        ("genasm_sene_only", AlignerConfig(W=64, O=24, k=12, store="and",
                                           early_term=False)),
        ("genasm_unimproved", AlignerConfig(W=64, O=24, k=12, store="edges4",
                                            early_term=False)),
    ):
        al = GenASMAligner(cfg, rescue_rounds=1)
        t = _median_time(lambda al=al: al.align(rs.reads, rs.ref_segments))
        rows.append((name, t / n_reads))

    # --- Edlib-like: Myers bit-parallel NW distance (batched, jitted) ---
    m_pad = read_len
    n_pad = int(read_len * 1.25) + 32
    nw = -(-m_pad // 32)
    pat = np.full((n_reads, m_pad), 255, np.uint8)
    txt = np.full((n_reads, n_pad), 9, np.uint8)
    ml = np.zeros(n_reads, np.int32)
    nl = np.zeros(n_reads, np.int32)
    for i, (r, s) in enumerate(zip(rs.reads, rs.ref_segments)):
        pat[i, :len(r)] = r; ml[i] = len(r)
        txt[i, :min(len(s), n_pad)] = s[:n_pad]; nl[i] = min(len(s), n_pad)
    patj, txtj = jnp.array(pat, jnp.int32), jnp.array(txt, jnp.int32)
    mlj, nlj = jnp.array(ml), jnp.array(nl)

    # scoring-engine row: GenASM-DC over the same work in W x W windows
    # (distance phase only — apples-to-apples with the Myers distance row)
    from repro.core.genasm import dc_dmajor
    cfg_dc = AlignerConfig(W=64, O=24, k=12)
    n_windows = n_reads * (-(-read_len // cfg_dc.stride))
    rng = np.random.default_rng(1)
    wpat = jnp.array(rng.integers(0, 4, (n_windows, 64)), jnp.int32)
    wtxt = jnp.array(rng.integers(0, 4, (n_windows, 64)), jnp.int32)

    def run_dc():
        return jax.block_until_ready(dc_dmajor(wpat, wtxt, cfg=cfg_dc).dist)
    t_dc = _median_time(run_dc)
    rows.append(("genasm_dc_distance_only", t_dc / n_reads))

    def run_myers():
        return jax.block_until_ready(
            myers_distance(patj, txtj, mlj, nlj, nw=nw, n=n_pad))
    t_my = _median_time(run_myers)
    rows.append(("edlib_like_myers", t_my / n_reads))
    # modeled Edlib banding factor: words in Ukkonen band / total words
    k_est = int(np.median([d for d in np.asarray(run_myers())])) + 16
    band_factor = min(1.0, (2 * k_est / 32 + 2) / nw)
    rows.append(("edlib_like_banded_model", t_my * band_factor / n_reads))

    # --- KSW2-like: banded affine DP (batched, jitted) ---
    bw = min(160, max(64, int(read_len * error_rate * 1.6)))

    def run_dp():
        return jax.block_until_ready(
            banded_affine_dist(patj, txtj, mlj, nlj, bw=bw, m=m_pad,
                               sub=4, gapo=6, gape=2))
    t_dp = _median_time(run_dp)
    rows.append(("ksw2_like_affine_dp", t_dp / n_reads))
    return rows, n_reads, read_len


def gpu_rows(t, n_reads=24, read_len=1000, error_rate=0.10, seed=0):
    """The tentpole's paper-headline GPU row family (§IV of the paper:
    4.1x vs the CPU GenASM pipeline, 62x vs KSW2, 7.2x vs Edlib).

    On a machine with a CUDA/ROCm device the improved pipeline runs
    COMPILED under backend='pallas_gpu' (the Triton lowering of the fused
    DC+TB kernels) and the three ratios are measured against the CPU
    contender times in ``t`` (the run() table — same corpus recipe, same
    W=64/O=24/k=12 geometry).  Without one, every measured cell is 0.0:
    compare.py renders zero-vs-zero as ``pending-hardware (not gated)``,
    so the row family, derived keys and ratio definitions are committed
    and trajectory-stable BEFORE hardware lands — and flip to gated
    throughput rows (``gpu_pairs_per_s`` matches the gate's substring)
    the first nightly that runs on a GPU runner.

    Interpret-mode timing is deliberately NOT substituted when no GPU is
    present: it measures the Pallas interpreter, not the Triton kernels,
    and a plausible-looking wrong number is worse than an honest zero."""
    import jax

    from repro.kernels.ops import GPU_PLATFORMS

    on_gpu = jax.default_backend() in GPU_PLATFORMS
    rows, derived = [], {}
    t_gpu = 0.0
    if on_gpu:
        g = synth_genome(400_000, seed=seed)
        rs = simulate_reads(g, n_reads, ReadSimConfig(read_len=read_len,
                                                      error_rate=error_rate,
                                                      seed=seed + 1))
        cfg = AlignerConfig(W=64, O=24, k=12, backend="pallas_gpu")
        al = GenASMAligner(cfg, rescue_rounds=1)
        t_gpu = _median_time(
            lambda: al.align(rs.reads, rs.ref_segments)) / n_reads
    mode = "compiled_triton" if on_gpu else "pending-hardware_no_cuda_device"
    rows.append(("aligners/genasm_gpu_improved", t_gpu * 1e6, mode))
    derived["gpu_pairs_per_s"] = (1.0 / t_gpu) if t_gpu else 0.0
    for key, base_name, target in (
            ("gpu_vs_cpu_genasm", "genasm_improved", "paper_gpu4.1x"),
            ("gpu_vs_ksw2_like", "ksw2_like_affine_dp", "paper_gpu62x"),
            ("gpu_vs_edlib_like", "edlib_like_myers", "paper_gpu7.2x")):
        ratio = (t.get(base_name, 0.0) / t_gpu) if t_gpu else 0.0
        derived[key] = ratio
        rows.append((f"aligners/speedup_{key}", 0.0,
                     f"{ratio:.2f}x_{target}" if on_gpu
                     else f"pending-hardware_{target}"))
    return rows, derived


def rescue_paths(n_reads=8, read_len=400, seed=3, rescue_rounds=2):
    """On-device masked k-doubling vs the host numpy rescue loop on a
    high-error read set (most pairs need at least one rescue round).
    Reports wall time AND host<->device transfer telemetry per align call
    — the host loop's per-round re-upload/download is exactly the traffic
    the on-device path deletes."""
    g = synth_genome(200_000, seed=seed)
    rs = simulate_reads(g, n_reads, ReadSimConfig(read_len=read_len,
                                                  error_rate=0.20,
                                                  seed=seed + 1))
    cfg = AlignerConfig(W=64, O=24, k=6)
    rows, derived = [], {}
    for name, mode in (("rescue_device", "device"), ("rescue_host", "host")):
        al = GenASMAligner(cfg, rescue_rounds=rescue_rounds, rescue_mode=mode)
        t = _median_time(lambda al=al: al.align(rs.reads, rs.ref_segments))
        transfer.reset()
        res = al.align(rs.reads, rs.ref_segments)
        s = transfer.stats()
        n_resc = res.summary(base_k=cfg.k)["n_rescued"]
        rows.append((f"aligners/{name}", t * 1e6 / n_reads,
                     f"h2d={s.h2d_calls}x{s.h2d_bytes}B_d2h="
                     f"{s.d2h_calls}x{s.d2h_bytes}B_rescued={n_resc}"))
        derived[f"{name}_wall_s"] = t
        derived[f"{name}_h2d_calls"] = s.h2d_calls
        derived[f"{name}_d2h_calls"] = s.d2h_calls
        derived[f"{name}_bytes_per_align"] = s.h2d_bytes + s.d2h_bytes
    derived["rescue_device_vs_host_wall"] = (
        derived["rescue_host_wall_s"] / derived["rescue_device_wall_s"])
    derived["rescue_transfer_bytes_saved_per_align"] = (
        derived["rescue_host_bytes_per_align"]
        - derived["rescue_device_bytes_per_align"])
    return rows, derived


def session_stream(n_reads=24, max_len=400, seed=7,
                   backends=("jnp", "pallas_fused"), obs=None):
    """The front-door claim in numbers: a RAGGED mixed-length request
    stream served by repro.api.AlignSession — pairs/s per backend at
    steady state (warm compile cache), with the bucket-hit / lowering
    counters that prove shape stability.  The legacy exact-shape door
    would re-trace on every new batch max-length; the session compiles
    once per (length bucket, lane class) and then only ever hits.

    Every counter in the emitted rows is read from the obs registry (one
    shared bundle, labelled ``session=<backend>`` per leg) — pass
    ``obs`` to keep the bundle and export its Prometheus/perfetto
    artifacts (``benchmarks.run --obs-dir``).  A final ``obs='off'`` leg
    re-runs the jnp stream with observability disabled, measuring what
    the telemetry costs on the hot path (gated manually against the
    enabled row's baseline)."""
    from repro.api import plan
    from repro.obs import Obs

    obs = obs if obs is not None else Obs.private()
    g = synth_genome(200_000, seed=seed)
    lens = [max(48, max_len // 4), max(64, max_len // 2), max_len]
    per = -(-n_reads // len(lens))
    sets = [simulate_reads(g, per, ReadSimConfig(read_len=L,
                                                 error_rate=0.08,
                                                 seed=seed + i))
            for i, L in enumerate(lens)]
    reads = [r for rs in sets for r in rs.reads]
    refs = [f for rs in sets for f in rs.ref_segments]
    order = np.random.default_rng(seed).permutation(len(reads))
    rows, derived = [], {}
    for backend in backends:
        cfg = AlignerConfig(W=32, O=12, k=8, backend=backend)
        view = obs.labeled(session=backend)
        ses = plan(cfg, rescue_rounds=1, batch_lanes=8, obs=view)

        def stream(ses=ses):
            futs = [ses.submit(reads[i], refs[i]) for i in order]
            ses.flush()
            return [f.result() for f in futs]

        t = _median_time(stream)
        res = stream()
        # every counter below is a registry read (the legacy accessors
        # are views over the same metrics — tests/test_obs.py asserts
        # the equality)
        lowerings = view.counter("session_cache_lowerings_total").value
        hits = view.counter("session_cache_hits_total").value
        lanes = view.counter("session_lanes_total").value
        pad_lanes = view.counter("session_pad_lanes_total").value
        executables = ses.session_stats()["compile_cache"]["executables"]
        pairs_s = len(reads) / t
        rows.append((f"aligners/session_stream_{backend}",
                     t * 1e6 / len(reads),
                     f"pairs_per_s={pairs_s:.1f}_lowerings="
                     f"{lowerings}_hits={hits}_buckets="
                     f"{executables}"))
        derived[f"session_{backend}_pairs_per_s"] = pairs_s
        derived[f"session_{backend}_lowerings"] = lowerings
        derived[f"session_{backend}_cache_hits"] = hits
        derived[f"session_{backend}_executables"] = executables
        derived[f"session_{backend}_aligned"] = sum(
            1 for r in res if r["ok"])
        derived[f"session_{backend}_pad_lane_frac"] = (
            pad_lanes / max(1, lanes))

    # the obs-off leg: same jnp stream, telemetry traded away entirely —
    # its pairs/s must stay within compare.py tolerance of the enabled
    # row (the "no-op when disabled" claim, measured not asserted)
    cfg = AlignerConfig(W=32, O=12, k=8, backend="jnp")
    ses = plan(cfg, rescue_rounds=1, batch_lanes=8, obs="off")

    def stream_off(ses=ses):
        futs = [ses.submit(reads[i], refs[i]) for i in order]
        ses.flush()
        return [f.result() for f in futs]

    t_off = _median_time(stream_off)
    pairs_s_off = len(reads) / t_off
    rows.append(("aligners/session_stream_jnp_obs_off",
                 t_off * 1e6 / len(reads),
                 f"pairs_per_s={pairs_s_off:.1f}_telemetry=disabled"))
    derived["session_jnp_obs_off_pairs_per_s"] = pairs_s_off
    return rows, derived


def session_concurrent(n_reads=24, max_len=320, seed=11, backend="jnp",
                       error_rate=0.16, rescue_rounds=2):
    """The background retire executor's claim in numbers: one ragged,
    rescue-heavy stream served twice through repro.api — executor='sync'
    (retire inline: decode + compacted rescue serialise with dispatch) vs
    executor='thread' (decode/rescue run on the retire thread, overlapping
    the dispatch thread's padding and the device's compute).  The high
    error rate makes rescue rounds — retire-side device round-trips — a
    real fraction of the work, which is exactly what the executor
    overlaps.  Both sessions share one CompileCache, so the row also
    measures cross-session sharing: the second session must lower
    NOTHING (the multi-tenant claim, asserted by its own counters)."""
    from repro.api import CompileCache, plan

    g = synth_genome(200_000, seed=seed)
    lens = [max(48, max_len // 4), max(64, max_len // 2), max_len]
    per = -(-n_reads // len(lens))
    sets = [simulate_reads(g, per, ReadSimConfig(read_len=L,
                                                 error_rate=error_rate,
                                                 seed=seed + i))
            for i, L in enumerate(lens)]
    reads = [r for rs in sets for r in rs.reads]
    refs = [f for rs in sets for f in rs.ref_segments]
    order = np.random.default_rng(seed).permutation(len(reads))
    cfg = AlignerConfig(W=32, O=12, k=6, backend=backend)
    store = CompileCache()   # shared across both sessions (and executors)
    rows, derived = [], {}
    sessions = {}
    for mode in ("sync", "thread"):
        ses = plan(cfg, rescue_rounds=rescue_rounds, batch_lanes=8,
                   executor=mode, cache=store)
        sessions[mode] = ses

        def stream(ses=ses):
            futs = [ses.submit(reads[i], refs[i]) for i in order]
            ses.flush()
            return [f.result() for f in futs]

        t = _median_time(stream)
        res = stream()
        st = ses.session_stats()
        cc = st["compile_cache"]
        pairs_s = len(reads) / t
        rows.append((f"aligners/session_concurrent_{mode}_{backend}",
                     t * 1e6 / len(reads),
                     f"pairs_per_s={pairs_s:.1f}_rescue_dispatches="
                     f"{st['rescue_dispatches']}_lowerings="
                     f"{cc['lowerings']}_shared_hits={cc['shared_hits']}"))
        derived[f"concurrent_{mode}_{backend}_pairs_per_s"] = pairs_s
        derived[f"concurrent_{mode}_{backend}_aligned"] = sum(
            1 for r in res if r["ok"])
        derived[f"concurrent_{mode}_{backend}_lowerings"] = cc["lowerings"]
        derived[f"concurrent_{mode}_{backend}_shared_hits"] = \
            cc["shared_hits"]
    sessions["thread"].close()
    # decode-overlap gain (>1: the retire thread bought wall-clock) and the
    # multi-tenant sharing claim (the second session lowered nothing)
    derived[f"concurrent_overlap_gain_{backend}"] = (
        derived[f"concurrent_sync_{backend}_pairs_per_s"] and
        derived[f"concurrent_thread_{backend}_pairs_per_s"]
        / derived[f"concurrent_sync_{backend}_pairs_per_s"])
    derived[f"concurrent_shared_lowerings_saved_{backend}"] = (
        derived[f"concurrent_sync_{backend}_lowerings"]
        - derived[f"concurrent_thread_{backend}_lowerings"])
    assert derived[f"concurrent_thread_{backend}_lowerings"] == 0, \
        "cross-session cache sharing broken: second session re-lowered"
    # both executors must agree lane for lane (cheap spot check)
    assert (derived[f"concurrent_sync_{backend}_aligned"]
            == derived[f"concurrent_thread_{backend}_aligned"])
    return rows, derived


def gateway_multitenant(n_latency=48, n_bulk=16, seed=17, backend="jnp",
                        deadline_s=30.0, pace_s=0.002, reps=3):
    """The PR-8 SLO rows: a skewed 2-tenant open-loop load through the
    multi-tenant gateway (repro.api.Gateway) on a threaded session.

    Phase 1 — latency under mixed load: a latency tenant (priority 0,
    short reads, per-request deadline) and a bulk tenant (priority 1,
    long reads, no deadline) submit from separate client threads, paced
    open-loop (arrivals do NOT wait for completions), with the
    background sweeper running.  Reports the latency tenant's
    submit-to-completion p50/p99 and the deadline-hit-rate — after a
    warm pass that eats every compile, as the MEDIAN over `reps`
    steady-state passes (same discipline as _median_time: on a 1-core CI
    runner a single pass's tail is one bad scheduler decision away from
    a 100x outlier; the median per-pass percentile is stable enough to
    gate).  The deadline is deliberately a stall canary, not a noise
    gauge — orders of magnitude above the expected p99 — because the
    committed trajectory row gates deadline_hit_rate DROPS: it must sit
    at 1.0 whenever the machine makes progress at all, and a drop means
    requests genuinely wedged.

    Phase 2 — shedding under a burst: a fresh manual-pump gateway with a
    small fixed capacity takes an alternating bulk/latency burst with no
    drain between arrivals, so every admit/shed decision is pure count
    arithmetic: bulk (shed_frac 0.5) sheds once 8 of capacity 16 are in
    the system, latency at 16 — shed_rate is exactly deterministic and
    gates GROWTH.  The admitted backlog is then pumped and drained, and
    completion counts are asserted against admission counts."""
    import threading as _threading

    from repro.api import Gateway, GatewayPolicy, ShedError, plan

    g = synth_genome(200_000, seed=seed)
    short = simulate_reads(g, n_latency, ReadSimConfig(
        read_len=96, error_rate=0.08, seed=seed))
    long_ = simulate_reads(g, n_bulk, ReadSimConfig(
        read_len=320, error_rate=0.12, seed=seed + 1))
    cfg = AlignerConfig(W=32, O=12, k=6, backend=backend)
    rows, derived = [], {}

    # ---- phase 1: open-loop latency/deadline under priority mixing ----
    ses = plan(cfg, rescue_rounds=1, batch_lanes=8, executor="thread")
    gw = Gateway(ses, GatewayPolicy(capacity=4 * (n_latency + n_bulk),
                                    linger_s=0.002))
    gw.start_sweeper(0.005)                  # 1ms wakeups thrash a 1-core
    # runner's GIL; 5ms still bounds linger latency well under the SLO
    lat_ten = gw.tenant("latency", priority=0, deadline_s=deadline_s)
    bulk_ten = gw.tenant("bulk", priority=1)

    warm_ten = gw.tenant("latency-warm", priority=0)   # no deadline: the
    # warm pass eats every bucket/rescue compile (seconds on CPU), which
    # would spuriously expire real deadlines

    def open_loop(ten):
        lat_futs = []

        def lat_client():
            for r, f in zip(short.reads, short.ref_segments):
                lat_futs.append(ten.submit(r, f))
                time.sleep(pace_s)

        def bulk_client():
            for r, f in zip(long_.reads, long_.ref_segments):
                bulk_ten.submit(r, f)
                time.sleep(3 * pace_s)       # skew: bulk arrives slower

        ts = [_threading.Thread(target=lat_client),
              _threading.Thread(target=bulk_client)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        gw.flush_all()
        for fut in lat_futs:
            fut.result(timeout=60)
        ses.results()                        # retire bulk too
        return lat_futs

    open_loop(warm_ten)                      # warm pass: compiles buckets
    p50s, p99s, hits, n_lat = [], [], 0, 0
    for _ in range(reps):                    # median-of-passes percentiles
        lat_futs = open_loop(lat_ten)
        lats = sorted(f.latency for f in lat_futs)
        p50s.append(lats[len(lats) // 2] * 1e3)
        p99s.append(lats[min(len(lats) - 1, int(len(lats) * 0.99))] * 1e3)
        hits += sum(1 for f in lat_futs if f.deadline_met)
        n_lat += len(lat_futs)
    p50 = sorted(p50s)[len(p50s) // 2]
    p99 = sorted(p99s)[len(p99s) // 2]
    hit_rate = hits / n_lat
    st = gw.gateway_stats()
    gw.close()
    ses.close()
    rows.append((f"aligners/gateway_multitenant_latency_{backend}",
                 p50 * 1e3,  # us_per_call column: p50 in us
                 f"latency_p50_ms={p50:.2f}_p99_ms={p99:.2f}"
                 f"_deadline_hit_rate={hit_rate:.3f}"
                 f"_partial_dispatches={st['partial_dispatches']}"))
    derived[f"gateway_latency_p50_ms_{backend}"] = p50
    derived[f"gateway_latency_p99_ms_{backend}"] = p99
    derived[f"gateway_deadline_hit_rate_{backend}"] = hit_rate
    assert st["expired"] == 0 and st["shed"] == 0, \
        "phase 1 sized to never shed/expire; capacity or deadline drifted"

    # ---- phase 2: deterministic burst shedding ------------------------
    ses2 = plan(cfg, rescue_rounds=1, batch_lanes=8)
    gw2 = Gateway(ses2, GatewayPolicy(capacity=16, shed_frac=(1.0, 0.5)),
                  auto_pump=False)
    lat2 = gw2.tenant("latency", priority=0)
    bulk2 = gw2.tenant("bulk", priority=1)
    n_burst = 32
    admitted = 0
    for i in range(n_burst):                 # alternating burst, no drain
        for ten, pool in ((bulk2, long_), (lat2, short)):
            r = pool.reads[i % len(pool.reads)]
            f = pool.ref_segments[i % len(pool.ref_segments)]
            try:
                ten.submit(r, f)
                admitted += 1
            except ShedError:
                pass
    st2 = gw2.gateway_stats()
    shed_rate = st2["shed"] / (2 * n_burst)
    gw2.close()                              # drain the admitted backlog
    done = gw2.gateway_stats()["completed"]
    ses2.close()
    assert done == admitted, (done, admitted)
    rows.append((f"aligners/gateway_multitenant_shed_{backend}", 0.0,
                 f"shed_rate={shed_rate:.3f}_admitted={admitted}"
                 f"_of={2 * n_burst}"))
    derived[f"gateway_shed_rate_{backend}"] = shed_rate
    derived[f"gateway_burst_admitted_{backend}"] = admitted
    return rows, derived


def mapper_stream(n_reads=24, read_len=400, genome_len=200_000, decoys=4,
                  seed=13, backend="jnp"):
    """The end-to-end mapping funnel in numbers: seed -> chain -> X-drop
    pre-filter -> AlignSession on a decoy-rich simulated read batch.
    Reports steady-state mapped-reads/s (the gated throughput), the
    candidate-kill rate the pre-filter earns its place with, and index
    build time / density for context."""
    from repro.data.genome import plant_decoys
    from repro.mapper import MapperConfig, ReadMapper

    g = synth_genome(genome_len, seed=seed)
    rs = simulate_reads(g, n_reads, ReadSimConfig(read_len=read_len,
                                                  error_rate=0.10,
                                                  seed=seed + 1))
    g, decoy_pos = plant_decoys(g, rs, decoys_per_read=decoys,
                                chunk=max(160, read_len // 4),
                                seed=seed + 2)

    t0 = time.time()
    mapper = ReadMapper(g, MapperConfig(), backend=backend,
                        W=32, O=12, k=8, rescue_rounds=2, batch_lanes=32)
    t_index = time.time() - t0

    rows, derived = [], {}
    with mapper:
        t = _median_time(lambda: mapper.map_batch(rs.reads))
        out = mapper.map_batch(rs.reads)
    st = out.stats
    reads_s = n_reads / t
    hits = sum(1 for mr, tp in zip(out.mapped, rs.true_pos)
               if mr.ok and abs(mr.ref_start - tp) <= 20)
    rows.append((f"mapper/map_stream_{backend}", t * 1e6 / n_reads,
                 f"mapped_reads_per_s={reads_s:.1f}_kill_rate="
                 f"{st['kill_rate']:.2f}_true_locus={hits}/{n_reads}"))
    derived["mapper_mapped_reads_per_s"] = reads_s
    derived["mapper_kill_rate"] = st["kill_rate"]
    derived["mapper_candidates_per_read"] = st["n_candidates"] / n_reads
    derived["mapper_true_locus_frac"] = hits / n_reads
    derived["mapper_index_build_s"] = t_index
    derived["mapper_index_density"] = mapper.index.stats()["density"]
    assert hits / n_reads >= 0.9, "mapper bench lost the true loci"
    return rows, derived


def multidevice(n_devices=8, n_reads=32, read_len=240, seed=5,
                backend="jnp"):
    """Sharded-vs-single throughput on `n_devices` forced host devices.

    The device count must be fixed before jax imports, so this re-execs a
    fresh interpreter with XLA_FLAGS=--xla_force_host_platform_device_count
    and parses a JSON report: wall time per align call, pairs/s (total and
    per device) and host<->device transfer bytes for the single-device run
    vs the mesh-sharded run (GenASMAligner(mesh=...) — the shard_map'd
    Pallas dispatch / GSPMD jnp path of kernels.ops).  On this CPU
    container the mesh is emulated (no parallel speedup is expected — the
    number that matters is per-device pairs/s and unchanged transfer
    counts); on real hardware the same code path is the scaling claim."""
    import json as _json
    import os
    import subprocess
    import sys
    # the jnp path's GSPMD constraint (and equal sharding generally) needs
    # the batch to divide the device count — quantise so the sharded row
    # can never silently benchmark an unsharded run
    n_reads = -(-n_reads // n_devices) * n_devices
    script = f"""
import json, time
import numpy as np
from repro.core.aligner import GenASMAligner
from repro.core.config import AlignerConfig
from repro.core import transfer
from repro.launch.mesh import make_test_mesh
from repro.data.genome import ReadSimConfig, simulate_reads, synth_genome

g = synth_genome(200_000, seed={seed})
rs = simulate_reads(g, {n_reads}, ReadSimConfig(read_len={read_len},
                                                error_rate=0.10,
                                                seed={seed} + 1))
cfg = AlignerConfig(W=64, O=24, k=12, backend={backend!r})
rep = {{}}
for name, mesh in (('1dev', None),
                   ('{n_devices}dev', make_test_mesh(({n_devices},),
                                                     ('data',)))):
    al = GenASMAligner(cfg, rescue_rounds=1, mesh=mesh)
    al.align(rs.reads, rs.ref_segments)          # warm / compile
    transfer.reset()
    ts = []
    for _ in range(3):
        t0 = time.time()
        al.align(rs.reads, rs.ref_segments)
        ts.append(time.time() - t0)
    s = transfer.stats()
    rep[name] = {{'wall_s': sorted(ts)[1], 'h2d_bytes': s.h2d_bytes // 3,
                 'd2h_bytes': s.d2h_bytes // 3,
                 'h2d_calls': s.h2d_calls // 3,
                 'd2h_calls': s.d2h_calls // 3}}
print(json.dumps(rep))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env.setdefault("PYTHONPATH", "src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(r.stdout + r.stderr)
    rep = _json.loads(r.stdout.strip().splitlines()[-1])
    rows, derived = [], {"n_devices": n_devices, "n_reads": n_reads}
    for name, d in rep.items():
        ndev = n_devices if name != "1dev" else 1
        pairs_s = n_reads / d["wall_s"]
        rows.append((f"aligners/sharded_{name}", d["wall_s"] * 1e6 / n_reads,
                     f"pairs_per_s={pairs_s:.1f}_per_dev="
                     f"{pairs_s / ndev:.1f}_h2d={d['h2d_calls']}x"
                     f"{d['h2d_bytes']}B_d2h={d['d2h_calls']}x"
                     f"{d['d2h_bytes']}B"))
        derived[f"{name}_wall_s"] = d["wall_s"]
        derived[f"{name}_pairs_per_s_per_dev"] = pairs_s / ndev
        derived[f"{name}_transfer_bytes"] = d["h2d_bytes"] + d["d2h_bytes"]
    derived["sharded_vs_single_wall"] = (rep["1dev"]["wall_s"]
                                         / rep[f"{n_devices}dev"]["wall_s"])
    return rows, derived


def table(n_reads=24, read_len=1000):
    rows, n, L = run(n_reads, read_len)
    t = dict(rows)
    imp = t["genasm_improved"]
    out = []
    for name, sec in rows:
        out.append((f"aligners/{name}", sec * 1e6,
                    f"speedup_vs_improved={imp and sec/imp:.2f}"))
    derived = {
        "improved_vs_unimproved": t["genasm_unimproved"] / imp,
        "improved_vs_edlib_like": t["edlib_like_myers"] / imp,
        "improved_vs_edlib_banded_model": t["edlib_like_banded_model"] / imp,
        "improved_vs_ksw2_like": t["ksw2_like_affine_dp"] / imp,
        "dc_engine_vs_edlib_like": t["edlib_like_myers"]
                                   / t["genasm_dc_distance_only"],
    }
    g_rows, g_derived = gpu_rows(t, n_reads=n, read_len=L)
    out += g_rows
    derived.update(g_derived)
    r_rows, r_derived = rescue_paths(n_reads=max(4, n_reads // 3),
                                     read_len=min(400, L))
    out += r_rows
    derived.update(r_derived)
    return out, derived
