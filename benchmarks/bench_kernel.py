"""Kernel-level table: per-window DC cost for the improved vs unimproved
fills (jnp path timed on CPU; the Pallas kernel is validated in interpret
mode — its on-chip working set is reported against the 16MB VMEM budget,
which is the paper's 'entire DP table fits on-chip' claim), plus the fused
DC+TB kernel vs the split DC-kernel + host-traceback pipeline.

Interpret-mode wall times on CPU do not model TPU speed; the
architecturally meaningful fused-vs-split numbers are the HBM bytes per
window (the band round-trip the fusion deletes), reported alongside.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitops import SENTINEL_PAT, SENTINEL_TEXT
from repro.core.config import AlignerConfig
from repro.core.genasm import dc_dmajor, dc_jmajor
from repro.core.traceback import traceback
from repro.kernels.genasm_dc import (default_max_ops, default_max_steps,
                                     vmem_bytes, vmem_bytes_tail)
from repro.kernels.ops import (genasm_dc_op, genasm_tail_fused_op,
                               genasm_tb_fused_op)


def _t(fn, reps=3):
    fn()
    ts = []
    for _ in range(reps):
        t0 = time.time(); fn(); ts.append(time.time() - t0)
    return sorted(ts)[len(ts) // 2]


def table(B=4096, W=64, k=12):
    rng = np.random.default_rng(0)
    pat = jnp.array(rng.integers(0, 4, (B, W)), jnp.int32)
    txt = jnp.array(rng.integers(0, 4, (B, W)), jnp.int32)
    wl = jnp.full((B,), W, jnp.int32)
    cfg = AlignerConfig(W=W, O=24, k=k)

    t_imp = _t(lambda: jax.block_until_ready(
        dc_dmajor(pat, txt, cfg=cfg).dist))
    t_base = _t(lambda: jax.block_until_ready(
        dc_jmajor(pat, txt, wl, wl, k=k, n=W, nw=cfg.nw,
                  store="edges4").dist))
    rows = [
        ("kernel/dc_improved_batch4096", t_imp * 1e6,
         f"us_per_window={t_imp/B*1e6:.2f}"),
        ("kernel/dc_unimproved_batch4096", t_base * 1e6,
         f"us_per_window={t_base/B*1e6:.2f}"),
        ("kernel/vmem_tile512_bytes", 0.0,
         f"{vmem_bytes(cfg, 512)}_of_16MiB="
         f"{vmem_bytes(cfg, 512)/(16*2**20):.2%}"),
    ]
    derived = {"dc_speedup_jnp_cpu": t_base / t_imp,
               "vmem_fraction": vmem_bytes(cfg, 512) / (16 * 2**20)}

    f_rows, f_derived = fused_vs_split(B=min(B, 256))
    rows += f_rows
    derived.update(f_derived)

    t_rows, t_derived = tail_fused_vs_split(B=min(B, 128))
    rows += t_rows
    derived.update(t_derived)

    m_rows, m_derived = footprint_rows()
    rows += m_rows
    derived.update(m_derived)
    return rows, derived


def footprint_rows(W=64, O=24, k=12, tile=256):
    """Declared-scratch footprint of the tail kernel, banded (Scrooge-style
    store elimination; the default wherever the band is a strict win) vs
    the full-store fallback, at the headline geometry — plus the lane-tile
    ceiling the bucket planner buys back from the savings.  Pure shape
    math (no compiles); the scratch-accounting suite proves these equal
    the kernels' declared ``pltpu.VMEM`` shapes.  The ``vmem_bytes_*``
    derived keys are gated by benchmarks.compare: they may only shrink."""
    from repro.core.windowing import plan_lane_tile
    cfg = AlignerConfig(W=W, O=O, k=k)             # tail_store='auto' → band
    cfg_full = AlignerConfig(W=W, O=O, k=k, tail_store="full")
    banded = vmem_bytes_tail(cfg, tile)
    full = vmem_bytes_tail(cfg_full, tile)
    square = vmem_bytes(cfg, tile)
    lt_band, lt_full = plan_lane_tile(cfg), plan_lane_tile(cfg_full)
    gname = f"w{W}k{k}_tile{tile}"
    rows = [
        (f"kernel/tail_scratch_banded_{gname}", 0.0,
         f"{banded}B_of_16MiB={banded/(16*2**20):.2%}"),
        (f"kernel/tail_scratch_full_{gname}", 0.0,
         f"{full}B_of_16MiB={full/(16*2**20):.2%}"),
        (f"kernel/tail_store_reduction_{gname}", 0.0,
         f"{full/banded:.2f}x_full_over_banded"),
        (f"kernel/planned_lane_tile_{gname}", 0.0,
         f"banded={lt_band}_full={lt_full}_at_16MiB_budget"),
    ]
    derived = {
        f"vmem_bytes_tail_{gname}_banded": banded,
        f"vmem_bytes_tail_{gname}_full": full,
        f"tail_store_reduction_{gname}": full / banded,
        f"vmem_bytes_square_{gname}": square,
        f"planned_lane_tile_{gname}_banded": lt_band,
        f"planned_lane_tile_{gname}_full": lt_full,
    }
    return rows, derived


def fused_vs_split(B=256, W=32, k=7, tile=128):
    """Fused DC+TB kernel vs split DC kernel + host jnp traceback, both in
    interpret mode (small geometry: interpret-mode walks are host loops).
    Also reports the per-window band HBM round-trip the fusion removes."""
    rng = np.random.default_rng(1)
    cfg = AlignerConfig(W=W, O=max(1, W // 3), k=k)
    pat = jnp.array(rng.integers(0, 4, (B, W)), jnp.int32)
    txt = jnp.array(rng.integers(0, 4, (B, W)), jnp.int32)
    wl = jnp.full((B,), W, jnp.int32)
    stride = cfg.stride
    max_ops, max_steps = default_max_ops(cfg), default_max_steps(cfg)

    def split():
        dist, band, lvl = genasm_dc_op(pat, txt, cfg=cfg, tile=tile)
        tb = traceback({"Rb": band}, pat, txt, wl, wl, dist,
                       jnp.int32(stride), cfg=cfg, mode="band",
                       max_ops=max_ops, max_steps=max_steps)
        return tb["n_ops"]

    def fused():
        return genasm_tb_fused_op(pat, txt, cfg=cfg, commit_limit=stride,
                                  max_ops=max_ops, max_steps=max_steps,
                                  tile=tile)["n_ops"]

    t_split = _t(lambda: jax.block_until_ready(split()))
    t_fused = _t(lambda: jax.block_until_ready(fused()))
    # band round-trip bytes the fused kernel never moves (write + read back)
    band_bytes = 2 * (k + 1) * cfg.ncols_band * cfg.nwb * 4
    out_bytes = (max_ops + 8) * 4
    rows = [
        (f"kernel/split_dc_plus_host_tb_B{B}_W{W}", t_split * 1e6,
         f"us_per_window={t_split/B*1e6:.2f}_interpret"),
        (f"kernel/fused_dc_tb_B{B}_W{W}", t_fused * 1e6,
         f"us_per_window={t_fused/B*1e6:.2f}_interpret"),
        ("kernel/fused_hbm_bytes_saved_per_window", 0.0,
         f"band_roundtrip={band_bytes}B_vs_ops_out={out_bytes}B"),
    ]
    derived = {"fused_vs_split_wall": t_split / t_fused,
               "fused_hbm_traffic_ratio": out_bytes / (band_bytes + out_bytes)}
    return rows, derived


def tail_fused_vs_split(B=128, W=32, k=7, tile=64):
    """Rectangular-tail window: the fused tail kernel vs the jnp 'and'-store
    fill + host traceback it replaces, on ragged (m_len <= W, n_len <= wt)
    tails like core.windowing produces.  Also reports the store round-trip
    bytes the fusion removes and the tail kernel's VMEM footprint."""
    rng = np.random.default_rng(2)
    cfg = AlignerConfig(W=W, O=max(1, W // 3), k=k)
    wt = W + 4 * k
    max_ops_t, max_steps_t = W + wt, W + wt + 4
    pat = np.full((B, W), SENTINEL_PAT, np.uint8)
    txt = np.full((B, wt), SENTINEL_TEXT, np.uint8)
    ml = np.zeros(B, np.int32)
    nl = np.zeros(B, np.int32)
    for b in range(B):
        m = int(rng.integers(W // 2, W + 1))
        n = int(np.clip(m + rng.integers(-k, k + 1), 1, wt))
        p = rng.integers(0, 4, m).astype(np.uint8)
        t = p.copy()
        for _ in range(int(rng.integers(0, k))):
            t[rng.integers(0, len(t))] = rng.integers(0, 4)
        t = t[:n] if len(t) >= n else np.concatenate(
            [t, rng.integers(0, 4, n - len(t)).astype(np.uint8)])
        pat[b, :m] = p[::-1]
        txt[b, :n] = t[::-1]
        ml[b], nl[b] = m, n
    patj, txtj = jnp.asarray(pat), jnp.asarray(txt)
    mlj, nlj = jnp.asarray(ml), jnp.asarray(nl)

    def split():
        res = dc_jmajor(patj, txtj, mlj, nlj, k=k, n=wt, nw=cfg.nw,
                        store="and")
        tb = traceback(res.store, patj, txtj, mlj, nlj, res.dist,
                       jnp.int32(2 * (W + wt)), cfg=cfg, mode="and",
                       max_ops=max_ops_t, max_steps=max_steps_t)
        return tb["n_ops"]

    def fused():
        return genasm_tail_fused_op(patj, txtj, mlj, nlj, cfg=cfg, n_text=wt,
                                    commit_limit=2 * (W + wt),
                                    max_ops=max_ops_t, max_steps=max_steps_t,
                                    tile=tile)["n_ops"]

    t_split = _t(lambda: jax.block_until_ready(split()))
    t_fused = _t(lambda: jax.block_until_ready(fused()))
    # the full SENE store the split path round-trips per problem per tail
    store_bytes = 2 * (k + 1) * (wt + 1) * cfg.nw * 4
    out_bytes = (max_ops_t + 8) * 4
    vmem = vmem_bytes_tail(cfg, 256, n_text=wt)
    rows = [
        (f"kernel/tail_split_and_store_B{B}_W{W}", t_split * 1e6,
         f"us_per_tail={t_split/B*1e6:.2f}_interpret"),
        (f"kernel/tail_fused_B{B}_W{W}", t_fused * 1e6,
         f"us_per_tail={t_fused/B*1e6:.2f}_interpret"),
        ("kernel/tail_fused_hbm_bytes_saved", 0.0,
         f"store_roundtrip={store_bytes}B_vs_ops_out={out_bytes}B"),
        ("kernel/tail_vmem_tile256_bytes", 0.0,
         f"{vmem}_of_16MiB={vmem/(16*2**20):.2%}"),
    ]
    derived = {"tail_fused_vs_split_wall": t_split / t_fused,
               "tail_hbm_traffic_ratio": out_bytes / (store_bytes + out_bytes),
               "tail_vmem_fraction": vmem / (16 * 2**20)}
    return rows, derived
