"""Kernel-level table: per-window DC cost for the improved vs unimproved
fills (jnp path timed on CPU; the Pallas kernel is validated in interpret
mode — its on-chip working set is reported against the 16MB VMEM budget,
which is the paper's 'entire DP table fits on-chip' claim)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import AlignerConfig
from repro.core.genasm import dc_dmajor, dc_jmajor
from repro.kernels.genasm_dc import vmem_bytes


def _t(fn, reps=3):
    fn()
    ts = []
    for _ in range(reps):
        t0 = time.time(); fn(); ts.append(time.time() - t0)
    return sorted(ts)[len(ts) // 2]


def table(B=4096, W=64, k=12):
    rng = np.random.default_rng(0)
    pat = jnp.array(rng.integers(0, 4, (B, W)), jnp.int32)
    txt = jnp.array(rng.integers(0, 4, (B, W)), jnp.int32)
    wl = jnp.full((B,), W, jnp.int32)
    cfg = AlignerConfig(W=W, O=24, k=k)

    t_imp = _t(lambda: jax.block_until_ready(
        dc_dmajor(pat, txt, cfg=cfg).dist))
    t_base = _t(lambda: jax.block_until_ready(
        dc_jmajor(pat, txt, wl, wl, k=k, n=W, nw=cfg.nw,
                  store="edges4").dist))
    rows = [
        ("kernel/dc_improved_batch4096", t_imp * 1e6,
         f"us_per_window={t_imp/B*1e6:.2f}"),
        ("kernel/dc_unimproved_batch4096", t_base * 1e6,
         f"us_per_window={t_base/B*1e6:.2f}"),
        ("kernel/vmem_tile512_bytes", 0.0,
         f"{vmem_bytes(cfg, 512)}_of_16MiB="
         f"{vmem_bytes(cfg, 512)/(16*2**20):.2%}"),
    ]
    derived = {"dc_speedup_jnp_cpu": t_base / t_imp,
               "vmem_fraction": vmem_bytes(cfg, 512) / (16 * 2**20)}
    return rows, derived
