"""Render the roofline table (EXPERIMENTS.md §Roofline) from the dry-run
cell JSONs.  Each row: arch, shape, three terms, dominant, MODEL_FLOPS,
useful fraction, memory per device."""
from __future__ import annotations

import json
import pathlib

OUT = pathlib.Path("experiments/dryrun")


def load_cells(out_dir=OUT):
    cells = []
    for p in sorted(out_dir.glob("*.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def fmt_row(c):
    if "skipped" in c:
        return f"| {c['arch']} | {c['shape']} | — | — | — | skipped | — | — | — |"
    if "error" in c:
        return f"| {c['arch']} | {c['shape']} | — | — | — | ERROR | — | — | — |"
    r = c["roofline"]
    if "singlepod" not in c:      # aligner cells carry memory at top level
        mem = c["memory"]
        gb = (mem["argument_bytes"] + mem["temp_bytes"]) / 2**30
        return ("| {arch} | {shape} | {c:.4f} | {m:.4f} | {x:.4f} | {dom} | "
                "int-ops {io:.2e} | — | {gb:.1f} |").format(
            arch=c["arch"], shape=c["shape"], c=r["compute_s"],
            m=r["memory_s"], x=r["collective_s"], dom=r["dominant"],
            io=r["int_ops_per_chip"], gb=gb)
    mem = c["singlepod"]["memory"]
    gb = (mem["argument_bytes"] + mem["temp_bytes"]) / 2**30
    return ("| {arch} | {shape} | {c:.4f} | {m:.4f} | {x:.4f} | {dom} | "
            "{mf:.2e} | {uf:.2f} | {gb:.1f} |").format(
        arch=c["arch"], shape=c["shape"], c=r["compute_s"], m=r["memory_s"],
        x=r["collective_s"], dom=r["dominant"], mf=r["model_flops"],
        uf=r["useful_fraction"], gb=gb)


def markdown_table(out_dir=OUT) -> str:
    head = ("| arch | shape | compute_s | memory_s | collective_s | dominant "
            "| MODEL_FLOPS | useful_frac | GB/dev (args+temp) |\n"
            "|---|---|---|---|---|---|---|---|---|")
    return "\n".join([head] + [fmt_row(c) for c in load_cells(out_dir)])


def rows():
    """CSV-style rows for benchmarks.run."""
    out = []
    for c in load_cells():
        if "roofline" not in c:
            continue
        r = c["roofline"]
        bound = r.get("bound_s", max(r["compute_s"], r["memory_s"],
                                     r["collective_s"]))
        useful = r.get("useful_fraction")
        extra = f",useful={useful:.2f}" if useful is not None else ""
        out.append((f"roofline/{c['arch']}/{c['shape']}",
                    bound * 1e6, f"dominant={r['dominant']}{extra}"))
    return out, {}


if __name__ == "__main__":
    print(markdown_table())
