"""Paper §I claims: 24x memory-footprint and 12x memory-access reduction.

Counts are the analytic per-window model (core/counting.py, validated by
tests against instrumented fills), instantiated with the *measured* average
ET level count from real simulated-read windows (dc_dmajor reports levels
actually computed per batch)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.aligner import GenASMAligner
from repro.core.config import AlignerConfig
from repro.core.counting import reduction_report
from repro.data.genome import ReadSimConfig, simulate_reads, synth_genome


def measure_avg_levels(error_rate=0.10, read_len=1500, n_reads=16, seed=3):
    """Average (d_min + 1) per committed window from the aligner outputs:
    total committed edits / windows + 1 estimates the per-problem levels
    the d-major fill needs (exact per-problem ET accounting)."""
    g = synth_genome(200_000, seed=seed)
    rs = simulate_reads(g, n_reads, ReadSimConfig(read_len=read_len,
                                                  error_rate=error_rate,
                                                  seed=seed + 1))
    cfg = AlignerConfig(W=64, O=24, k=12)
    al = GenASMAligner(cfg, rescue_rounds=1)
    res = al.align(rs.reads, rs.ref_segments)
    ok = ~res.failed
    n_windows = np.ceil((read_len - cfg.W) / cfg.stride) + 1
    per_window_edits = res.dist[ok].mean() / n_windows
    return float(per_window_edits + 1.0), cfg


def table():
    rows, derived = [], {}
    for err, label in ((0.10, "pacbio_10pct"), (0.05, "hifi_5pct")):
        avg_levels, cfg = measure_avg_levels(err)
        rep = reduction_report(cfg, avg_levels=avg_levels)
        rows.append((f"memory/{label}/footprint_reduction", 0.0,
                     f"{rep['footprint_reduction_touched']:.1f}x_paper24x"))
        rows.append((f"memory/{label}/access_reduction", 0.0,
                     f"{rep['access_reduction']:.1f}x_paper12x"))
        rows.append((f"memory/{label}/avg_levels_ET", 0.0,
                     f"{avg_levels:.2f}_of_{cfg.k + 1}"))
        rows.append((f"memory/{label}/vmem_bytes_per_problem", 0.0,
                     str(rep["vmem_bytes_per_problem"])))
        derived[label] = rep
    return rows, derived
