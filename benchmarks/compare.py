"""Nightly perf-trajectory gate: diff a fresh bench_report.json against the
latest committed BENCH_*.json baseline and FAIL on regressions of metrics
both reports share, so the serving path's throughput — and now its VMEM
footprint — can only ratchet forward.

    PYTHONPATH=src python -m benchmarks.compare bench_report.json
        [--baseline BENCH_PR5.json] [--threshold 0.30]

Compared metrics are every numeric leaf anywhere under ``derived`` whose
dotted path contains ``pairs_per_s`` (throughput rows, one per
backend/executor), ``vmem_bytes`` (declared-scratch footprint rows —
the numbers the scratch-accounting suite proves are real), or one of the
PR-8 gateway SLO keys (``latency_p99_ms``, ``shed_rate``,
``deadline_hit_rate``).  The gate is direction-aware:

  * ``pairs_per_s`` regresses when ``current < baseline * (1 - threshold)``
    — throughput must not fall;
  * ``vmem_bytes`` regresses when ``current > baseline * (1 + threshold)``
    — footprint must not grow (these are deterministic shape math, so the
    tolerance only shields genuine accounting redefinitions, not noise);
  * latency semantics (the gateway SLO rows from the multi-tenant
    open-loop load): ``latency_p99_ms`` and ``shed_rate`` gate GROWTH
    like ``vmem_bytes`` (tail latency and rejected traffic must not
    balloon), ``deadline_hit_rate`` gates DROPS like throughput (the SLO
    must keep being met).  ``latency_p99_ms`` gates at a widened
    tolerance (``TOLERANCE_MULT``): wall-clock tails on shared 1-core
    runners have ~2x healthy run-to-run spread.

Only metrics present in BOTH reports can fail the gate.  Added metrics
(no baseline) and removed metrics (no current value) are listed
explicitly after the table — loudly, so a silently-renamed key can't
dodge the gate unnoticed — but exit 0.  A metric at zero in BOTH
reports is a committed placeholder for hardware the runner lacks (the
``pallas_gpu`` rows on CPU CI) and renders as ``pending-hardware (not
gated)``; zero on only the baseline side renders ``zero-baseline``.

A markdown trajectory table (throughput and footprint columns side by
side) is printed, and appended to ``$GITHUB_STEP_SUMMARY`` when set (the
CI job summary).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

#: substrings of a dotted metric path that make it gated, with the sign of
#: a regression: +1 = lower is worse (throughput, SLO hit rate), -1 =
#: higher is worse (footprint, tail latency, shed rate).  First match
#: wins.
GATED = (("pairs_per_s", +1), ("mapped_reads_per_s", +1),
         ("vmem_bytes", -1), ("deadline_hit_rate", +1),
         ("latency_p99_ms", -1), ("shed_rate", -1))

#: per-metric widening of the shared threshold: wall-clock tail latency
#: on a 1-core CI runner has ~2x run-to-run spread between perfectly
#: healthy runs (the bench already medians over passes), so its ceiling
#: gates at 3x the base threshold — a genuine scheduling regression is
#: an order of magnitude, not tens of percent.  Deterministic rates
#: (shed_rate) and counters keep the tight default.
TOLERANCE_MULT = (("latency_p99_ms", 3.0),)


def _tolerance_mult(path: str) -> float:
    for sub, mult in TOLERANCE_MULT:
        if sub in path:
            return mult
    return 1.0


def _metric_sign(path: str) -> int | None:
    for sub, sign in GATED:
        if sub in path:
            return sign
    return None


def _flatten_metrics(report: dict) -> dict[str, float]:
    """{dotted.path: value} for every numeric leaf under ``derived`` whose
    path names a gated metric (recursive — nested groups like
    ``memory.<profile>.vmem_bytes_per_problem`` count too)."""
    out = {}

    def walk(prefix: str, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}.{k}" if prefix else str(k), v)
        elif isinstance(node, (int, float)) and not isinstance(node, bool):
            if _metric_sign(prefix) is not None:
                out[prefix] = float(node)

    walk("", report.get("derived") or {})
    return out


def latest_baseline(root: str) -> str | None:
    """The committed BENCH_PR<N>.json with the highest N (falls back to
    lexicographic order for non-PR-numbered files)."""
    cands = glob.glob(os.path.join(root, "BENCH_*.json"))
    if not cands:
        return None

    def key(p):
        m = re.search(r"BENCH_PR(\d+)", os.path.basename(p))
        return (1, int(m.group(1))) if m else (0, os.path.basename(p))

    return max(cands, key=key)


def compare(current: dict, baseline: dict, threshold: float):
    """Returns (table_rows, regressions, added, removed): one row per
    shared metric as (name, base, cur, delta_frac, status); added/removed
    are the names only in one report (reported, never gating)."""
    cur = _flatten_metrics(current)
    base = _flatten_metrics(baseline)
    rows, regressions = [], []
    added = sorted(set(cur) - set(base))
    removed = sorted(set(base) - set(cur))
    for name in sorted(set(cur) & set(base)):
        c, b = cur[name], base[name]
        if b == 0:
            # a zero baseline gates nothing: the floor c >= 0 (or ceiling
            # c <= 0) is trivially true for any throughput and the delta
            # is undefined — surface it instead of a misleading "ok +0.0%".
            # Zero on BOTH sides is a different situation: a committed
            # placeholder for hardware this runner lacks (the pallas_gpu
            # rows on CPU CI) — annotate it as such so the table reads as
            # "structured, awaiting hardware", not as a suspicious zero.
            status = ("pending-hardware (not gated)" if c == 0
                      else "zero-baseline (not gated)")
            rows.append((name, b, c, None, status))
            continue
        delta = (c - b) / b
        eff = threshold * _tolerance_mult(name)
        if _metric_sign(name) > 0:                 # throughput: floor
            ok = c >= b * (1.0 - eff)
        else:                                      # footprint: ceiling
            ok = c <= b * (1.0 + eff)
        status = "ok" if ok else "REGRESSION"
        rows.append((name, b, c, delta, status))
        if not ok:
            regressions.append(name)
    for name in added:
        rows.append((name, None, cur[name], None, "added"))
    for name in removed:
        rows.append((name, base[name], None, None, "removed"))
    return rows, regressions, added, removed


def _fmt(name: str, v: float | None) -> str:
    if v is None:
        return "—"
    if "vmem_bytes" in name:
        return f"{v:,.0f}"
    if "_rate" in name:                        # 0..1 fractions: 3 decimals
        return f"{v:.3f}"
    return f"{v:.1f}"


def _meta_line(label: str, report: dict) -> str | None:
    """One-line provenance for a report's ``meta`` block (benchmarks.run
    writes it) — shown beside the gate table so a regression caused by a
    different machine/jax/sha is diagnosable at a glance."""
    m = report.get("meta")
    if not m:
        return f"{label}: no meta block (pre-PR9 report)"
    return (f"{label}: jax={m.get('jax_version', '?')} "
            f"cpus={m.get('cpu_count', '?')} sha={m.get('git_sha', '?')} "
            f"at={m.get('timestamp_utc', '?')} "
            f"[{m.get('platform', '?')}]")


def render(rows, regressions, added, removed, threshold: float,
           baseline_path: str, current: dict | None = None,
           baseline: dict | None = None) -> str:
    lines = [
        f"### Bench trajectory vs `{os.path.basename(baseline_path)}` "
        f"(gate: -{threshold:.0%} pairs/s, +{threshold:.0%} vmem_bytes)",
        "",
    ]
    meta_lines = [ln for ln in
                  (_meta_line("current", current or {}),
                   _meta_line("baseline", baseline or {})) if ln]
    if meta_lines:
        lines += [f"> {ln}" for ln in meta_lines] + [""]
    lines += [
        "| metric | baseline | current | delta | status |",
        "|---|---:|---:|---:|---|",
    ]
    for name, b, c, delta, status in rows:
        ds = f"{delta:+.1%}" if delta is not None else "—"
        mark = {"REGRESSION": "❌", "ok": "✅"}.get(status, "·")
        lines.append(f"| {name} | {_fmt(name, b)} | {_fmt(name, c)} | {ds} "
                     f"| {mark} {status} |")
    lines.append("")
    if added:
        lines.append(f"Added metrics (no baseline, not gated): "
                     f"{', '.join(f'`{n}`' for n in added)}")
    if removed:
        lines.append(f"Removed metrics (no current value, not gated): "
                     f"{', '.join(f'`{n}`' for n in removed)}")
    if not added and not removed:
        lines.append("Metric key set unchanged from baseline.")
    return "\n".join(lines) + "\n"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("report", help="fresh bench_report.json (benchmarks.run "
                                   "--json)")
    ap.add_argument("--baseline", default=None,
                    help="committed BENCH_*.json to diff against "
                         "(default: the latest by PR number)")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="allowed fractional pairs/s drop / vmem_bytes "
                         "growth (default 0.30)")
    args = ap.parse_args()

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    baseline_path = args.baseline or latest_baseline(root)
    if baseline_path is None:
        print("no committed BENCH_*.json baseline found — nothing to gate")
        return 0
    with open(args.report) as fh:
        current = json.load(fh)
    with open(baseline_path) as fh:
        baseline = json.load(fh)

    rows, regressions, added, removed = compare(current, baseline,
                                                args.threshold)
    table = render(rows, regressions, added, removed, args.threshold,
                   baseline_path, current=current, baseline=baseline)
    print(table)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as fh:
            fh.write(table)
    if regressions:
        print(f"FAIL: {len(regressions)} regression(s) beyond "
              f"{args.threshold:.0%}: {', '.join(regressions)}",
              file=sys.stderr)
        return 1
    print(f"ok: {sum(1 for r in rows if r[4] == 'ok')} shared metric(s) "
          f"within {args.threshold:.0%} of baseline; "
          f"{len(added)} added, {len(removed)} removed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
