"""Nightly perf-trajectory gate: diff a fresh bench_report.json against the
latest committed BENCH_*.json baseline and FAIL on large pairs/s
regressions, so the serving path's throughput can only ratchet forward.

    PYTHONPATH=src python -m benchmarks.compare bench_report.json
        [--baseline BENCH_PR5.json] [--threshold 0.30]

Compared metrics are every numeric ``derived`` entry whose name contains
``pairs_per_s`` (one per backend/executor row — the numbers the PR-over-PR
trajectory tracks).  A metric regresses when
``current < baseline * (1 - threshold)``; the default 30% tolerance
absorbs runner-to-runner noise (the committed baselines come from a
different container than the CI runners) while still catching a serving
path that quietly fell off a cliff.  New metrics (no baseline) and
retired metrics (no current value) are reported but never fail.

A markdown trajectory table is printed, and appended to
``$GITHUB_STEP_SUMMARY`` when set (the CI job summary).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys


def _flatten_pairs_metrics(report: dict) -> dict[str, float]:
    """{section.key: value} for every numeric derived metric that names a
    pairs/s throughput."""
    out = {}
    for section, d in (report.get("derived") or {}).items():
        if not isinstance(d, dict):
            continue
        for k, v in d.items():
            if "pairs_per_s" in k and isinstance(v, (int, float)):
                out[f"{section}.{k}"] = float(v)
    return out


def latest_baseline(root: str) -> str | None:
    """The committed BENCH_PR<N>.json with the highest N (falls back to
    lexicographic order for non-PR-numbered files)."""
    cands = glob.glob(os.path.join(root, "BENCH_*.json"))
    if not cands:
        return None

    def key(p):
        m = re.search(r"BENCH_PR(\d+)", os.path.basename(p))
        return (1, int(m.group(1))) if m else (0, os.path.basename(p))

    return max(cands, key=key)


def compare(current: dict, baseline: dict, threshold: float):
    """Returns (table_rows, regressions): one row per metric as
    (name, base, cur, delta_frac|None, status)."""
    cur = _flatten_pairs_metrics(current)
    base = _flatten_pairs_metrics(baseline)
    rows, regressions = [], []
    for name in sorted(set(cur) | set(base)):
        c, b = cur.get(name), base.get(name)
        if b is None:
            rows.append((name, None, c, None, "new"))
        elif c is None:
            rows.append((name, b, None, None, "gone"))
        else:
            delta = (c - b) / b if b else 0.0
            status = "ok" if c >= b * (1.0 - threshold) else "REGRESSION"
            rows.append((name, b, c, delta, status))
            if status == "REGRESSION":
                regressions.append(name)
    return rows, regressions


def render(rows, threshold: float, baseline_path: str) -> str:
    lines = [
        f"### Bench trajectory vs `{os.path.basename(baseline_path)}` "
        f"(gate: -{threshold:.0%} pairs/s)",
        "",
        "| metric | baseline | current | delta | status |",
        "|---|---:|---:|---:|---|",
    ]
    for name, b, c, delta, status in rows:
        bs = f"{b:.1f}" if b is not None else "—"
        cs = f"{c:.1f}" if c is not None else "—"
        ds = f"{delta:+.1%}" if delta is not None else "—"
        mark = "❌" if status == "REGRESSION" else "✅" \
            if status == "ok" else "·"
        lines.append(f"| {name} | {bs} | {cs} | {ds} | {mark} {status} |")
    return "\n".join(lines) + "\n"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("report", help="fresh bench_report.json (benchmarks.run "
                                   "--json)")
    ap.add_argument("--baseline", default=None,
                    help="committed BENCH_*.json to diff against "
                         "(default: the latest by PR number)")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="allowed fractional pairs/s drop (default 0.30)")
    args = ap.parse_args()

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    baseline_path = args.baseline or latest_baseline(root)
    if baseline_path is None:
        print("no committed BENCH_*.json baseline found — nothing to gate")
        return 0
    with open(args.report) as fh:
        current = json.load(fh)
    with open(baseline_path) as fh:
        baseline = json.load(fh)

    rows, regressions = compare(current, baseline, args.threshold)
    table = render(rows, args.threshold, baseline_path)
    print(table)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as fh:
            fh.write(table)
    if regressions:
        print(f"FAIL: {len(regressions)} pairs/s regression(s) beyond "
              f"{args.threshold:.0%}: {', '.join(regressions)}",
              file=sys.stderr)
        return 1
    print(f"ok: {sum(1 for r in rows if r[4] == 'ok')} metric(s) within "
          f"{args.threshold:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
